"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (per spec)."""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        ablation_formats,
        fig1_scaling_law,
        fig2_gradient_alignment,
        fig3_kernel_speedups,
        roofline_report,
        serve_throughput,
        table2_quantizer_metrics,
        table3_method_comparison,
        table7_ptq_vs_native,
    )

    suites = [
        ("table2", table2_quantizer_metrics.run),
        ("fig1", fig1_scaling_law.run),
        ("fig2", fig2_gradient_alignment.run),
        ("fig3", fig3_kernel_speedups.run),
        ("table3", table3_method_comparison.run),
        ("table7", table7_ptq_vs_native.run),
        ("ablation", ablation_formats.run),
        ("roofline", roofline_report.run),
        ("serve", serve_throughput.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if only and only != name:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            print(f"{name},0,ERROR: {type(e).__name__}: {e}")
            failures += 1
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.1f},{derived}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
