"""Table 2 reproduction: error–bias trade-off of quantizer schemes.

Exact reproduction (no GPU needed): the paper computes MSE on Gaussian data
and PMA misalignment 1 − E[1/S] per scheme.  Expected (paper): QuEST MSE
1.35e-2 < RTN 1.40e-2 < SR 2.84e-2; misalignment SR 0 < RTN 9.3e-3 < QuEST
1.3e-2; RTN-PMA ≈ aligned.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import metrics as M
from repro.core import quantizers as Q

PAPER = {  # (MSE, misalignment) from Table 2
    "sr_absmax": (2.84e-2, 0.0),
    "rtn_absmax": (1.40e-2, 9.3e-3),
    "quest": (1.35e-2, 1.3e-2),
    "rtn_absmax_pma": (1.42e-2, 2.8e-5),
}


def run() -> list[tuple]:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2048, 32), jnp.float32)
    xflat = jax.random.normal(jax.random.PRNGKey(1), (8192,), jnp.float32)
    rows = []
    for name in PAPER:
        t0 = time.perf_counter()
        if name == "sr_absmax":
            r = Q.sr_absmax(x, jax.random.PRNGKey(2))
        elif name == "rtn_absmax":
            r = Q.rtn_absmax(x)
        elif name == "quest":
            r = Q.quest(x)
        else:
            r = Q.rtn_absmax_pma(x)
        mse = float(jnp.mean((r.values - x) ** 2) / jnp.mean(x**2))
        mis = float(M.pma_misalignment(xflat, name, jax.random.PRNGKey(3),
                                       num_samples=48))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table2/{name}/mse", us,
                     f"{mse:.3e} (paper {PAPER[name][0]:.2e})"))
        rows.append((f"table2/{name}/misalignment", us,
                     f"{mis:.3e} (paper {PAPER[name][1]:.1e})"))
    # the headline orderings must reproduce
    m = {n: float(jnp.mean((q.values - x) ** 2)) for n, q in [
        ("quest", Q.quest(x)), ("rtn", Q.rtn_absmax(x)),
        ("sr", Q.sr_absmax(x, jax.random.PRNGKey(4)))]}
    ok = m["quest"] < m["rtn"] < m["sr"]
    rows.append(("table2/ordering_quest<rtn<sr", 0.0, "PASS" if ok else "FAIL"))
    return rows
