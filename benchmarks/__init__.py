"""Benchmark harness: one module per paper table/figure.

  table2  — forward/backward quantizer metrics (MSE, PMA) — exact repro
  table3  — fully-quantized training method comparison (scaled-down)
  fig1    — scaling-law fit + FP4/FP8 optimality regions
  fig3    — linear-layer speedup model (roofline-derived) + kernel timings
  table7  — PTQ (QuaRot-style) vs native Quartet training
  roofline — per-(arch × shape × mesh) three-term roofline from the dry-run
"""
