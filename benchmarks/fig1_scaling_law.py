"""Figure 1 reproduction: scaling-law fit + FP4/FP8 optimality regions.

(a) stage-1/stage-2 fit machinery validated on the paper's own published
    coefficients (Table 6) — planted-recovery is exact;
(b,c) the optimality regions under the Table-1 BOPS speedup model with the
    paper's fitted efficiencies (effN=0.64, effD=0.94): the FP4-forward
    region must grow when the backward drops from FP8 to FP4, and popular
    (N, D/N) points (Llama-3-8B-class) must fall inside it.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.scaling_law import (
    PAPER_COEFFS,
    SPEEDUPS,
    ScalingLaw,
    fit_baseline,
    fit_efficiencies,
    optimality_region,
)


def run() -> list[tuple]:
    rows = []
    law = ScalingLaw(**{k if k != "gamma" else "gamma": v
                        for k, v in PAPER_COEFFS.items()})

    # (a) fit recovery on the paper-coefficient surface
    t0 = time.perf_counter()
    runs = [(n, n * r, float(law.loss(n, n * r)))
            for n in [30e6, 50e6, 100e6, 200e6]
            for r in [25, 50, 100, 200, 400, 800]]
    fitted = fit_baseline(runs)
    err = max(abs(fitted.loss(n, d) - l) / l for n, d, l in runs)
    rows.append(("fig1a/stage1_fit_max_rel_err", (time.perf_counter() - t0) * 1e6,
                 f"{err:.2e}"))

    t0 = time.perf_counter()
    qruns = [(n, n * r, float(law.loss(n, n * r, 0.64, 0.94)))
             for n in [30e6, 100e6] for r in [25, 100, 400, 800]]
    en, ed = fit_efficiencies(law, qruns)
    rows.append(("fig1a/stage2_effN_effD", (time.perf_counter() - t0) * 1e6,
                 f"effN={en:.3f} effD={ed:.3f} (paper 0.64/0.94)"))

    # (b,c) optimality regions
    def region(backward):
        methods = {}
        for fwd in ("fp4", "fp8"):
            sp = SPEEDUPS[(fwd, backward)]
            methods[fwd] = dict(
                eff_n=0.64 if fwd == "fp4" else 1.0,
                eff_d=(0.94 if fwd == "fp4" else 1.0) if backward == "fp4" else 1.0,
                spfw=sp["spfw"], sptr=sp["sptr"])
        ns = np.logspace(8, 11.5, 24)  # 100M .. 300B params
        rs = np.logspace(1, 3.3, 24)  # D/N 10 .. 2000
        return optimality_region(law, methods, ns, rs), ns, rs

    r8, ns, rs = region("fp8")
    r4, _, _ = region("fp4")
    f8 = float((r8 == "fp4").mean())
    f4 = float((r4 == "fp4").mean())
    rows.append(("fig1b/fp4_region_frac_fp8bwd", 0.0, f"{f8:.3f}"))
    rows.append(("fig1c/fp4_region_frac_fp4bwd", 0.0, f"{f4:.3f}"))
    rows.append(("fig1c/region_grows_with_fp4_bwd", 0.0,
                 "PASS" if f4 > f8 else "FAIL"))
    # Llama-3-8B-class point: N=8e9, D/N=1875 — paper notes such models fall
    # in the FP4-optimal regime
    i = int(np.argmin(abs(ns - 8e9)))
    j = len(rs) - 1
    rows.append(("fig1c/llama3_8b_class_point", 0.0,
                 f"optimal={r4[i, j]} at N=8e9, D/N~2000"))
    return rows
