"""Figure 2 reproduction: backward quantization vs gradient quality.

(a) cosine similarity and (b) magnitude alignment (the PMA quantity) of
inter-layer activation gradients as a function of back-propagation depth,
for RTN vs SR backward quantization, against the unquantized-backward
reference — on a small Llama stack, exactly the paper's probe.
(c) the training-dynamics consequence: RTN-backward is competitive early,
SR-backward wins as the token budget grows (the paper's D/N inflection).

Paper's qualitative claims under test: RTN keeps higher cosine similarity;
SR keeps magnitude alignment ≈ 1 (unbiased); with depth both effects
compound; longer training favors SR.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.llama_paper import tiny_llama
from repro.core.quartet import QuartetConfig
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
from repro.models import build_model
from repro.models.transformer import dense_block, init_dense_block
from repro.optim import adamw, cosine_warmup
from repro.train.loop import train

DEPTH = 6


def _per_depth_alignment():
    """Inter-layer activation gradients for all depths in ONE backward per
    scheme, via ε-taps: x ← layer(x) + ε_d ⇒ ∂L/∂ε_d is the boundary grad."""
    cfg = tiny_llama(d=96, layers=DEPTH, vocab=512)
    cfg = dataclasses.replace(cfg, remat=False, dtype="float32")
    key = jax.random.PRNGKey(0)
    dtype = jnp.float32
    layers = [init_dense_block(k, cfg, dtype)
              for k in jax.random.split(key, DEPTH)]
    B, S = 2, 64
    x0 = jax.random.normal(key, (B, S, cfg.d_model), dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    eps0 = [jnp.zeros_like(x0) for _ in range(DEPTH)]

    def grads_for(qcfg):
        c = dataclasses.replace(cfg, quartet=qcfg)

        def loss(eps):
            x = x0
            for d, lp in enumerate(layers):
                x, _, _ = dense_block(lp, x + eps[d], pos, jnp.uint32(7), c,
                                      None, None, "quartet")
            return jnp.sum(x.astype(jnp.float32) ** 2)

        return jax.jit(jax.grad(loss))(eps0)

    grads = {
        "reference": grads_for(QuartetConfig(bwd_rounding="none",
                                             bwd_hadamard="none")),
        "rtn": grads_for(QuartetConfig(bwd_rounding="rtn",
                                       bwd_hadamard="random")),
        "sr": grads_for(QuartetConfig()),
    }

    rows = []
    stats = {}
    for name in ("rtn", "sr"):
        cos, mag = [], []
        for d in range(DEPTH):
            g, r = grads[name][d], grads["reference"][d]
            cos.append(float(jnp.vdot(g, r) /
                             (jnp.linalg.norm(g) * jnp.linalg.norm(r))))
            mag.append(float(jnp.vdot(g, r) / jnp.vdot(r, r)))
        stats[name] = (cos, mag)
        # index 0 = deepest (most backprop steps accumulated)
        rows.append((f"fig2a/{name}/cosine_by_depth", 0.0,
                     " ".join(f"{c:.3f}" for c in cos)))
        rows.append((f"fig2b/{name}/magnitude_by_depth", 0.0,
                     " ".join(f"{m:.3f}" for m in mag)))
    rtn_cos, sr_cos = stats["rtn"][0][0], stats["sr"][0][0]
    sr_mag = stats["sr"][1][0]
    rows.append(("fig2/rtn_cosine>=sr_cosine_at_depth", 0.0,
                 f"rtn={rtn_cos:.3f} sr={sr_cos:.3f} "
                 f"{'PASS' if rtn_cos >= sr_cos - 0.02 else 'FAIL'}"))
    rows.append(("fig2/sr_magnitude_near_1_at_depth", 0.0,
                 f"{sr_mag:.3f} {'PASS' if abs(sr_mag - 1) < 0.15 else 'FAIL'}"))
    return rows


def _training_consequence():
    """Fig. 2(c): loss gap vs reference for RTN- vs SR-backward training."""
    rows = []
    cfg = tiny_llama(d=64, layers=2, vocab=512)
    ds = SyntheticC4Dataset(vocab_size=512, seed=3)
    finals = {}
    for name, qc in [("sr", QuartetConfig()),
                     ("rtn", QuartetConfig(bwd_rounding="rtn"))]:
        c = dataclasses.replace(cfg, quartet=qc)
        model = build_model(c)
        for steps in (120, 360):
            b = TokenBatcher(ds, 8, 64, seed=1)
            opt = adamw(cosine_warmup(2e-3, steps), weight_decay=0.0)
            t0 = time.perf_counter()
            _, hist = train(model, opt, b, steps, log_every=0)
            us = (time.perf_counter() - t0) * 1e6 / steps
            final = float(np.mean([h["loss"] for h in hist[-8:]]))
            finals[(name, steps)] = final
            rows.append((f"fig2c/{name}_bwd/steps{steps}", us, f"loss={final:.4f}"))
    gap_short = finals[("sr", 120)] - finals[("rtn", 120)]
    gap_long = finals[("sr", 360)] - finals[("rtn", 360)]
    rows.append(("fig2c/sr_gains_with_budget", 0.0,
                 f"gap(sr-rtn) {gap_short:+.4f} @120 -> {gap_long:+.4f} @360 "
                 f"(paper: SR overtakes at large D/N)"))
    return rows


def run() -> list[tuple]:
    return _per_depth_alignment() + _training_consequence()
