"""§Roofline report: reads reports/dryrun.json and emits the per-(arch ×
shape × mesh) three-term table (+ markdown for EXPERIMENTS.md)."""

from __future__ import annotations

import json
import os


def load(path="reports/dryrun.json"):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def run(path="reports/dryrun.json") -> list[tuple]:
    rows = []
    for r in load(path):
        if r.get("status") != "ok":
            rows.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                         f"FAILED: {r.get('error', '?')[:80]}"))
            continue
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        derived = (f"dom={r['dominant']} comp={r['compute_s'] * 1e3:.1f}ms "
                   f"mem={r['memory_s'] * 1e3:.1f}ms coll={r['collective_s'] * 1e3:.1f}ms "
                   f"useful={r.get('useful_fraction', 0):.3f} "
                   f"temp={r['bytes_per_device']['temp'] / 2**30:.1f}GiB")
        rows.append((name, r.get("compile_s", 0) * 1e6, derived))
    return rows


def markdown(path="reports/dryrun.json") -> str:
    out = ["| arch | shape | mesh | mb | compute | memory* | collective | dominant | useful | temp GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load(path):
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | FAIL | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('microbatch', 1)} "
            f"| {r['compute_s'] * 1e3:.1f}ms | {r['memory_s'] * 1e3:.0f}ms "
            f"| {r['collective_s'] * 1e3:.1f}ms | {r['dominant']} "
            f"| {r.get('useful_fraction', 0):.2f} "
            f"| {r['bytes_per_device']['temp'] / 2**30:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(markdown())
