"""Table 3 (scaled-down): fully-quantized training method comparison.

The paper pre-trains 30M-param Llamas on C4 at D/N ∈ {25..800} per method.
On the CPU container we reproduce the *comparison* at tiny scale: identical
~0.3M-param Llamas on the synthetic C4 stand-in, one per method, at two
token budgets; the claim under test is the ordering — Quartet lowest loss,
LUQ-INT4 the strongest prior, Jetfire/HALO-FP4 degraded — not the absolute
values.  ``FULL=1`` env extends the budgets toward real D/N ratios.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.configs.llama_paper import tiny_llama
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
from repro.models import build_model
from repro.optim import adamw, cosine_warmup
from repro.train.loop import train

METHODS = ["bf16", "quartet", "luq_int4", "luq_fp4", "jetfire_fp4",
           "halo_fp4", "lss_int4"]


def run() -> list[tuple]:
    full = bool(int(os.environ.get("FULL", "0")))
    steps_grid = [150, 300] if not full else [300, 1200, 4800]
    cfg = tiny_llama(d=64, layers=2, vocab=512)
    model = build_model(cfg)
    ds = SyntheticC4Dataset(vocab_size=cfg.vocab_size, seed=7)

    rows = []
    finals: dict[str, list[float]] = {}
    for method in METHODS:
        finals[method] = []
        for steps in steps_grid:
            batcher = TokenBatcher(ds, global_batch=8, seq_len=64, seed=1)
            opt = adamw(cosine_warmup(2e-3, steps), weight_decay=0.0)
            t0 = time.perf_counter()
            try:
                _, hist = train(model, opt, batcher, steps, method=method,
                                log_every=0)
                losses = [h["loss"] for h in hist[-10:]]
                final = float(np.mean(losses))
                if not np.isfinite(final):
                    final = float("nan")
            except FloatingPointError:
                final = float("nan")
            us = (time.perf_counter() - t0) * 1e6 / max(steps, 1)
            finals[method].append(final)
            rows.append((f"table3/{method}/steps{steps}", us, f"loss={final:.4f}"))

    # ordering checks at the largest budget (paper's qualitative claims)
    last = {m: finals[m][-1] for m in METHODS}
    q, bf = last["quartet"], last["bf16"]
    prior_best = np.nanmin([last[m] for m in METHODS if m not in ("quartet", "bf16")])
    rows.append(("table3/quartet_beats_all_4bit_priors", 0.0,
                 "PASS" if q < prior_best else f"FAIL q={q:.3f} prior={prior_best:.3f}"))
    rows.append(("table3/quartet_near_bf16", 0.0,
                 f"gap={q - bf:+.4f} (paper: near-lossless at high D/N)"))
    return rows
