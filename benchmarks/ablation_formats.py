"""Format ablation (paper §6 future work): Quartet with alternative
hardware formats — NVFP4 (E2M1, block-16, E4M3 scales), MXFP8 (E4M3,
block-32, E8M0 scales) — vs the paper's MXFP4.

Reports forward quantization MSE (the effN proxy of §4.1/Table 2) and a
fixed-budget tiny-train loss per format.  Expected: MSE mxfp8 ≪ nvfp4 <
mxfp4 (finer scales / more bits), with train losses ordered accordingly and
MXFP4 still close — the paper's headline that 4 bits suffice.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.llama_paper import tiny_llama
from repro.core import formats as F
from repro.core import quantizers as Q
from repro.core.quartet import QuartetConfig
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
from repro.models import build_model
from repro.optim import adamw, cosine_warmup
from repro.train.loop import train

VARIANTS = {
    "mxfp4": QuartetConfig(),  # the paper
    "nvfp4": QuartetConfig(fwd_format="nvfp4", bwd_format="nvfp4", group=16),
    "mxfp8": QuartetConfig(fwd_format="mxfp8", bwd_format="mxfp8"),
}


def run() -> list[tuple]:
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (2048, 64))
    mses = {}
    for name, qc in VARIANTS.items():
        fmt = qc.fwd_fmt
        r = Q.quest(x, fmt)
        mse = float(jnp.mean((r.values - x) ** 2) / jnp.mean(x**2))
        mses[name] = mse
        rows.append((f"ablation/{name}/fwd_mse", 0.0, f"{mse:.3e}"))
    rows.append(("ablation/mse_ordering_fp8<fp4", 0.0,
                 "PASS" if mses["mxfp8"] < min(mses["mxfp4"], mses["nvfp4"])
                 else "FAIL"))

    steps = 150
    cfg0 = tiny_llama(d=64, layers=2, vocab=512)
    ds = SyntheticC4Dataset(vocab_size=512, seed=5)
    finals = {}
    for name, qc in VARIANTS.items():
        cfg = dataclasses.replace(cfg0, quartet=qc)
        model = build_model(cfg)
        b = TokenBatcher(ds, 8, 64, seed=1)
        opt = adamw(cosine_warmup(2e-3, steps), weight_decay=0.0)
        t0 = time.perf_counter()
        _, hist = train(model, opt, b, steps, log_every=0)
        us = (time.perf_counter() - t0) * 1e6 / steps
        finals[name] = float(np.mean([h["loss"] for h in hist[-8:]]))
        rows.append((f"ablation/{name}/train_loss", us, f"{finals[name]:.4f}"))
    gap = finals["mxfp4"] - finals["mxfp8"]
    rows.append(("ablation/mxfp4_close_to_mxfp8", 0.0,
                 f"gap={gap:+.4f} (paper: 4-bit ≈ 8-bit with Quartet)"))
    return rows
