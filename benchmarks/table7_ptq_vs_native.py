"""Table 7 (scaled-down): post-training quantization vs native Quartet.

Paper: QuaRot-PTQ of a BF16-trained 7B scores 18.19 PPL vs Quartet-native
17.77 (BF16 16.40) on C4.  Scaled reproduction: train one tiny Llama in BF16
and one with Quartet natively (same tokens); PTQ the BF16 model with the
QuaRot-style transform (fixed Hadamard + MXFP4 RTN of weights & activations =
our QuEST forward without the trained adaptation); compare eval losses.
Claim under test: native Quartet < PTQ, both within reach of BF16.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs.llama_paper import tiny_llama
from repro.core.quartet import QuartetConfig
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
from repro.models import build_model
from repro.optim import adamw, cosine_warmup
from repro.train.loop import evaluate, train


def run() -> list[tuple]:
    steps = 300
    cfg = tiny_llama(d=64, layers=2, vocab=512)
    ds = SyntheticC4Dataset(vocab_size=cfg.vocab_size, seed=11)
    rows = []

    def train_one(method, cfg_):
        model = build_model(cfg_)
        batcher = TokenBatcher(ds, global_batch=8, seq_len=64, seed=2)
        opt = adamw(cosine_warmup(2e-3, steps), weight_decay=0.0)
        state, hist = train(model, opt, batcher, steps, method=method, log_every=0)
        ev = TokenBatcher(ds, global_batch=8, seq_len=64, seed=99)
        return model, state, evaluate(model, state, ev, 4, method=method)

    t0 = time.perf_counter()
    model_bf, state_bf, loss_bf = train_one("bf16", cfg)
    rows.append(("table7/bf16_eval", (time.perf_counter() - t0) * 1e6,
                 f"loss={loss_bf:.4f} (paper ppl 16.40)"))

    # PTQ: evaluate the BF16-trained weights through the quantized forward
    # (fixed Hadamard + MXFP4, QuaRot-style) — no adaptation
    t0 = time.perf_counter()
    ev = TokenBatcher(ds, global_batch=8, seq_len=64, seed=99)
    loss_ptq = evaluate(model_bf, state_bf, ev, 4, method="quartet")
    rows.append(("table7/ptq_quarot_eval", (time.perf_counter() - t0) * 1e6,
                 f"loss={loss_ptq:.4f} (paper ppl 18.19)"))

    t0 = time.perf_counter()
    _, _, loss_q = train_one("quartet", cfg)
    rows.append(("table7/quartet_native_eval", (time.perf_counter() - t0) * 1e6,
                 f"loss={loss_q:.4f} (paper ppl 17.77)"))

    ok = loss_q < loss_ptq
    rows.append(("table7/native_beats_ptq", 0.0,
                 "PASS" if ok else f"FAIL q={loss_q:.4f} ptq={loss_ptq:.4f}"))
    return rows
