"""Figure 3 analogue: linear-layer speedup model + kernel sanity timings.

The paper measures CUDA wall-clock on an RTX 5090.  Without FP4 silicon we
report the same quantity from a calibrated cost model over the *exact op
sequence our kernels execute*, per Llama-7B layer shape (as Fig. 3):

  t(layer) = max(flops/peak(format), bytes/HBM_bw) + quant-stage overhead

with Blackwell-class ratios (FP4 = 2× FP8 = 4× BF16 peak) and the real bytes
our Stage-1/Stage-2 kernels move (4-bit payload + 8-bit scales + masks).
Expected from the paper: fwd ≈ 2.4×/4× vs FP8/BF16 at large shapes, training
≈ 1.8×/2.6×.  CPU interpret-mode wall times are also printed per kernel —
correctness-path timings, not performance claims.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# Llama-7B linear shapes (d=4096, ffn=11008), batch 64 × seq 512 (paper Fig 3)
SHAPES = {
    "qkv_proj": (32768, 4096, 4096),
    "ffn_up": (32768, 4096, 11008),
    "ffn_down": (32768, 11008, 4096),
}

PEAK_BF16 = 1.0  # normalized
PEAK_FP8 = 2.0
PEAK_FP4 = 4.0
HBM = 1.0  # bytes/s normalized so that flops/byte balance ≈ B200 (~140)
RIDGE = 140.0  # flops per byte at the compute/memory roofline ridge


def _t_gemm(m, k, n, bits_in, peak):
    flops = 2 * m * k * n
    bytes_ = (m * k + k * n) * bits_in / 8 + m * n * 2  # out bf16
    return max(flops / (peak * RIDGE), bytes_ / HBM)


def _t_quant(m, k, bits_out):
    # Stage-1: read bf16, write 4-bit codes + e8m0 scales (1/32) + mask bits
    return (m * k * 2 + m * k * (bits_out / 8 + 1 / 32 + 1 / 8)) / HBM


def model_times(m, k, n):
    out = {}
    # BF16: one GEMM, no quant
    out["bf16"] = _t_gemm(m, k, n, 16, PEAK_BF16)
    # FP8: per-tensor cast fwd (cheap) + GEMM
    out["fp8"] = _t_gemm(m, k, n, 8, PEAK_FP8) + _t_quant(m, k, 8) + _t_quant(k, n, 8)
    # Quartet MXFP4: fused Hadamard+QuEST quant both operands + FP4 GEMM
    out["quartet_fp4"] = (_t_gemm(m, k, n, 4, PEAK_FP4)
                          + _t_quant(m, k, 4) + _t_quant(k, n, 4))
    return out


def run() -> list[tuple]:
    rows = []
    fwd_speedups_fp8, fwd_speedups_bf16 = [], []
    for name, (m, k, n) in SHAPES.items():
        t = model_times(m, k, n)
        s8 = t["fp8"] / t["quartet_fp4"]
        s16 = t["bf16"] / t["quartet_fp4"]
        fwd_speedups_fp8.append(s8)
        fwd_speedups_bf16.append(s16)
        rows.append((f"fig3/fwd/{name}", 0.0,
                     f"vs_fp8={s8:.2f}x vs_bf16={s16:.2f}x (paper: up to 2.4x/4x)"))
    # backward: 2 GEMMs + 4 SR-quantizations + inverse Hadamards (bf16 IO)
    for name, (m, k, n) in SHAPES.items():
        def t_bwd(fmt_bits, peak, extra_quants):
            t = (_t_gemm(m, n, k, fmt_bits, peak) + _t_gemm(k, m, n, fmt_bits, peak)
                 + extra_quants)
            return t
        tb16 = t_bwd(16, PEAK_BF16, 0)
        tb8 = t_bwd(8, PEAK_FP8, _t_quant(m, n, 8) * 2)
        tb4 = t_bwd(4, PEAK_FP4, (_t_quant(m, n, 4) + _t_quant(k, n, 4)
                                  + _t_quant(k, m, 4) + _t_quant(n, m, 4)))
        rows.append((f"fig3/bwd/{name}", 0.0,
                     f"vs_fp8={tb8 / tb4:.2f}x vs_bf16={tb16 / tb4:.2f}x "
                     f"(paper: up to 1.6x/2.3x)"))

    # CPU interpret-mode kernel wall times (correctness path, not perf)
    from repro.kernels.hadamard_quant import hadamard_quest_quantize
    from repro.kernels.mxfp4_matmul import mxfp4_matmul
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512))
    c, s, msk = hadamard_quest_quantize(x)  # compile
    jax.block_until_ready(c)
    t0 = time.perf_counter()
    for _ in range(5):
        c, s, msk = hadamard_quest_quantize(x)
    jax.block_until_ready(c)
    rows.append(("fig3/kernel_hadamard_quant_interp", (time.perf_counter() - t0) / 5 * 1e6,
                 "cpu-interpret"))
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
    cw, sw, _ = hadamard_quest_quantize(w.T)
    y = mxfp4_matmul(c, s, cw.T, sw.T)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(5):
        y = mxfp4_matmul(c, s, cw.T, sw.T)
    jax.block_until_ready(y)
    rows.append(("fig3/kernel_mxfp4_matmul_interp", (time.perf_counter() - t0) / 5 * 1e6,
                 "cpu-interpret"))

    # Paged-attention decode (serving): the step is HBM-bound, so the model
    # speedup is the KV-bytes ratio of the legacy gather-dequantize path
    # (read packed + write dense + read dense) over the fused kernel (read
    # packed pages in place) — llama-7B-class GQA shape (hd=128, 8 KV heads).
    hd, hkv = 128, 8
    packed = 2 * hkv * (hd // 2 + hd // 32)  # 4.25-bit K+V payload per token
    dense = 2 * hkv * hd * 2  # bf16 K+V per token
    rows.append(("fig3/decode_paged_vs_gather_bytes", 0.0,
                 f"{(packed + 2 * dense) / packed:.2f}x fewer KV bytes/step "
                 f"(packed {packed}B vs gather {packed + 2 * dense}B per tok)"))

    # CPU interpret-mode wall time for the fused paged-attention kernel
    from repro.kernels.paged_attention import paged_attention, quant_block

    B, hq, ps, n_pp = 4, 2 * hkv, 16, 4
    n_pages = 1 + B * n_pp
    nb = hd // quant_block(hd)
    pool = {
        "k_codes": jnp.zeros((n_pages, ps, hkv, hd // 2), jnp.uint8),
        "k_scales": jnp.full((n_pages, ps, hkv, nb), 127, jnp.uint8),
        "v_codes": jnp.zeros((n_pages, ps, hkv, hd // 2), jnp.uint8),
        "v_scales": jnp.full((n_pages, ps, hkv, nb), 127, jnp.uint8),
    }
    tables = jnp.arange(1, 1 + B * n_pp, dtype=jnp.int32).reshape(B, n_pp)
    lengths = jnp.full((B,), ps * n_pp, jnp.int32)
    qd = jax.random.normal(jax.random.PRNGKey(2), (B, hq, hd))
    o = paged_attention(qd, pool, tables, lengths)
    jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(5):
        o = paged_attention(qd, pool, tables, lengths)
    jax.block_until_ready(o)
    rows.append(("fig3/kernel_paged_attention_interp",
                 (time.perf_counter() - t0) / 5 * 1e6, "cpu-interpret"))
    return rows
