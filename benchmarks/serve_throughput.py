"""Serving benchmark: continuous batching + paged FP4 KV cache.

``PYTHONPATH=src python benchmarks/serve_throughput.py --reduced`` runs a
fixed-seed mixed-length workload through the engine twice (dense-cache and
MXFP4-cache modes) and prints a JSON report:

* tokens/sec (decode throughput, wall clock, post-warmup),
* p50/p95 request latency and TTFT on the virtual serving clock,
* persistent cache bytes dense vs FP4 and their ratio,
* a parity check — dense-cache engine outputs must equal sequential
  ``greedy_generate`` token-for-token for every request.

``run()`` adapts the same numbers to the ``benchmarks.run`` CSV driver.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _build(arch: str, reduced: bool):
    from repro.configs import get_config, get_reduced_config
    from repro.models import build_model

    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    for _ in range(n_requests):
        t += rng.exponential(0.25)
        plen = int(rng.integers(6, 28))
        out.append((t, rng.integers(0, cfg.vocab_size, plen).astype(np.int32), max_new))
    return out


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def bench(arch: str = "qwen3-1.7b", reduced: bool = True, n_requests: int = 8,
          max_new: int = 8, n_slots: int = 4, verify_parity: bool = True) -> dict:
    from repro.launch.serve_engine import run_workload
    from repro.serve import Engine, EngineConfig
    from repro.train.serve import greedy_generate

    cfg, model, params = _build(arch, reduced)
    workload = _workload(cfg, n_requests, max_new)
    report: dict = {"arch": cfg.name, "family": cfg.family,
                    "n_requests": n_requests, "max_new": max_new,
                    "n_slots": n_slots}

    outputs = {}
    for kv in ("dense", "mxfp4"):
        eng = Engine(model, params, EngineConfig(
            n_slots=n_slots, max_len=64, page_size=16, kv_dtype=kv,
            prefill_chunk=16))
        # warmup: compile the three step shapes outside the timed region
        eng.submit(workload[0][1], 2, arrival_time=0.0)
        eng.drain()
        eng.completed.clear()

        t0 = time.perf_counter()
        done, _ = run_workload(eng, workload, verbose=False)
        wall = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in done)
        outputs[kv] = {r.rid: list(r.tokens) for r in done}
        report[kv] = {
            "tokens_per_sec": round(toks / wall, 2),
            "wall_sec": round(wall, 3),
            "latency_p50_s": round(_pct([r.latency() for r in done], 0.5), 4),
            "latency_p95_s": round(_pct([r.latency() for r in done], 0.95), 4),
            "ttft_p50_s": round(_pct([r.ttft() for r in done], 0.5), 4),
            "ttft_p95_s": round(_pct([r.ttft() for r in done], 0.95), 4),
            "cache_bytes": eng.cache_bytes(),
            "bits_per_kv_elem": round(eng.cache.bits_per_element(), 2)
            if eng.paged else 16.0,
        }

    report["cache_ratio"] = round(
        report["dense"]["cache_bytes"] / report["mxfp4"]["cache_bytes"], 2)

    if verify_parity:
        ref_toks = []
        for _, prompt, mn in workload:
            ref = greedy_generate(model, params, jnp.asarray(prompt)[None],
                                  max_new=mn, max_len=int(prompt.size) + mn)
            ref_toks.append(ref[0].tolist())
        # rids are assigned in submission (arrival) order; the warmup request
        # is cleared, so sorted rids map 1:1 onto the workload — minus the
        # warmup's rid 0 offset
        eng_toks = [outputs["dense"][rid] for rid in sorted(outputs["dense"])]
        report["parity_dense_vs_sequential"] = eng_toks == ref_toks

    return report


def run():
    """benchmarks.run driver hook → (name, us_per_call, derived) rows."""
    rep = bench()
    us = rep["mxfp4"]["wall_sec"] * 1e6 / max(rep["n_requests"] * rep["max_new"], 1)
    return [
        ("serve_fp4_tok_per_s", us, f"{rep['mxfp4']['tokens_per_sec']}tok/s"),
        ("serve_dense_tok_per_s",
         rep["dense"]["wall_sec"] * 1e6 / max(rep["n_requests"] * rep["max_new"], 1),
         f"{rep['dense']['tokens_per_sec']}tok/s"),
        ("serve_cache_ratio", 0.0, f"{rep['cache_ratio']}x"),
        ("serve_parity", 0.0, str(rep.get("parity_dense_vs_sequential", "skipped"))),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--no-parity", action="store_true")
    args = ap.parse_args()
    rep = bench(args.arch, args.reduced, args.requests, args.max_new,
                args.slots, verify_parity=not args.no_parity)
    print(json.dumps(rep, indent=2))
    if rep.get("parity_dense_vs_sequential") is False:
        raise SystemExit("PARITY FAILURE: dense-cache engine != sequential greedy")
    if rep["cache_ratio"] < 3.0:
        raise SystemExit(f"cache ratio {rep['cache_ratio']} < 3x")


if __name__ == "__main__":
    main()
