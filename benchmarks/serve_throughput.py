"""Serving benchmark: continuous batching + paged FP4 KV cache.

``PYTHONPATH=src python benchmarks/serve_throughput.py --reduced`` runs a
fixed-seed mixed-length workload through the engine in four configurations —
{dense, mxfp4 cache} × {paged-kernel, gather-dense decode} — and prints a
JSON report:

* tokens/sec (decode throughput, wall clock, post-warmup) per configuration,
* p50/p95 request latency and TTFT on the virtual serving clock,
* tokens-per-decode-call and draft acceptance rate per configuration (plain
  decoding sits at exactly 1.0 token/call; speculative decoding amortizes
  each verify call over 1..k+1 emitted tokens),
* a speculative on/off A/B (``spec`` section): greedy self-speculation over
  the paged-kernel decode, dense + mxfp4 pools, with token-exactness vs the
  non-speculative engine asserted,
* a prefill A/B (``prefill`` section): a concurrent-arrival burst of
  prefill-dominated requests (max_new=1) through the batched paged prefill
  (ONE jitted call advances every prefilling slot per tick) vs the per-slot
  gather oracle — prompt tokens/sec, mean + p95 TTFT, and per-chunk KV
  bytes; batched paged prefill must stay token-exact vs the oracle,
* a shared-prefix A/B (``prefix_cache`` section, ``--shared-prefix`` /
  ``--smoke``): N users × one system prompt through the radix prefix cache
  (warm) vs the non-sharing engine (cold) — prefix hit rate, shared tokens,
  COW pages, prefill tok/s and mean/p95 TTFT cold-vs-warm, with warm-vs-cold
  token parity and pool page-conservation (no leaks) asserted,
* a state-pool family A/B (``families`` section, ``--family ARCH``,
  repeatable / ``--smoke``): each non-attention arch (ssm / hybrid /
  enc-dec / VLM) through the unified StatePool engine vs the dense-slot
  oracle — token parity (dense planes, asserted exact), pooled vs oracle
  decode tok/s, and per-decode-step state-byte traffic of the packed
  planes vs the oracle's dense per-slot caches,
* a multi-device A/B (``sharding`` section, ``--tp`` / ``--dp``): the
  TP-sharded engine (packed pool + paged-attention grid sharded over KV
  heads on the ``model`` mesh axis) and the DP-replicated engine
  (independent replicas on disjoint device groups) vs the single-device
  engine — token parity asserted, per-shard pool bytes, TTFT/TPOT deltas,
  per-replica and aggregate decode tok/s; null when ``tp == dp == 1`` or
  the process sees too few devices,
* persistent cache bytes dense vs FP4 and their ratio,
* decode-step HBM traffic model: KV bytes touched per batched decode step by
  the fused paged-attention kernel (O(packed KV): read the packed pages in
  place) vs the legacy gather-dequantize oracle (read packed + write dense +
  read dense), and their ratio — and the same model per prefill chunk,
* parity checks — dense-cache engine outputs must equal sequential
  ``greedy_generate`` token-for-token, and the paged-kernel decode must equal
  the gather-dense decode token-for-token in dense-cache mode.

CPU wall-clock caveat: the paged kernel runs in Pallas *interpret* mode here,
so its tok/s is a correctness-path number; the bytes model is the hardware
claim (the kernel's blocking moves 4.25-bit payload instead of bf16 KV).

Latency / pool / quantization-health numbers come from the engine's own
telemetry (``repro.serve.telemetry``): the benchmark enables a metrics
registry + tracer per configuration, resets it after warmup, and reads
TTFT/TPOT/queue-wait percentiles, tick wall-times, pool occupancy peaks and
kv_pack clip/scale gauges out of the final snapshot — it no longer re-derives
them from request objects.  ``--metrics-out`` streams the registry snapshots
of the primary (mxfp4/paged) run as JSON-lines.

The report is also persisted as a schema-versioned baseline:
``BENCH_serve.json`` at the repo root (``telemetry.schema.BENCH_SCHEMA``),
validated before writing, so the perf trajectory is tracked across PRs.

``run()`` adapts the same numbers to the ``benchmarks.run`` CSV driver.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_PATH = REPO_ROOT / "BENCH_serve.json"


def _build(arch: str, reduced: bool):
    from repro.configs import get_config, get_reduced_config
    from repro.models import build_model

    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    for _ in range(n_requests):
        t += rng.exponential(0.25)
        plen = int(rng.integers(6, 28))
        out.append((t, rng.integers(0, cfg.vocab_size, plen).astype(np.int32), max_new))
    return out


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def decode_kv_bytes_per_step(cache, backend: str) -> int:
    """KV bytes touched by one batched decode step (model, not measurement).

    Both paths see every slot's full page table (T = pages_per_slot·page_size
    positions per slot, all L layers).  The paged kernel streams the packed
    pages once; the gather oracle reads the packed pool, writes the dense
    [L, B, T, Hkv, hd] view, then attention reads it back.  Per-token scatter
    writes (4.25-bit payload for one token) are negligible and omitted.
    """
    hd, H, L = cache.head_dim, cache.kv_heads, cache.layers
    tokens = cache.n_slots * cache.pages_per_slot * cache.page_size
    if cache.kv_dtype == "dense":
        packed_per_tok = 2 * H * hd * jnp.dtype(cache._dtype).itemsize
    else:
        nb = cache.pool["k_scales"].shape[-1]  # scale bytes per head per token
        packed_per_tok = 2 * H * (hd // 2 + nb)
    packed = L * tokens * packed_per_tok
    if backend == "paged":
        return packed
    dense = L * tokens * 2 * H * hd * jnp.dtype(cache._dtype).itemsize
    return packed + 2 * dense  # read packed + write dense + read dense


def prefill_kv_bytes_per_chunk(cache, backend: str) -> int:
    """KV bytes touched per prefilling slot per chunk (model, not measurement).

    Prefill sweeps one slot's page table per chunk exactly as decode sweeps
    every slot's per step, so this is the decode model divided by the slot
    count (ONE shared byte model — keep any change to it in
    :func:`decode_kv_bytes_per_step`): the batched paged prefill streams the
    slot's packed pages once per chunk, the gather oracle reads the packed
    pages, writes the dense [L, T, Hkv, hd] view, and attention reads it
    back.  Batched prefill therefore moves O(packed KV) per chunk instead of
    O(dense KV), which is what keeps TTFT flat as concurrent arrivals stack
    up.
    """
    return decode_kv_bytes_per_step(cache, backend) // cache.n_slots


def _bench_shared_prefix(model, cfg, params, n_requests: int, n_slots: int) -> dict:
    """Shared-prefix A/B: radix prefix cache on (warm) vs off (cold).

    Every request carries the same ``prefix_len``-token system prompt plus a
    short unique tail (request 0 is the pure prefix, exercising the
    full-match eager-COW path).  A primer request publishes the prefix into
    the warm engine's radix index before the measured t=0 burst, so every
    burst admission aliases the shared pages and prefills only its tail —
    the cold engine re-prefills everything.  max_new=1 keeps the run
    prefill-dominated (TTFT is the whole story).  Both engines run with
    ``debug_cache`` on, and the warm run ends with a leak check: after all
    retires, evicting the whole index must return the pool to fully free
    (scratch page 0 aside).
    """
    from repro.launch.serve_engine import run_workload
    from repro.serve import Engine, EngineConfig

    prng = np.random.default_rng(7)
    page_size, prefix_len, tail_len = 8, 24, 6
    prefix = prng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    burst = []
    for i in range(n_requests):
        if i == 0:
            prompt = prefix.copy()  # pure-prefix request: full match + COW
        else:
            tail = prng.integers(0, cfg.vocab_size, tail_len).astype(np.int32)
            prompt = np.concatenate([prefix, tail])
        burst.append((0.0, prompt, 1))
    primer = np.concatenate(
        [prefix, prng.integers(0, cfg.vocab_size, tail_len).astype(np.int32)])
    prompt_toks = sum(len(p) for _, p, _ in burst)

    rep: dict = {"n_requests": n_requests, "prefix_len": prefix_len,
                 "prompt_tokens": prompt_toks}
    out = {}
    for label, on in (("warm", True), ("cold", False)):
        eng = Engine(model, params, EngineConfig(
            n_slots=n_slots, max_len=64, page_size=page_size, kv_dtype="mxfp4",
            prefill_chunk=page_size, decode_backend="paged",
            prefix_cache=on, debug_cache=True))
        # warmup compiles the step shapes; the primer publishes the shared
        # prefix into the warm engine's radix index — both are dropped from
        # the registry before the measured burst
        eng.submit(prng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32),
                   1, arrival_time=0.0)
        eng.submit(primer, 1, arrival_time=0.0)
        eng.drain()
        # second warmup pass: a pure-prefix request now full-matches the
        # published prefix and eagerly COWs its last page, compiling the
        # copy_page kernel outside the timed region (the cold engine just
        # prefills it — keeps both branches' warmups identical)
        eng.submit(prefix.copy(), 1, arrival_time=0.0)
        eng.drain()
        eng.completed.clear()
        eng.telemetry.reset(eng)
        t0 = time.perf_counter()
        done, _ = run_workload(eng, burst, verbose=False)
        wall = time.perf_counter() - t0
        ttfts = [r.ttft() for r in done]
        rep[label] = {
            "prefill_tok_per_s": round(prompt_toks / wall, 2),
            "wall_sec": round(wall, 3),
            "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4),
            "ttft_p95_s": round(_pct(ttfts, 0.95), 4),
        }
        out[label] = {r.rid: list(r.tokens) for r in done}
        c = eng.telemetry.finalize()["counters"]
        if on:
            rep["hit_rate"] = round(
                c["prefix_hit_requests"] / max(c["prefix_lookups"], 1), 4)
            rep["shared_tokens"] = c["prefix_shared_tokens"]
            rep["cow_pages"] = c["prefix_cow_pages"]
            rep["evicted_pages"] = c["prefix_evicted_pages"]
            # leak check: every request has retired, so the index holds the
            # only remaining references — dropping it must free every page
            eng.cache.check_invariants()
            eng.prefix.evict(eng.cache, eng.cache.n_pages)
            rep["no_leaks"] = bool(
                eng.cache.free_pages == eng.cache.n_pages - 1)
    # the prefix cache must be invisible at the tokens level
    rep["parity_warm_vs_cold"] = out["warm"] == out["cold"]
    return rep


def _bench_families(archs, n_requests: int, max_new: int, n_slots: int,
                    reduced: bool = True) -> dict:
    """State-pool A/B over the non-attention families (``--family``).

    Per arch, the same fixed workload runs through three engines:

    * the ``dense_slots`` oracle (per-slot dense caches) — reference tokens
      and oracle throughput,
    * the state pool with ``kv_dtype="dense"`` — planes hold bit-exact
      values, so its tokens must equal the oracle's (``token_parity``),
    * the state pool with ``kv_dtype="mxfp4"`` — the deployable config:
      pooled throughput plus the per-decode-step state-byte traffic of the
      packed planes vs the oracle's dense per-slot caches
      (``state_bytes_ratio``, the FP4 bytes win for recurrent state).

    Keys are arch slugs (``falcon_mamba_7b``); the dict fills the schema-v5
    nullable ``families`` block.
    """
    from repro.launch.serve_engine import make_extra, run_workload
    from repro.serve import Engine, EngineConfig

    out: dict = {}
    for arch in archs:
        cfg, model, params = _build(arch, reduced)
        extra = make_extra(cfg, jax.random.PRNGKey(2))
        workload = _workload(cfg, n_requests, max_new, seed=5)

        def run_one(kv, backend):
            eng = Engine(model, params, EngineConfig(
                n_slots=n_slots, max_len=64, page_size=8, kv_dtype=kv,
                prefill_chunk=8, decode_backend=backend, debug_cache=True))
            eng.submit(workload[0][1], 2, extra=extra, arrival_time=0.0)
            eng.drain()
            eng.completed.clear()
            eng.telemetry.reset(eng)
            t0 = time.perf_counter()
            done, _ = run_workload(eng, workload, extra=extra, verbose=False)
            wall = time.perf_counter() - t0
            toks = sum(len(r.tokens) for r in done)
            return eng, {r.rid: list(r.tokens) for r in done}, toks / wall

        oracle, o_out, o_rate = run_one("dense", "dense_slots")
        _, p_out, _ = run_one("dense", "statepool")
        pooled, _, p_rate = run_one("mxfp4", "statepool")
        pooled.cache.check_invariants()
        step_pool = pooled.cache.state_bytes_per_decode_step(64)
        step_dense = pooled.cache.dense_state_bytes_per_decode_step(64)
        out[arch.replace("-", "_").replace(".", "_")] = {
            "family": cfg.family,
            "token_parity": float(p_out == o_out),
            "pool_tok_per_s": round(p_rate, 2),
            "oracle_tok_per_s": round(o_rate, 2),
            "state_bytes_per_step_pool": step_pool,
            "state_bytes_per_step_dense": step_dense,
            "state_bytes_ratio": round(step_dense / step_pool, 2),
            "cache_bytes_pool": pooled.cache_bytes(),
            "cache_bytes_dense": oracle.cache_bytes(),
        }
    return out


def _bench_sharded(model, cfg, params, n_requests: int, n_slots: int,
                   tp: int, dp: int) -> dict | None:
    """Multi-device A/B: single-device vs TP-sharded vs DP-replicated.

    The TP engine shards the packed MXFP4 pool (and the paged-attention
    grid) over the KV-head axis; the DP engine runs independent replicas on
    disjoint device groups behind a shared request-id counter.  Both must be
    token-exact vs the single-device engine (sharding is head/expert slices
    + tiled all_gathers, never a cross-shard reduction), so parity is an
    equality check on the sorted-by-rid token lists.  DP aggregate
    throughput is total decode tokens over the critical-path replica's busy
    seconds — replicas tick sequentially on one host here but run
    concurrently in deployment.

    Returns ``None`` (reported as ``sharding: null``) when there is nothing
    to shard (``tp == dp == 1``), the family has no paged pool, or the
    process sees fewer than ``tp * dp`` devices (run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
    """
    from repro.launch.serve_engine import run_workload
    from repro.serve import (EngineConfig, ReplicatedEngine, ShardingConfig,
                             make_engine)

    n_dev = len(jax.devices())
    if cfg.family not in ("dense", "moe") or (tp <= 1 and dp <= 1) \
            or tp * dp > n_dev:
        return None

    prng = np.random.default_rng(11)
    max_new = 8
    burst = [(0.0,
              prng.integers(0, cfg.vocab_size,
                            int(prng.integers(8, 25))).astype(np.int32),
              max_new)
             for _ in range(n_requests)]

    def run_one(sh):
        eng = make_engine(model, params, EngineConfig(
            n_slots=n_slots, max_len=64, page_size=8, kv_dtype="mxfp4",
            prefill_chunk=8, decode_backend="paged", sharding=sh))
        engines = eng.engines if isinstance(eng, ReplicatedEngine) else [eng]
        # warmup: one submit per replica — the placer round-robins exact
        # inventory ties, so every replica compiles its steps untimed
        for _ in engines:
            eng.submit(burst[0][1], 2, arrival_time=0.0)
        eng.drain()
        for e in engines:
            e.completed.clear()
            e.telemetry.reset(e)
        if isinstance(eng, ReplicatedEngine):
            eng.busy_s = [0.0] * len(engines)
        t0 = time.perf_counter()
        done, _ = run_workload(eng, burst, verbose=False)
        wall = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in done)
        out = [list(r.tokens) for r in sorted(done, key=lambda r: r.rid)]
        return eng, engines, out, toks, wall

    def _latency(e):  # p50 TTFT/TPOT from the engine's own tracer
        h = e.telemetry.finalize()["histograms"]
        rnd = lambda v: None if v is None else round(v, 4)
        return rnd(h["ttft_s"].get("p50")), rnd(h["tpot_s"].get("p50"))

    s_eng, _, s_out, s_toks, s_wall = run_one(None)
    s_ttft, s_tpot = _latency(s_eng)
    s_rate = round(s_toks / s_wall, 2)
    rep: dict = {
        "tp": tp, "dp": dp, "devices": n_dev,
        "single": {"decode_tok_per_s": s_rate, "ttft_p50_s": s_ttft,
                   "tpot_p50_s": s_tpot, "wall_sec": round(s_wall, 3)},
        "tp_run": None, "dp_run": None,
    }

    if tp > 1:
        t_eng, _, t_out, t_toks, t_wall = run_one(ShardingConfig(tp=tp, dp=1))
        t_ttft, t_tpot = _latency(t_eng)
        rep["tp_run"] = {
            "decode_tok_per_s": round(t_toks / t_wall, 2),
            "ttft_p50_s": t_ttft,
            "tpot_p50_s": t_tpot,
            "wall_sec": round(t_wall, 3),
            "pool_bytes_per_shard": t_eng.cache_bytes() // tp,
            "parity_vs_single": float(t_out == s_out),
            "ttft_p50_delta_s": None if (t_ttft is None or s_ttft is None)
            else round(t_ttft - s_ttft, 4),
            "tpot_p50_delta_s": None if (t_tpot is None or s_tpot is None)
            else round(t_tpot - s_tpot, 4),
        }

    if dp > 1:
        d_eng, d_engines, d_out, d_toks, d_wall = run_one(
            ShardingConfig(tp=tp, dp=dp))
        busy = [max(b, 1e-9) for b in d_eng.busy_s]
        per_replica = [
            round(sum(len(q.tokens) for q in e.completed) / busy[r], 2)
            for r, e in enumerate(d_engines)]
        agg = round(d_toks / max(busy), 2)
        # DP scaling is measured against ONE identical replica: when the
        # replicas are tp-sharded, that baseline is the tp_run rate (a tp=1
        # baseline would conflate TP per-tick overhead with DP scaling)
        base_rate = rep["tp_run"]["decode_tok_per_s"] if tp > 1 else s_rate
        rep["dp_run"] = {
            "aggregate_decode_tok_per_s": agg,
            "per_replica_tok_per_s": per_replica,
            "busy_s": [round(b, 3) for b in busy],
            "speedup_vs_one_replica": round(agg / max(base_rate, 1e-9), 2),
            "parity_vs_single": float(d_out == s_out),
            "pool_bytes_per_shard": d_eng.cache_bytes() // (tp * dp),
            "wall_sec": round(d_wall, 3),
        }
    return rep


def bench(arch: str = "qwen3-1.7b", reduced: bool = True, n_requests: int = 8,
          max_new: int = 8, n_slots: int = 4, verify_parity: bool = True,
          spec_k: int = 3, spec_proposer: str = "self",
          metrics_out: str | None = None, shared_prefix: bool = True,
          tp: int = 1, dp: int = 1, profile_out: str | None = None,
          family_archs: list[str] | None = None) -> dict:
    from repro.launch.serve_engine import run_workload
    from repro.serve import Engine, EngineConfig, SpecConfig
    from repro.serve.spec import aggregate_stats
    from repro.serve.telemetry import TelemetryConfig
    from repro.train.serve import greedy_generate

    cfg, model, params = _build(arch, reduced)
    workload = _workload(cfg, n_requests, max_new)
    report: dict = {"arch": cfg.name, "family": cfg.family,
                    "n_requests": n_requests, "max_new": max_new,
                    "n_slots": n_slots}

    def run_config(kv, backend, spec=None, primary=False):
        # the primary (mxfp4/paged) configuration streams its registry
        # snapshots and samples pool quantization health every tick; the
        # others keep the in-memory registry only (NullSink).  --profile-out
        # additionally records the primary run's Chrome trace (the per-call
        # cost lowering happens during warmup, outside the timed region)
        tcfg = TelemetryConfig(
            metrics_path=metrics_out if primary else None,
            emit_every_ticks=5 if primary and metrics_out else 0,
            quant_stride=1 if primary else 0,
            profile_trace_path=profile_out if primary else None)
        eng = Engine(model, params, EngineConfig(
            n_slots=n_slots, max_len=64, page_size=16, kv_dtype=kv,
            prefill_chunk=16, decode_backend=backend, spec=spec,
            telemetry=tcfg))
        # warmup: compile the step shapes outside the timed region, then drop
        # the warmup traffic from the registry (schema survives the reset)
        eng.submit(workload[0][1], 2, arrival_time=0.0)
        eng.drain()
        eng.completed.clear()
        eng.telemetry.reset(eng)

        t0 = time.perf_counter()
        done, _ = run_workload(eng, workload, verbose=False)
        wall = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in done)
        agg = aggregate_stats(done)
        snap = eng.telemetry.finalize()
        g = snap["gauges"]

        def rnd(v, nd=4):
            return None if v is None else round(v, nd)

        def hp(name, q, nd=4):  # empty histograms summarize without quantiles
            return rnd(snap["histograms"][name].get(q), nd)

        stats = {
            "tokens_per_sec": round(toks / wall, 2),
            "wall_sec": round(wall, 3),
            # virtual-clock latencies, derived by the request tracer
            "latency_p50_s": hp("request_latency_s", "p50"),
            "latency_p95_s": hp("request_latency_s", "p95"),
            "ttft_p50_s": hp("ttft_s", "p50"),
            "ttft_p95_s": hp("ttft_s", "p95"),
            "tpot_p50_s": hp("tpot_s", "p50"),
            "tpot_p95_s": hp("tpot_s", "p95"),
            "queue_wait_p50_s": hp("queue_wait_s", "p50"),
            # real wall time per tick section
            "decode_tick_p50_s": hp("decode_tick_s", "p50", 6),
            "decode_tick_p95_s": hp("decode_tick_s", "p95", 6),
            "verify_tick_p50_s": hp("verify_tick_s", "p50", 6),
            "prefill_tick_p50_s": hp("prefill_tick_s", "p50", 6),
            # pool pressure over the run
            "pool_occupancy_peak": rnd(g["pool_occupancy_peak"]),
            "free_page_watermark": g["pool_pages_free_watermark"],
            "tokens_per_decode_call": agg["tokens_per_decode_call"],
            "acceptance_rate": agg["acceptance_rate"],
            "cache_bytes": eng.cache_bytes(),
            "bits_per_kv_elem": round(eng.cache.bits_per_element(), 2)
            if eng.paged else 16.0,
            "decode_kv_bytes_per_step":
            decode_kv_bytes_per_step(eng.cache, backend) if eng.paged else 0,
            "prefill_kv_bytes_per_chunk":
            prefill_kv_bytes_per_chunk(eng.cache, backend) if eng.paged else 0,
        }
        if primary:
            # per-phase device cost accounting: AOT-lower the engine's jitted
            # steps AFTER the timed region and pair the HLO FLOPs/bytes with
            # the measured phase wall-time histograms (schema v4 "profile")
            from repro.serve.telemetry.profiling import profile_report
            stats["profile"] = profile_report(eng, snap)
        if primary and snap["counters"]["quant_health_samples"]:
            stats["quant_health"] = {
                "clip_fraction_k": rnd(g["kv_clip_fraction_k"], 6),
                "clip_fraction_v": rnd(g["kv_clip_fraction_v"], 6),
                "zero_fraction_k": rnd(g["kv_zero_fraction_k"], 6),
                "scale_hist_nonzero_bins":
                snap["binned"]["kv_scale_hist_k"]["nonzero_bins"],
                "scale_code_min": snap["binned"]["kv_scale_hist_k"]["bin_min"],
                "scale_code_max": snap["binned"]["kv_scale_hist_k"]["bin_max"],
            }
        return stats, {r.rid: list(r.tokens) for r in done}

    outputs: dict = {}
    report["decode_backends"] = {}
    for kv, backend in (("dense", "paged"), ("dense", "gather"),
                        ("mxfp4", "paged"), ("mxfp4", "gather")):
        stats, outputs[(kv, backend)] = run_config(
            kv, backend, primary=(kv == "mxfp4" and backend == "paged"))
        if kv == "mxfp4" and backend == "paged":
            report["profile"] = stats.pop("profile", None)
        if backend == "paged":  # primary numbers, keyed by cache dtype
            report[kv] = stats
        report["decode_backends"][f"{kv}/{backend}"] = {
            k: stats[k] for k in
            ("tokens_per_sec", "wall_sec", "decode_kv_bytes_per_step",
             "prefill_kv_bytes_per_chunk")}

    # -- speculative on/off A/B (paged-kernel decode, both pool dtypes) -----
    report["spec"] = {"k": spec_k, "proposer": spec_proposer}
    if cfg.family in ("dense", "moe"):
        sc = SpecConfig(k=spec_k, proposer=spec_proposer)
        for kv in ("dense", "mxfp4"):
            stats, out = run_config(kv, "paged", spec=sc)
            stats["parity_vs_nonspec"] = out == outputs[(kv, "paged")]
            report["spec"][kv] = stats

    # -- batched-prefill A/B: concurrent arrival burst, prefill-dominated ----
    # Every request lands at t=0 with max_new=1, so the run is ~all prefill:
    # the batched path advances EVERY prefilling slot in one jitted call per
    # tick (and attends over the packed pool), the gather oracle runs one
    # [1, C] call per slot per tick plus [1, 1] remainder singles.
    if cfg.family in ("dense", "moe"):
        prng = np.random.default_rng(1)
        plens = [int(prng.integers(9, 33)) for _ in range(n_requests)]
        burst = [(0.0, prng.integers(0, cfg.vocab_size, pl).astype(np.int32), 1)
                 for pl in plens]
        prefill_rep: dict = {"n_requests": n_requests,
                             "prompt_tokens": sum(plens)}
        pf_out = {}
        for backend in ("paged", "gather"):
            eng = Engine(model, params, EngineConfig(
                n_slots=n_slots, max_len=64, page_size=16, kv_dtype="dense",
                prefill_chunk=16, decode_backend=backend))
            eng.submit(burst[0][1], 1, arrival_time=0.0)
            eng.drain()
            eng.completed.clear()
            t0 = time.perf_counter()
            done, _ = run_workload(eng, burst, verbose=False)
            wall = time.perf_counter() - t0
            ttfts = [r.ttft() for r in done]
            prefill_rep[backend] = {
                "prefill_tok_per_s": round(sum(plens) / wall, 2),
                "wall_sec": round(wall, 3),
                "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4),
                "ttft_p95_s": round(_pct(ttfts, 0.95), 4),
            }
            pf_out[backend] = {r.rid: list(r.tokens) for r in done}
        # batched paged prefill must reproduce the per-slot gather oracle
        # token-for-token on the dense pool
        prefill_rep["parity_paged_vs_gather"] = pf_out["paged"] == pf_out["gather"]
        db_ = report["decode_backends"]
        pp = db_["mxfp4/paged"]["prefill_kv_bytes_per_chunk"]
        prefill_rep["kv_bytes_per_chunk_mxfp4"] = {
            "paged": pp,
            "gather": db_["mxfp4/gather"]["prefill_kv_bytes_per_chunk"],
            "ratio_gather_over_paged": round(
                db_["mxfp4/gather"]["prefill_kv_bytes_per_chunk"] / pp, 2)
            if pp else None,
        }
        report["prefill"] = prefill_rep

    # -- shared-prefix A/B: radix prefix cache warm vs cold ------------------
    if shared_prefix and cfg.family in ("dense", "moe"):
        report["prefix_cache"] = _bench_shared_prefix(
            model, cfg, params, n_requests, n_slots)

    # -- multi-device A/B: TP-sharded pool/kernels + DP engine replicas ------
    report["sharding"] = _bench_sharded(
        model, cfg, params, n_requests, n_slots, tp, dp)

    # -- state-pool family A/B: pooled serving vs the dense-slot oracle ------
    report["families"] = (
        _bench_families(family_archs, n_requests, max_new, n_slots, reduced)
        if family_archs else None)

    report["cache_ratio"] = round(
        report["dense"]["cache_bytes"] / report["mxfp4"]["cache_bytes"], 2)
    db = report["decode_backends"]
    paged_bytes = db["mxfp4/paged"]["decode_kv_bytes_per_step"]
    report["decode_bytes_ratio_gather_over_paged"] = round(
        db["mxfp4/gather"]["decode_kv_bytes_per_step"] / paged_bytes, 2
    ) if paged_bytes else None  # dense-slot families: no paged decode path
    # the paged kernel must reproduce the gather oracle exactly when the pool
    # stores the compute dtype (same values, same online-softmax math)
    report["parity_paged_vs_gather_dense"] = (
        outputs[("dense", "paged")] == outputs[("dense", "gather")])

    if verify_parity:
        ref_toks = []
        for _, prompt, mn in workload:
            ref = greedy_generate(model, params, jnp.asarray(prompt)[None],
                                  max_new=mn, max_len=int(prompt.size) + mn)
            ref_toks.append(ref[0].tolist())
        # rids are assigned in submission (arrival) order; the warmup request
        # is cleared, so sorted rids map 1:1 onto the workload — minus the
        # warmup's rid 0 offset
        dense_out = outputs[("dense", "paged")]
        eng_toks = [dense_out[rid] for rid in sorted(dense_out)]
        report["parity_dense_vs_sequential"] = eng_toks == ref_toks

    return report


def make_bench_baseline(rep: dict) -> dict:
    """Benchmark report → the schema-versioned ``BENCH_serve.json`` document
    (``telemetry.schema.BENCH_SCHEMA``).  Null-able fields go null on
    dense-slot families / configurations with nothing to measure."""
    from repro.serve.telemetry.schema import BENCH_SCHEMA

    m, d, db = rep["mxfp4"], rep["dense"], rep["decode_backends"]
    sp_m = rep.get("spec", {}).get("mxfp4")
    qh = m.get("quant_health", {})
    pf = rep.get("prefill", {}).get("kv_bytes_per_chunk_mxfp4", {})
    px = rep.get("prefix_cache", {})
    px_w, px_c = px.get("warm", {}), px.get("cold", {})
    return {
        "schema": BENCH_SCHEMA,
        "arch": rep["arch"],
        "family": rep["family"],
        "config": {"n_requests": rep["n_requests"], "max_new": rep["max_new"],
                   "n_slots": rep["n_slots"]},
        "throughput": {
            "mxfp4_paged_tok_per_s": m["tokens_per_sec"],
            "dense_paged_tok_per_s": d["tokens_per_sec"],
            "mxfp4_gather_tok_per_s": db["mxfp4/gather"]["tokens_per_sec"],
        },
        "latency": {
            "ttft_p50_s": m["ttft_p50_s"], "ttft_p95_s": m["ttft_p95_s"],
            "tpot_p50_s": m["tpot_p50_s"], "tpot_p95_s": m["tpot_p95_s"],
            "latency_p50_s": m["latency_p50_s"],
            "latency_p95_s": m["latency_p95_s"],
            "queue_wait_p50_s": m["queue_wait_p50_s"],
        },
        "tick": {
            "decode_p50_s": m["decode_tick_p50_s"],
            "decode_p95_s": m["decode_tick_p95_s"],
            "prefill_p50_s": m["prefill_tick_p50_s"],
        },
        "kv": {
            "cache_bytes_dense": d["cache_bytes"],
            "cache_bytes_mxfp4": m["cache_bytes"],
            "cache_ratio": rep["cache_ratio"],
            "bits_per_elem_mxfp4": m["bits_per_kv_elem"],
            "decode_bytes_ratio_gather_over_paged":
            rep["decode_bytes_ratio_gather_over_paged"],
            "prefill_bytes_ratio_gather_over_paged":
            pf.get("ratio_gather_over_paged"),
        },
        "pool": {
            "occupancy_peak": m["pool_occupancy_peak"] or 0,
            "free_page_watermark": m["free_page_watermark"] or 0,
        },
        "spec": {
            "k": rep["spec"]["k"],
            "proposer": rep["spec"]["proposer"],
            "acceptance_rate": sp_m["acceptance_rate"] if sp_m else None,
            "tokens_per_decode_call":
            sp_m["tokens_per_decode_call"] if sp_m else None,
        },
        "quant_health": {
            "clip_fraction_k": qh.get("clip_fraction_k"),
            "clip_fraction_v": qh.get("clip_fraction_v"),
            "zero_fraction_k": qh.get("zero_fraction_k"),
            "scale_hist_nonzero_bins": qh.get("scale_hist_nonzero_bins"),
            "scale_code_min": qh.get("scale_code_min"),
            "scale_code_max": qh.get("scale_code_max"),
        },
        "prefix": {
            "hit_rate": px.get("hit_rate"),
            "shared_tokens": px.get("shared_tokens"),
            "cow_pages": px.get("cow_pages"),
            "warm_ttft_mean_s": px_w.get("ttft_mean_s"),
            "cold_ttft_mean_s": px_c.get("ttft_mean_s"),
            "warm_ttft_p95_s": px_w.get("ttft_p95_s"),
            "cold_ttft_p95_s": px_c.get("ttft_p95_s"),
            "warm_prefill_tok_per_s": px_w.get("prefill_tok_per_s"),
            "cold_prefill_tok_per_s": px_c.get("prefill_tok_per_s"),
        },
        # null on single-device runs; the dict from _bench_sharded already
        # matches the schema's nullable "sharding" block
        "sharding": rep.get("sharding"),
        # per-phase cost accounting of the primary run (profiling.py) —
        # already shaped like the schema's nullable "profile" block
        "profile": rep.get("profile"),
        # state-pool family A/B (--family); already shaped like the schema's
        # nullable "families" map
        "families": rep.get("families"),
    }


def write_bench(rep: dict, path=BENCH_PATH) -> dict:
    """Validate + persist the baseline; raises before writing anything if
    the document doesn't conform to BENCH_SCHEMA."""
    from repro.serve.telemetry.schema import validate_bench

    doc = make_bench_baseline(rep)
    errors = validate_bench(doc)
    if errors:
        raise ValueError("refusing to write invalid BENCH_serve.json:\n  "
                         + "\n  ".join(errors))
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    return doc


def run():
    """benchmarks.run driver hook → (name, us_per_call, derived) rows.
    Also persists the BENCH_serve.json baseline."""
    rep = bench()
    write_bench(rep)
    per_tok = max(rep["n_requests"] * rep["max_new"], 1)
    db = rep["decode_backends"]
    rows = [
        ("serve_bench_baseline", 0.0, str(BENCH_PATH)),
        ("serve_fp4_tok_per_s", rep["mxfp4"]["wall_sec"] * 1e6 / per_tok,
         f"{rep['mxfp4']['tokens_per_sec']}tok/s"),
        ("serve_dense_tok_per_s", rep["dense"]["wall_sec"] * 1e6 / per_tok,
         f"{rep['dense']['tokens_per_sec']}tok/s"),
        ("serve_gather_decode_tok_per_s",
         db["mxfp4/gather"]["wall_sec"] * 1e6 / per_tok,
         f"{db['mxfp4/gather']['tokens_per_sec']}tok/s"),
        ("serve_cache_ratio", 0.0, f"{rep['cache_ratio']}x"),
        ("serve_decode_bytes_ratio", 0.0,
         f"{rep['decode_bytes_ratio_gather_over_paged']}x"),
        ("serve_parity", 0.0, str(rep.get("parity_dense_vs_sequential", "skipped"))),
        ("serve_parity_paged_vs_gather", 0.0,
         str(rep["parity_paged_vs_gather_dense"])),
    ]
    if "mxfp4" in rep.get("spec", {}):
        sp = rep["spec"]["mxfp4"]
        rows += [
            ("serve_spec_tok_per_decode_call", 0.0,
             f"{sp['tokens_per_decode_call']}tok/call"),
            ("serve_spec_acceptance", 0.0, f"{sp['acceptance_rate']}"),
            ("serve_spec_parity", 0.0, str(sp["parity_vs_nonspec"])),
        ]
    if "prefill" in rep:
        pf = rep["prefill"]
        rows += [
            ("serve_prefill_tok_per_s", 0.0,
             f"{pf['paged']['prefill_tok_per_s']}tok/s"),
            ("serve_prefill_ttft_mean", 0.0,
             f"{pf['paged']['ttft_mean_s']}s"),
            ("serve_prefill_bytes_ratio", 0.0,
             f"{pf['kv_bytes_per_chunk_mxfp4']['ratio_gather_over_paged']}x"),
            ("serve_prefill_parity", 0.0, str(pf["parity_paged_vs_gather"])),
        ]
    if "prefix_cache" in rep:
        px = rep["prefix_cache"]
        rows += [
            ("serve_prefix_hit_rate", 0.0, f"{px['hit_rate']}"),
            ("serve_prefix_warm_ttft_mean", 0.0, f"{px['warm']['ttft_mean_s']}s"),
            ("serve_prefix_cold_ttft_mean", 0.0, f"{px['cold']['ttft_mean_s']}s"),
            ("serve_prefix_cow_pages", 0.0, f"{px['cow_pages']}"),
            ("serve_prefix_parity", 0.0, str(px["parity_warm_vs_cold"])),
            ("serve_prefix_no_leaks", 0.0, str(px["no_leaks"])),
        ]
    if rep.get("families"):
        for slug, fb in rep["families"].items():
            rows += [
                (f"serve_family_{slug}_parity", 0.0,
                 str(fb["token_parity"] == 1.0)),
                (f"serve_family_{slug}_state_bytes_ratio", 0.0,
                 f"{fb['state_bytes_ratio']}x"),
            ]
    if rep.get("sharding"):
        sh = rep["sharding"]
        if sh["tp_run"]:
            rows += [
                ("serve_tp_parity", 0.0,
                 str(sh["tp_run"]["parity_vs_single"] == 1.0)),
                ("serve_tp_pool_bytes_per_shard", 0.0,
                 f"{sh['tp_run']['pool_bytes_per_shard']}"),
            ]
        if sh["dp_run"]:
            rows += [
                ("serve_dp_parity", 0.0,
                 str(sh["dp_run"]["parity_vs_single"] == 1.0)),
                ("serve_dp_aggregate_tok_per_s", 0.0,
                 f"{sh['dp_run']['aggregate_decode_tok_per_s']}tok/s"),
                ("serve_dp_speedup", 0.0,
                 f"{sh['dp_run']['speedup_vs_one_replica']}x"),
            ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--no-parity", action="store_true")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="drafted tokens per verify call in the spec A/B")
    ap.add_argument("--spec-proposer", default="self",
                    choices=["self", "ngram"],
                    help="proposer for the spec A/B ('self' = parity oracle)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run the shared-prefix A/B section (radix prefix "
                         "cache warm vs cold: hit rate, prefill tok/s, "
                         "mean/p95 TTFT); implied by --smoke")
    ap.add_argument("--smoke", action="store_true",
                    help="small fixed workload + assert the paged-kernel "
                         "decode metrics, spec-mode parity, "
                         "tokens-per-decode-call > 1, prefix-cache "
                         "hit/TTFT/parity/leak checks, and the telemetry "
                         "stream/baseline artifacts (CI)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree for the sharding A/B "
                         "(shards the packed KV pool + paged-attention grid "
                         "over the 'model' mesh axis; needs tp*dp devices — "
                         "force them on CPU with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel engine-replica count for the "
                         "sharding A/B (independent replicas on disjoint "
                         "device groups)")
    ap.add_argument("--family", action="append", dest="family_archs",
                    default=None, metavar="ARCH",
                    help="repeatable: run the state-pool A/B for this "
                         "non-attention arch (pooled engine vs dense-slot "
                         "oracle: token parity, tok/s, state bytes/step); "
                         "fills the schema-v5 'families' block (smoke "
                         "default: falcon-mamba-7b + whisper-tiny)")
    ap.add_argument("--metrics-out", default=None,
                    help="stream the primary run's registry snapshots as "
                         "JSON-lines to this path (smoke default: "
                         "benchmarks/out/metrics_serve.jsonl)")
    ap.add_argument("--profile-out", default=None,
                    help="write the primary run's Chrome trace-event JSON "
                         "(open in Perfetto / chrome://tracing) to this "
                         "path (smoke default: benchmarks/out/"
                         "trace_serve.json)")
    ap.add_argument("--bench-out", default=str(BENCH_PATH),
                    help="where to write the schema-versioned benchmark "
                         "baseline ('' to skip)")
    args = ap.parse_args()
    if args.smoke:
        args.reduced, args.requests, args.max_new, args.slots = True, 4, 4, 2
        args.shared_prefix = True
        if args.family_archs is None:
            args.family_archs = ["falcon-mamba-7b", "whisper-tiny"]
        out_dir = REPO_ROOT / "benchmarks" / "out"
        out_dir.mkdir(parents=True, exist_ok=True)
        if args.metrics_out is None:
            args.metrics_out = str(out_dir / "metrics_serve.jsonl")
        if args.profile_out is None:
            args.profile_out = str(out_dir / "trace_serve.json")
    rep = bench(args.arch, args.reduced, args.requests, args.max_new,
                args.slots, verify_parity=not args.no_parity,
                spec_k=args.spec_k, spec_proposer=args.spec_proposer,
                metrics_out=args.metrics_out, shared_prefix=args.shared_prefix,
                tp=args.tp, dp=args.dp, profile_out=args.profile_out,
                family_archs=args.family_archs)
    print(json.dumps(rep, indent=2))
    if (args.tp > 1 or args.dp > 1) and rep.get("sharding") is None:
        print(f"sharding section skipped: {args.tp * args.dp} devices needed, "
              f"{len(jax.devices())} visible (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)", file=sys.stderr)
    if args.bench_out:
        write_bench(rep, args.bench_out)
        print(f"wrote {args.bench_out}", file=sys.stderr)
    if args.smoke:
        from repro.serve.telemetry.schema import (validate_bench_file,
                                                  validate_metrics_file)
        # the telemetry stream must exist, parse, and carry real signal
        n_snaps = validate_metrics_file(args.metrics_out)
        assert n_snaps >= 1, "empty metrics stream"
        m = rep["mxfp4"]
        assert m["pool_occupancy_peak"] > 0, "pool occupancy never nonzero"
        assert m["decode_tick_p50_s"] > 0, "no decode tick latency recorded"
        assert m["ttft_p50_s"] > 0 and m["ttft_p95_s"] > 0
        assert m["tpot_p50_s"] is not None and m["tpot_p50_s"] > 0
        assert m["latency_p50_s"] > 0
        qh = m.get("quant_health")
        assert qh is not None, "quant health never sampled on the mxfp4 pool"
        assert qh["scale_hist_nonzero_bins"] >= 1
        assert qh["clip_fraction_k"] is not None and qh["clip_fraction_k"] >= 0
        # per-phase cost accounting: the paged primary run must produce a
        # non-null profile block with real decode FLOPs/bytes and a
        # utilization in (0, 1] territory (interpret-mode caveat: the Pallas
        # kernel's internals are undercounted, never zero)
        prof = rep.get("profile")
        assert prof is not None, "profile block missing on a paged family"
        assert prof["decode"] is not None
        assert prof["decode"]["flops_per_call"] > 0
        assert prof["decode"]["hbm_bytes_per_call"] > 0
        assert prof["decode"]["roofline_util_mean"] > 0
        # the Chrome trace must load structurally and carry tick-phase,
        # request-lifecycle, and compile events
        if args.profile_out:
            from repro.serve.telemetry.profiling import validate_trace_file
            tdoc = validate_trace_file(args.profile_out)
            cats = {e.get("cat") for e in tdoc["traceEvents"]}
            assert {"tick", "phase", "request"} <= cats, \
                f"trace missing span categories: {cats}"
        # the persisted baseline must round-trip its schema validator
        doc = validate_bench_file(args.bench_out)
        assert doc["spec"]["acceptance_rate"] is None or \
            0.0 <= doc["spec"]["acceptance_rate"] <= 1.0
        for key in ("mxfp4/paged", "mxfp4/gather", "dense/paged"):
            assert key in rep["decode_backends"], f"missing decode metrics {key}"
            assert rep["decode_backends"][key]["decode_kv_bytes_per_step"] > 0
        assert rep["decode_bytes_ratio_gather_over_paged"] > 1.0
        # batched paged prefill: token-exact vs the per-slot gather oracle,
        # O(packed KV) per chunk, and real throughput/TTFT numbers reported
        # (section exists only for paged families, like the spec A/B)
        pf = rep.get("prefill")
        if pf is not None:
            assert pf["parity_paged_vs_gather"], \
                "PARITY FAILURE: batched paged prefill != per-slot gather prefill"
            assert pf["kv_bytes_per_chunk_mxfp4"]["ratio_gather_over_paged"] > 1.0
            for backend in ("paged", "gather"):
                assert pf[backend]["prefill_tok_per_s"] > 0
                assert pf[backend]["ttft_mean_s"] > 0
        # shared-prefix section: the radix cache must actually hit, COW must
        # be exercised (the pure-prefix request), warm admission must beat
        # cold TTFT strictly, and no pool page may leak past all retires
        px = rep.get("prefix_cache")
        if px is not None:
            assert px["parity_warm_vs_cold"], \
                "PARITY FAILURE: prefix-cached engine != cold engine"
            assert px["hit_rate"] > 0, "prefix cache never hit"
            assert px["shared_tokens"] > 0, "no prompt tokens were aliased"
            assert px["cow_pages"] >= 1, "full-match COW never exercised"
            assert px["warm"]["ttft_mean_s"] < px["cold"]["ttft_mean_s"], \
                "prefix cache did not improve mean TTFT"
            assert px["no_leaks"], "pool pages leaked by the prefix cache"
        # sharding A/B: TP and DP engines must be token-exact vs the
        # single-device engine, and dp >= 2 replicas must actually scale —
        # aggregate decode throughput >= 1.5x the single-replica rate
        sh = rep.get("sharding")
        if sh is not None:
            if sh["tp_run"] is not None:
                assert sh["tp_run"]["parity_vs_single"] == 1.0, \
                    "PARITY FAILURE: TP-sharded engine != single-device engine"
                assert sh["tp_run"]["pool_bytes_per_shard"] > 0
            if sh["dp_run"] is not None:
                assert sh["dp_run"]["parity_vs_single"] == 1.0, \
                    "PARITY FAILURE: DP-replicated engine != single-device engine"
                assert sh["dp_run"]["speedup_vs_one_replica"] >= 1.5, \
                    "DP aggregate decode throughput below 1.5x one replica"
        # state-pool family A/B: pooled serving must be token-exact vs the
        # dense-slot oracle on every benchmarked family (dense planes), and
        # the packed pool must cut per-decode-step state traffic >= 4x on at
        # least the pure-SSM family (f32 recurrent state packs to 4.25-bit)
        fams = rep.get("families")
        if fams is not None:
            for slug, fb in fams.items():
                assert fb["token_parity"] == 1.0, \
                    f"PARITY FAILURE: state-pool {slug} != dense-slot oracle"
                assert fb["state_bytes_ratio"] > 1.0, slug
            if "falcon_mamba_7b" in fams:
                assert fams["falcon_mamba_7b"]["state_bytes_ratio"] >= 4.0, \
                    "SSM state bytes/step reduction below 4x vs dense slots"
        # non-spec decode emits exactly one token per batched call
        assert rep["mxfp4"]["tokens_per_decode_call"] == 1.0
        # spec A/B only exists for paged (dense/moe) families
        for kv in ("dense", "mxfp4"):
            if kv not in rep["spec"]:
                continue
            sp = rep["spec"][kv]
            assert sp["parity_vs_nonspec"], \
                f"PARITY FAILURE: spec({kv}) != non-spec engine"
            assert sp["tokens_per_decode_call"] > 1.0, \
                f"spec({kv}) tokens_per_decode_call not > 1"
            assert 0.0 <= sp["acceptance_rate"] <= 1.0
    if rep.get("parity_dense_vs_sequential") is False:
        raise SystemExit("PARITY FAILURE: dense-cache engine != sequential greedy")
    if not rep["parity_paged_vs_gather_dense"]:
        raise SystemExit("PARITY FAILURE: paged-kernel decode != gather-dense decode")
    if rep.get("prefill", {}).get("parity_paged_vs_gather") is False:
        raise SystemExit("PARITY FAILURE: batched paged prefill != gather prefill")
    if rep.get("prefix_cache", {}).get("parity_warm_vs_cold") is False:
        raise SystemExit("PARITY FAILURE: prefix-cached engine != cold engine")
    if "dense" in rep["spec"] and not rep["spec"]["dense"]["parity_vs_nonspec"]:
        raise SystemExit("PARITY FAILURE: speculative engine != non-speculative engine")
    if rep["cache_ratio"] < 3.0:
        raise SystemExit(f"cache ratio {rep['cache_ratio']} < 3x")


if __name__ == "__main__":
    main()
