"""Serving benchmark: continuous batching + paged FP4 KV cache.

``PYTHONPATH=src python benchmarks/serve_throughput.py --reduced`` runs a
fixed-seed mixed-length workload through the engine in four configurations —
{dense, mxfp4 cache} × {paged-kernel, gather-dense decode} — and prints a
JSON report:

* tokens/sec (decode throughput, wall clock, post-warmup) per configuration,
* p50/p95 request latency and TTFT on the virtual serving clock,
* tokens-per-decode-call and draft acceptance rate per configuration (plain
  decoding sits at exactly 1.0 token/call; speculative decoding amortizes
  each verify call over 1..k+1 emitted tokens),
* a speculative on/off A/B (``spec`` section): greedy self-speculation over
  the paged-kernel decode, dense + mxfp4 pools, with token-exactness vs the
  non-speculative engine asserted,
* a prefill A/B (``prefill`` section): a concurrent-arrival burst of
  prefill-dominated requests (max_new=1) through the batched paged prefill
  (ONE jitted call advances every prefilling slot per tick) vs the per-slot
  gather oracle — prompt tokens/sec, mean + p95 TTFT, and per-chunk KV
  bytes; batched paged prefill must stay token-exact vs the oracle,
* persistent cache bytes dense vs FP4 and their ratio,
* decode-step HBM traffic model: KV bytes touched per batched decode step by
  the fused paged-attention kernel (O(packed KV): read the packed pages in
  place) vs the legacy gather-dequantize oracle (read packed + write dense +
  read dense), and their ratio — and the same model per prefill chunk,
* parity checks — dense-cache engine outputs must equal sequential
  ``greedy_generate`` token-for-token, and the paged-kernel decode must equal
  the gather-dense decode token-for-token in dense-cache mode.

CPU wall-clock caveat: the paged kernel runs in Pallas *interpret* mode here,
so its tok/s is a correctness-path number; the bytes model is the hardware
claim (the kernel's blocking moves 4.25-bit payload instead of bf16 KV).

``run()`` adapts the same numbers to the ``benchmarks.run`` CSV driver.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _build(arch: str, reduced: bool):
    from repro.configs import get_config, get_reduced_config
    from repro.models import build_model

    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    for _ in range(n_requests):
        t += rng.exponential(0.25)
        plen = int(rng.integers(6, 28))
        out.append((t, rng.integers(0, cfg.vocab_size, plen).astype(np.int32), max_new))
    return out


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def decode_kv_bytes_per_step(cache, backend: str) -> int:
    """KV bytes touched by one batched decode step (model, not measurement).

    Both paths see every slot's full page table (T = pages_per_slot·page_size
    positions per slot, all L layers).  The paged kernel streams the packed
    pages once; the gather oracle reads the packed pool, writes the dense
    [L, B, T, Hkv, hd] view, then attention reads it back.  Per-token scatter
    writes (4.25-bit payload for one token) are negligible and omitted.
    """
    hd, H, L = cache.head_dim, cache.kv_heads, cache.layers
    tokens = cache.n_slots * cache.pages_per_slot * cache.page_size
    if cache.kv_dtype == "dense":
        packed_per_tok = 2 * H * hd * jnp.dtype(cache._dtype).itemsize
    else:
        nb = cache.pool["k_scales"].shape[-1]  # scale bytes per head per token
        packed_per_tok = 2 * H * (hd // 2 + nb)
    packed = L * tokens * packed_per_tok
    if backend == "paged":
        return packed
    dense = L * tokens * 2 * H * hd * jnp.dtype(cache._dtype).itemsize
    return packed + 2 * dense  # read packed + write dense + read dense


def prefill_kv_bytes_per_chunk(cache, backend: str) -> int:
    """KV bytes touched per prefilling slot per chunk (model, not measurement).

    Prefill sweeps one slot's page table per chunk exactly as decode sweeps
    every slot's per step, so this is the decode model divided by the slot
    count (ONE shared byte model — keep any change to it in
    :func:`decode_kv_bytes_per_step`): the batched paged prefill streams the
    slot's packed pages once per chunk, the gather oracle reads the packed
    pages, writes the dense [L, T, Hkv, hd] view, and attention reads it
    back.  Batched prefill therefore moves O(packed KV) per chunk instead of
    O(dense KV), which is what keeps TTFT flat as concurrent arrivals stack
    up.
    """
    return decode_kv_bytes_per_step(cache, backend) // cache.n_slots


def bench(arch: str = "qwen3-1.7b", reduced: bool = True, n_requests: int = 8,
          max_new: int = 8, n_slots: int = 4, verify_parity: bool = True,
          spec_k: int = 3, spec_proposer: str = "self") -> dict:
    from repro.launch.serve_engine import run_workload
    from repro.serve import Engine, EngineConfig, SpecConfig
    from repro.serve.spec import aggregate_stats
    from repro.train.serve import greedy_generate

    cfg, model, params = _build(arch, reduced)
    workload = _workload(cfg, n_requests, max_new)
    report: dict = {"arch": cfg.name, "family": cfg.family,
                    "n_requests": n_requests, "max_new": max_new,
                    "n_slots": n_slots}

    def run_config(kv, backend, spec=None):
        eng = Engine(model, params, EngineConfig(
            n_slots=n_slots, max_len=64, page_size=16, kv_dtype=kv,
            prefill_chunk=16, decode_backend=backend, spec=spec))
        # warmup: compile the step shapes outside the timed region
        eng.submit(workload[0][1], 2, arrival_time=0.0)
        eng.drain()
        eng.completed.clear()

        t0 = time.perf_counter()
        done, _ = run_workload(eng, workload, verbose=False)
        wall = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in done)
        agg = aggregate_stats(done)
        stats = {
            "tokens_per_sec": round(toks / wall, 2),
            "wall_sec": round(wall, 3),
            "latency_p50_s": round(_pct([r.latency() for r in done], 0.5), 4),
            "latency_p95_s": round(_pct([r.latency() for r in done], 0.95), 4),
            "ttft_p50_s": round(_pct([r.ttft() for r in done], 0.5), 4),
            "ttft_p95_s": round(_pct([r.ttft() for r in done], 0.95), 4),
            "tokens_per_decode_call": agg["tokens_per_decode_call"],
            "acceptance_rate": agg["acceptance_rate"],
            "cache_bytes": eng.cache_bytes(),
            "bits_per_kv_elem": round(eng.cache.bits_per_element(), 2)
            if eng.paged else 16.0,
            "decode_kv_bytes_per_step":
            decode_kv_bytes_per_step(eng.cache, backend) if eng.paged else 0,
            "prefill_kv_bytes_per_chunk":
            prefill_kv_bytes_per_chunk(eng.cache, backend) if eng.paged else 0,
        }
        return stats, {r.rid: list(r.tokens) for r in done}

    outputs: dict = {}
    report["decode_backends"] = {}
    for kv, backend in (("dense", "paged"), ("dense", "gather"),
                        ("mxfp4", "paged"), ("mxfp4", "gather")):
        stats, outputs[(kv, backend)] = run_config(kv, backend)
        if backend == "paged":  # primary numbers, keyed by cache dtype
            report[kv] = stats
        report["decode_backends"][f"{kv}/{backend}"] = {
            k: stats[k] for k in
            ("tokens_per_sec", "wall_sec", "decode_kv_bytes_per_step",
             "prefill_kv_bytes_per_chunk")}

    # -- speculative on/off A/B (paged-kernel decode, both pool dtypes) -----
    report["spec"] = {"k": spec_k, "proposer": spec_proposer}
    if cfg.family in ("dense", "moe"):
        sc = SpecConfig(k=spec_k, proposer=spec_proposer)
        for kv in ("dense", "mxfp4"):
            stats, out = run_config(kv, "paged", spec=sc)
            stats["parity_vs_nonspec"] = out == outputs[(kv, "paged")]
            report["spec"][kv] = stats

    # -- batched-prefill A/B: concurrent arrival burst, prefill-dominated ----
    # Every request lands at t=0 with max_new=1, so the run is ~all prefill:
    # the batched path advances EVERY prefilling slot in one jitted call per
    # tick (and attends over the packed pool), the gather oracle runs one
    # [1, C] call per slot per tick plus [1, 1] remainder singles.
    if cfg.family in ("dense", "moe"):
        prng = np.random.default_rng(1)
        plens = [int(prng.integers(9, 33)) for _ in range(n_requests)]
        burst = [(0.0, prng.integers(0, cfg.vocab_size, pl).astype(np.int32), 1)
                 for pl in plens]
        prefill_rep: dict = {"n_requests": n_requests,
                             "prompt_tokens": sum(plens)}
        pf_out = {}
        for backend in ("paged", "gather"):
            eng = Engine(model, params, EngineConfig(
                n_slots=n_slots, max_len=64, page_size=16, kv_dtype="dense",
                prefill_chunk=16, decode_backend=backend))
            eng.submit(burst[0][1], 1, arrival_time=0.0)
            eng.drain()
            eng.completed.clear()
            t0 = time.perf_counter()
            done, _ = run_workload(eng, burst, verbose=False)
            wall = time.perf_counter() - t0
            ttfts = [r.ttft() for r in done]
            prefill_rep[backend] = {
                "prefill_tok_per_s": round(sum(plens) / wall, 2),
                "wall_sec": round(wall, 3),
                "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4),
                "ttft_p95_s": round(_pct(ttfts, 0.95), 4),
            }
            pf_out[backend] = {r.rid: list(r.tokens) for r in done}
        # batched paged prefill must reproduce the per-slot gather oracle
        # token-for-token on the dense pool
        prefill_rep["parity_paged_vs_gather"] = pf_out["paged"] == pf_out["gather"]
        db_ = report["decode_backends"]
        pp = db_["mxfp4/paged"]["prefill_kv_bytes_per_chunk"]
        prefill_rep["kv_bytes_per_chunk_mxfp4"] = {
            "paged": pp,
            "gather": db_["mxfp4/gather"]["prefill_kv_bytes_per_chunk"],
            "ratio_gather_over_paged": round(
                db_["mxfp4/gather"]["prefill_kv_bytes_per_chunk"] / pp, 2)
            if pp else None,
        }
        report["prefill"] = prefill_rep

    report["cache_ratio"] = round(
        report["dense"]["cache_bytes"] / report["mxfp4"]["cache_bytes"], 2)
    db = report["decode_backends"]
    paged_bytes = db["mxfp4/paged"]["decode_kv_bytes_per_step"]
    report["decode_bytes_ratio_gather_over_paged"] = round(
        db["mxfp4/gather"]["decode_kv_bytes_per_step"] / paged_bytes, 2
    ) if paged_bytes else None  # dense-slot families: no paged decode path
    # the paged kernel must reproduce the gather oracle exactly when the pool
    # stores the compute dtype (same values, same online-softmax math)
    report["parity_paged_vs_gather_dense"] = (
        outputs[("dense", "paged")] == outputs[("dense", "gather")])

    if verify_parity:
        ref_toks = []
        for _, prompt, mn in workload:
            ref = greedy_generate(model, params, jnp.asarray(prompt)[None],
                                  max_new=mn, max_len=int(prompt.size) + mn)
            ref_toks.append(ref[0].tolist())
        # rids are assigned in submission (arrival) order; the warmup request
        # is cleared, so sorted rids map 1:1 onto the workload — minus the
        # warmup's rid 0 offset
        dense_out = outputs[("dense", "paged")]
        eng_toks = [dense_out[rid] for rid in sorted(dense_out)]
        report["parity_dense_vs_sequential"] = eng_toks == ref_toks

    return report


def run():
    """benchmarks.run driver hook → (name, us_per_call, derived) rows."""
    rep = bench()
    per_tok = max(rep["n_requests"] * rep["max_new"], 1)
    db = rep["decode_backends"]
    rows = [
        ("serve_fp4_tok_per_s", rep["mxfp4"]["wall_sec"] * 1e6 / per_tok,
         f"{rep['mxfp4']['tokens_per_sec']}tok/s"),
        ("serve_dense_tok_per_s", rep["dense"]["wall_sec"] * 1e6 / per_tok,
         f"{rep['dense']['tokens_per_sec']}tok/s"),
        ("serve_gather_decode_tok_per_s",
         db["mxfp4/gather"]["wall_sec"] * 1e6 / per_tok,
         f"{db['mxfp4/gather']['tokens_per_sec']}tok/s"),
        ("serve_cache_ratio", 0.0, f"{rep['cache_ratio']}x"),
        ("serve_decode_bytes_ratio", 0.0,
         f"{rep['decode_bytes_ratio_gather_over_paged']}x"),
        ("serve_parity", 0.0, str(rep.get("parity_dense_vs_sequential", "skipped"))),
        ("serve_parity_paged_vs_gather", 0.0,
         str(rep["parity_paged_vs_gather_dense"])),
    ]
    if "mxfp4" in rep.get("spec", {}):
        sp = rep["spec"]["mxfp4"]
        rows += [
            ("serve_spec_tok_per_decode_call", 0.0,
             f"{sp['tokens_per_decode_call']}tok/call"),
            ("serve_spec_acceptance", 0.0, f"{sp['acceptance_rate']}"),
            ("serve_spec_parity", 0.0, str(sp["parity_vs_nonspec"])),
        ]
    if "prefill" in rep:
        pf = rep["prefill"]
        rows += [
            ("serve_prefill_tok_per_s", 0.0,
             f"{pf['paged']['prefill_tok_per_s']}tok/s"),
            ("serve_prefill_ttft_mean", 0.0,
             f"{pf['paged']['ttft_mean_s']}s"),
            ("serve_prefill_bytes_ratio", 0.0,
             f"{pf['kv_bytes_per_chunk_mxfp4']['ratio_gather_over_paged']}x"),
            ("serve_prefill_parity", 0.0, str(pf["parity_paged_vs_gather"])),
        ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--no-parity", action="store_true")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="drafted tokens per verify call in the spec A/B")
    ap.add_argument("--spec-proposer", default="self",
                    choices=["self", "ngram"],
                    help="proposer for the spec A/B ('self' = parity oracle)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fixed workload + assert the paged-kernel "
                         "decode metrics, spec-mode parity, and "
                         "tokens-per-decode-call > 1 (CI)")
    args = ap.parse_args()
    if args.smoke:
        args.reduced, args.requests, args.max_new, args.slots = True, 4, 4, 2
    rep = bench(args.arch, args.reduced, args.requests, args.max_new,
                args.slots, verify_parity=not args.no_parity,
                spec_k=args.spec_k, spec_proposer=args.spec_proposer)
    print(json.dumps(rep, indent=2))
    if args.smoke:
        for key in ("mxfp4/paged", "mxfp4/gather", "dense/paged"):
            assert key in rep["decode_backends"], f"missing decode metrics {key}"
            assert rep["decode_backends"][key]["decode_kv_bytes_per_step"] > 0
        assert rep["decode_bytes_ratio_gather_over_paged"] > 1.0
        # batched paged prefill: token-exact vs the per-slot gather oracle,
        # O(packed KV) per chunk, and real throughput/TTFT numbers reported
        # (section exists only for paged families, like the spec A/B)
        pf = rep.get("prefill")
        if pf is not None:
            assert pf["parity_paged_vs_gather"], \
                "PARITY FAILURE: batched paged prefill != per-slot gather prefill"
            assert pf["kv_bytes_per_chunk_mxfp4"]["ratio_gather_over_paged"] > 1.0
            for backend in ("paged", "gather"):
                assert pf[backend]["prefill_tok_per_s"] > 0
                assert pf[backend]["ttft_mean_s"] > 0
        # non-spec decode emits exactly one token per batched call
        assert rep["mxfp4"]["tokens_per_decode_call"] == 1.0
        # spec A/B only exists for paged (dense/moe) families
        for kv in ("dense", "mxfp4"):
            if kv not in rep["spec"]:
                continue
            sp = rep["spec"][kv]
            assert sp["parity_vs_nonspec"], \
                f"PARITY FAILURE: spec({kv}) != non-spec engine"
            assert sp["tokens_per_decode_call"] > 1.0, \
                f"spec({kv}) tokens_per_decode_call not > 1"
            assert 0.0 <= sp["acceptance_rate"] <= 1.0
    if rep.get("parity_dense_vs_sequential") is False:
        raise SystemExit("PARITY FAILURE: dense-cache engine != sequential greedy")
    if not rep["parity_paged_vs_gather_dense"]:
        raise SystemExit("PARITY FAILURE: paged-kernel decode != gather-dense decode")
    if rep.get("prefill", {}).get("parity_paged_vs_gather") is False:
        raise SystemExit("PARITY FAILURE: batched paged prefill != gather prefill")
    if "dense" in rep["spec"] and not rep["spec"]["dense"]["parity_vs_nonspec"]:
        raise SystemExit("PARITY FAILURE: speculative engine != non-speculative engine")
    if rep["cache_ratio"] < 3.0:
        raise SystemExit(f"cache ratio {rep['cache_ratio']} < 3x")


if __name__ == "__main__":
    main()
