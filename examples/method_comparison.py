"""Table-3-style method shoot-out at laptop scale: train the same tiny Llama
with every fully-quantized training method and print the loss table.

  PYTHONPATH=src python examples/method_comparison.py --steps 200
"""

import argparse

import numpy as np

from repro.configs.llama_paper import tiny_llama
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
from repro.models import build_model
from repro.optim import adamw, cosine_warmup
from repro.train.loop import train

METHODS = ["bf16", "quartet", "luq_int4", "luq_fp4", "jetfire_fp4",
           "halo_fp4", "lss_int4"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d", type=int, default=64)
    args = ap.parse_args()

    cfg = tiny_llama(d=args.d, layers=2, vocab=512)
    model = build_model(cfg)
    ds = SyntheticC4Dataset(vocab_size=cfg.vocab_size, seed=7)

    print(f"{'method':14s} {'final loss':>10s}   (tiny Llama, {args.steps} steps)")
    results = {}
    for method in METHODS:
        batcher = TokenBatcher(ds, global_batch=8, seq_len=64, seed=1)
        opt = adamw(cosine_warmup(2e-3, args.steps), weight_decay=0.0)
        _, hist = train(model, opt, batcher, args.steps, method=method, log_every=0)
        final = float(np.mean([h["loss"] for h in hist[-8:]]))
        results[method] = final
        print(f"{method:14s} {final:10.4f}")

    prior = min(v for k, v in results.items() if k not in ("bf16", "quartet"))
    print(f"\nquartet vs best 4-bit prior: {results['quartet']:.4f} vs {prior:.4f} "
          f"({'WINS' if results['quartet'] < prior else 'LOSES'}) — paper Table 3")


if __name__ == "__main__":
    main()
