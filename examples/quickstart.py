"""Quickstart: Quartet's Algorithm 1 on a single linear layer.

Shows the public API at the three levels most users need:
  1. quartet_linear — the drop-in quantized GEMM with custom VJP,
  2. the quantizer zoo + metrics of §4.3,
  3. a 20-step training sanity run of a tiny Llama with every matmul in MXFP4.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core import quantizers as Q
from repro.core.quartet import QUARTET_CONFIG, quartet_linear


def main():
    key = jax.random.PRNGKey(0)

    # -- 1. the Quartet linear layer -----------------------------------------
    x = jax.random.normal(key, (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.06
    y = quartet_linear(x, w, jnp.uint32(0), QUARTET_CONFIG)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    print(f"[1] forward in MXFP4: relative error {rel:.3f} "
          f"(all three GEMMs of the VJP run in MXFP4)")
    grads = jax.grad(lambda a, b: jnp.sum(
        quartet_linear(a, b, jnp.uint32(0), QUARTET_CONFIG) ** 2), (0, 1))(x, w)
    print(f"    backward: |dx|={float(jnp.linalg.norm(grads[0])):.2f} "
          f"|dw|={float(jnp.linalg.norm(grads[1])):.2f}")

    # -- 2. the error-bias trade-off (Table 2) --------------------------------
    g = jax.random.normal(key, (2048, 32))
    for name, r in [("QuEST  ", Q.quest(g)), ("RTN    ", Q.rtn_absmax(g)),
                    ("SR     ", Q.sr_absmax(g, jax.random.PRNGKey(2)))]:
        mse = float(jnp.mean((r.values - g) ** 2) / jnp.mean(g**2))
        print(f"[2] {name} forward MSE {mse:.4f}")
    mis = float(M.pma_misalignment(g.ravel()[:4096], "sr_absmax",
                                   jax.random.PRNGKey(3), num_samples=16))
    print(f"    SR misalignment {mis:+.1e}  → unbiased backward (§4.3)")

    # -- 3. end-to-end: a tiny Llama fully trained in MXFP4 -------------------
    from repro.configs.llama_paper import tiny_llama
    from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
    from repro.models import build_model
    from repro.optim import adamw, cosine_warmup
    from repro.train.loop import train

    cfg = tiny_llama(d=64, layers=2, vocab=512)
    model = build_model(cfg)
    ds = SyntheticC4Dataset(vocab_size=cfg.vocab_size, seed=0)
    batcher = TokenBatcher(ds, global_batch=8, seq_len=64)
    opt = adamw(cosine_warmup(2e-3, 20), weight_decay=0.0)
    _, hist = train(model, opt, batcher, 20, log_every=0)
    print(f"[3] tiny-Llama, every linear in MXFP4: "
          f"loss {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f} in 20 steps")


if __name__ == "__main__":
    main()
