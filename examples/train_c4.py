"""End-to-end training driver (deliverable b): pre-train a Llama on the C4
stand-in with Quartet, exactly the paper's recipe (AdamW, cosine + 10%
warmup, clip 1.0, seq 512, fp32 optimizer states).

Default runs the paper's 30M config for a few hundred steps — on a TPU pod
this is the real pre-training entry point (same code path as
``repro.launch.train``); on the CPU container pass ``--tiny`` for a
minutes-scale run.  Restarts resume from the checkpoint directory.

  PYTHONPATH=src python examples/train_c4.py --tiny --steps 300
  PYTHONPATH=src python examples/train_c4.py --arch llama-paper-30m \
      --steps 500 --method quartet --checkpoint-dir ckpts/30m
"""

import argparse

from repro.configs import get_config
from repro.configs.llama_paper import LEARNING_RATES, tiny_llama
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher, make_dataset
from repro.models import build_model
from repro.optim import adamw, cosine_warmup
from repro.train.loop import evaluate, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-paper-30m")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--method", default="quartet")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--data", default="synthetic",
                    help='"synthetic" or a path to packed uint16 tokens (C4)')
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    if args.tiny:
        cfg = tiny_llama(d=96, layers=3, vocab=1024)
        lr = 2e-3
    else:
        cfg = get_config(args.arch)
        lr = LEARNING_RATES.get(args.arch, 6e-4)
    seq = args.seq or (64 if args.tiny else 512)  # paper: seq 512

    model = build_model(cfg)
    ds = make_dataset(args.data, cfg.vocab_size)
    batcher = TokenBatcher(ds, args.batch, seq)
    opt = adamw(cosine_warmup(lr, args.steps), weight_decay=0.1)

    state, hist = train(
        model, opt, batcher, args.steps, method=args.method,
        checkpoint_dir=args.checkpoint_dir, checkpoint_every=max(args.steps // 4, 50),
        microbatch=args.microbatch, log_every=10)

    ev = TokenBatcher(ds, args.batch, seq, seed=123)
    val = evaluate(model, state, ev, 8, method=args.method)
    print(f"\n{cfg.name} [{args.method}] {args.steps} steps "
          f"({args.steps * args.batch * seq:,} tokens): val loss {val:.4f}")


if __name__ == "__main__":
    main()
