"""Serving example: the continuous-batching engine on mixed prompt lengths
with staggered arrivals, for any architecture family.

  PYTHONPATH=src python examples/serve_batched.py                      # dense arch, FP4 KV pages
  PYTHONPATH=src python examples/serve_batched.py --kv dense           # parity mode
  PYTHONPATH=src python examples/serve_batched.py --arch falcon-mamba-7b   # SSM → dense slots
  PYTHONPATH=src python examples/serve_batched.py --spec ngram --spec-k 4  # speculative decoding

Requests arrive over the first few engine steps (not all at once), prompts
range from 6 to 30 tokens, and there are more requests than decode slots —
so the run exercises queueing, chunked prefill riding alongside in-flight
decodes, retirement, and slot/page recycling.  The dense-cache engine output
is checked token-for-token against sequential ``greedy_generate``.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serve import Engine, EngineConfig, SpecConfig, TelemetryConfig
from repro.serve.spec import aggregate_stats
from repro.train.serve import greedy_generate


def make_extra(cfg, key):
    if cfg.family == "encdec":
        return {"source_embeds": jax.random.normal(
            key, (1, cfg.max_source_len, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"image_embeds": jax.random.normal(
            key, (1, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)}
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--kv", default="mxfp4", choices=["mxfp4", "dense"])
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged families: radix prefix cache with "
                         "copy-on-write — prompts get a shared 16-token "
                         "system prefix so later admissions alias its pages "
                         "and prefill only their unique tail")
    ap.add_argument("--spec", default=None, choices=["self", "ngram"],
                    help="speculative decoding proposer (paged families)")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--metrics-out", default=None,
                    help="stream telemetry snapshots as JSON-lines here")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request span traces as JSON-lines here")
    ap.add_argument("--profile-out", default=None,
                    help="profile the run: roofline/bandwidth gauges + a "
                         "Chrome trace-event JSON written here (open in "
                         "Perfetto / chrome://tracing)")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    rng = np.random.default_rng(0)
    extra = make_extra(cfg, key)

    spec = (SpecConfig(k=args.spec_k, proposer=args.spec)
            if args.spec is not None else None)
    engine = Engine(model, params, EngineConfig(
        n_slots=args.slots, max_len=48, page_size=8, kv_dtype=args.kv,
        prefill_chunk=8, prefix_cache=args.prefix_cache, spec=spec,
        telemetry=TelemetryConfig(metrics_path=args.metrics_out,
                                  trace_path=args.trace_out,
                                  profile_trace_path=args.profile_out,
                                  quant_stride=4)))

    # mixed prompt lengths, arrivals staggered over the first steps
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(6, 31)))
               .astype(np.int32) for _ in range(args.requests)]
    if args.prefix_cache:
        # shared system prefix (two full pages): the first request to retire
        # publishes its pages into the radix index, later admissions alias
        # them and prefill only their unique tail
        system = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        prompts = [np.concatenate([system, p]) for p in prompts]
    arrive_at_step = sorted(int(rng.integers(0, 4)) for _ in range(args.requests))

    t0 = time.time()
    handles, next_req = [], 0
    while next_req < len(prompts) or engine.sched.pending:
        while next_req < len(prompts) and arrive_at_step[next_req] <= engine.steps:
            handles.append(engine.submit(prompts[next_req], args.max_new,
                                         extra=extra, arrival_time=float(engine.steps)))
            next_req += 1
        info = engine.step(now=float(engine.steps))
        print(f"step {info['step']:3d}: queued={info['queued']} "
              f"prefill={info['prefilling']} decode={info['decoding']}")
    dt = time.time() - t0

    total = sum(len(h.tokens) for h in handles)
    print(f"\n{cfg.name} [{cfg.family}] kv={args.kv if engine.paged else 'dense-slots'}: "
          f"{len(handles)} requests ({min(p.size for p in prompts)}–"
          f"{max(p.size for p in prompts)} prompt tokens) → {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s)")
    # the engine's own telemetry replaces hand-rolled stats: queue depths,
    # TTFT/TPOT percentiles, pool occupancy and FP4 clip/scale health all
    # came along for free with the run
    engine.telemetry.finalize()
    print(engine.telemetry.summary())
    if engine.prefix is not None:
        c = engine.telemetry.registry.counter
        print(f"prefix cache: {c('prefix_hit_requests').value}/"
              f"{c('prefix_lookups').value} admissions hit, "
              f"{c('prefix_shared_tokens').value} prompt tokens aliased, "
              f"{c('prefix_cow_pages').value} COW pages, "
              f"{engine.prefix.cached_pages()} pages cached")
    for label, path in (("metrics", args.metrics_out), ("traces", args.trace_out),
                        ("profile trace", args.profile_out)):
        if path:
            print(f"{label} → {path}")
    if spec is not None:
        agg = aggregate_stats(handles)
        print(f"spec[{args.spec}, k={args.spec_k}]: "
              f"{agg['tokens_per_decode_call']} tokens/verify-call, "
              f"acceptance {agg['acceptance_rate']}")
    for h in handles[:3]:
        print(f"  req {h.rid}: prompt[{h.prompt_len}] -> {h.tokens}")

    if args.kv == "dense" or not engine.paged:
        ok = all(
            h.tokens == greedy_generate(
                model, params, jnp.asarray(h.prompt)[None], max_new=args.max_new,
                max_len=h.prompt_len + args.max_new, extra=extra)[0].tolist()
            for h in handles)
        print("token-for-token parity vs sequential greedy_generate:", ok)


if __name__ == "__main__":
    main()
