"""Serving example (deliverable b): batched prefill + incremental decode with
the per-family cache engine, for any architecture.

  PYTHONPATH=src python examples/serve_batched.py --arch falcon-mamba-7b
  PYTHONPATH=src python examples/serve_batched.py --arch whisper-tiny
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.train.serve import greedy_generate, init_cache, make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    extra = None
    if cfg.family == "encdec":
        extra = {"source_embeds": jax.random.normal(
            key, (args.batch, cfg.max_source_len, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        extra = {"image_embeds": jax.random.normal(
            key, (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)}

    # explicit prefill/decode (what a serving loop does per request batch)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    caches = init_cache(model, args.batch, args.prompt_len + args.max_new)
    t0 = time.time()
    logits, caches, pos = prefill(params, prompt, caches, extra)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    for _ in range(args.max_new - 1):
        logits, caches, pos = decode(params, tok, pos, caches)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"{cfg.name}: prefill({args.batch}×{args.prompt_len}) + "
          f"{args.max_new} decode steps in {dt:.2f}s "
          f"→ {args.batch * args.max_new / dt:.1f} tok/s (CPU, reduced config)")
    print("sample:", out[0])

    # one-call wrapper used by tests
    out2 = greedy_generate(model, params, prompt, max_new=4,
                           max_len=args.prompt_len + 4, extra=extra)
    print("greedy_generate:", out2.shape)


if __name__ == "__main__":
    main()
