"""Speculative decoding: verify/rollback invariants, proposers, sampling.

Contracts pinned here:

* **Token-exactness** — greedy speculation (any proposer) emits exactly the
  tokens the non-speculative engine would, across dense/mxfp4 pools and
  ragged concurrent slot lengths; self-speculation additionally accepts
  ~100 % of drafts (same model, bitwise-equal logits), which pins the whole
  draft → verify → accept pipeline including the multi-query paged kernel.
* **Rollback** — rejected suffixes shrink the slot's logical length;
  logical lengths grow monotonically tick over tick, freed speculation
  pages return to the (sorted) free list and are reused low-ids-first.
* **Sampling** — temperature 0 ≡ greedy bit-for-bit; a sampled engine
  request matches a sampled ``greedy_generate`` with the same
  SamplingParams (shared per-token key discipline); sampled speculation
  matches sampled non-speculative decoding.
* **Accounting** — acceptance rate ∈ [0, 1]; plain decode sits at exactly
  1.0 token per decode call, speculation above 1.0.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serve import Engine, EngineConfig, PagedCache, SamplingParams, SpecConfig
from repro.serve.spec import accept_tokens, aggregate_stats
from repro.train.serve import greedy_generate

pytestmark = pytest.mark.spec

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def qwen_setup():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _prompts(cfg, lens=(7, 12, 5), seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in lens]


def _run(model, params, prompts, max_new=6, *, spec=None, kv="dense",
         backend=None, sampling=None, n_slots=3, eos_id=None):
    eng = Engine(model, params, EngineConfig(
        n_slots=n_slots, max_len=32, page_size=8, kv_dtype=kv,
        prefill_chunk=8, decode_backend=backend, spec=spec, eos_id=eos_id))
    handles = [eng.submit(p, max_new, sampling=sampling) for p in prompts]
    eng.drain()
    return eng, handles


# ---------------------------------------------------------------------------
# acceptance logic (pure host)
# ---------------------------------------------------------------------------


def test_accept_tokens():
    # all accepted → bonus rides along
    assert accept_tokens([1, 2, 3], [1, 2, 3, 9]) == (3, [1, 2, 3, 9])
    # first mismatch → correction token emitted, suffix dropped
    assert accept_tokens([1, 2, 3], [1, 7, 8, 9]) == (1, [1, 7])
    assert accept_tokens([1, 2, 3], [5, 6, 7, 8]) == (0, [5])
    with pytest.raises(ValueError):
        accept_tokens([1, 2], [1, 2])  # target must carry k+1 draws


# ---------------------------------------------------------------------------
# greedy token-exactness: spec engine == non-spec engine (== greedy_generate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv,backend", [("dense", "paged"), ("dense", "gather"),
                                        ("mxfp4", "paged")])
def test_self_spec_token_exact(qwen_setup, kv, backend):
    """Greedy self-speculation (k=3): token-for-token vs the non-speculative
    engine over ragged concurrent requests, and ~100 % acceptance (the
    verify recomputes bitwise-identical logits).  mxfp4+gather is excluded
    by design: the gather oracle's intra-burst attention reads the drafted
    tokens' KV pre-quantization, while sequential decode reads them from the
    packed pool — the default paged backend quantizes-then-attends in both
    shapes and stays exact."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg)
    _, base = _run(model, params, prompts, kv=kv, backend=backend)
    eng, spec_h = _run(model, params, prompts, kv=kv, backend=backend,
                       spec=SpecConfig(k=3, proposer="self"))
    for b, s in zip(base, spec_h):
        assert s.tokens == b.tokens
        assert s.acceptance_rate() == 1.0
        assert s.tokens_per_decode_call() > 1.0
    # all pages recycled (incl. speculation headroom pages)
    assert eng.cache.free_pages == eng.cache.n_pages - 1


def test_ngram_spec_token_exact_and_bounded_acceptance(qwen_setup):
    """Any greedy proposer is token-exact — speculation changes the schedule,
    never the tokens; ngram acceptance is whatever it is, but ∈ [0, 1]."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg)
    for kv in ("dense", "mxfp4"):
        _, base = _run(model, params, prompts, kv=kv)
        _, spec_h = _run(model, params, prompts, kv=kv,
                         spec=SpecConfig(k=3, proposer="ngram", ngram=2))
        for b, s in zip(base, spec_h):
            assert s.tokens == b.tokens
            assert 0.0 <= s.acceptance_rate() <= 1.0
            assert 1.0 <= s.tokens_per_decode_call() <= 4.0


def test_draft_model_spec_token_exact(qwen_setup):
    """Draft-model proposer with draft == target (same arch/seed): the draft
    cache machinery (lazy prefill sync, lock-step rollback) must keep
    acceptance high and outputs exact."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(7, 12))
    spec = SpecConfig(k=3, proposer="draft", draft_arch="qwen3-1.7b",
                      draft_kv_dtype="mxfp4")
    _, base = _run(model, params, prompts, kv="mxfp4")
    _, spec_h = _run(model, params, prompts, kv="mxfp4", spec=spec)
    for b, s in zip(base, spec_h):
        assert s.tokens == b.tokens
        assert s.acceptance_rate() > 0.5  # same weights → near-total agreement


def test_moe_self_spec_token_exact():
    """MoE routing sees multi-token verify bursts (per-token top-k routing
    at per-slot offsets) — must stay exact like dense."""
    cfg = get_reduced_config("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(KEY)
    prompts = _prompts(cfg, lens=(6, 9))
    _, base = _run(model, params, prompts, max_new=4, kv="dense")
    _, spec_h = _run(model, params, prompts, max_new=4, kv="dense",
                     spec=SpecConfig(k=2, proposer="self"))
    for b, s in zip(base, spec_h):
        assert s.tokens == b.tokens


def test_spec_eos_mid_burst(qwen_setup):
    """EOS inside an accepted burst stops emission immediately — no tokens
    after EOS, finish_reason == 'eos', parity with the non-spec engine."""
    cfg, model, params = qwen_setup
    prompt = _prompts(cfg, lens=(9,), seed=6)[0]
    first = int(greedy_generate(model, params, jnp.asarray(prompt)[None],
                                max_new=1, max_len=16)[0, 0])
    second = int(greedy_generate(model, params, jnp.asarray(prompt)[None],
                                 max_new=2, max_len=16)[0, 1])
    for eos in (first, second):
        _, base = _run(model, params, [prompt], max_new=8, eos_id=eos)
        _, spec_h = _run(model, params, [prompt], max_new=8, eos_id=eos,
                         spec=SpecConfig(k=3, proposer="self"))
        assert spec_h[0].tokens == base[0].tokens
        assert spec_h[0].finish_reason == "eos"
        assert spec_h[0].tokens[-1] == eos


def test_spec_rejects_non_paged_families():
    cfg = get_reduced_config("falcon-mamba-7b")
    model = build_model(cfg)
    params = model.init(KEY)
    with pytest.raises(ValueError):
        Engine(model, params, EngineConfig(spec=SpecConfig(k=2)))


# ---------------------------------------------------------------------------
# page-reservation contract: spec decode never maps beyond the admission
# reservation, so a pool sized exactly to its reservations cannot OOM
# ---------------------------------------------------------------------------


def test_spec_full_pool_decode_to_budget(qwen_setup):
    """Regression: with the pool sized EXACTLY to the admitted reservations
    (no spare pages at all) and prompt+max_new == max_len, speculative decode
    must run to the token budget.  The old ``ensure(slot, p0 + k + 1)``
    mapped pages past the reservation on demand, popping unreserved pages —
    under this full pool that raised RuntimeError("out of pages") mid-flight
    despite the scheduler's reserved-up-front contract."""
    cfg, model, params = qwen_setup
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2)]
    # n_slots=2, page_size=4, prompt=8 + max_new=8 == max_len=16 → exactly
    # 4 pages per slot; pool = scratch + 2×4 pages, i.e. zero slack
    ecfg = EngineConfig(n_slots=2, max_len=16, page_size=4, kv_dtype="mxfp4",
                        prefill_chunk=4, n_pages=1 + 2 * 4,
                        spec=SpecConfig(k=3, proposer="self"))
    eng = Engine(model, params, ecfg)
    handles = [eng.submit(p, 8) for p in prompts]
    reserved = eng.cache.pages_needed(16)
    while eng.sched.pending:  # drain, asserting the reservation invariant
        eng.step()
        for req in eng.sched.active.values():
            assert eng.cache.mapped_pages(req.slot) <= reserved
    assert all(len(h.tokens) == 8 for h in handles)
    assert all(h.acceptance_rate() == 1.0 for h in handles)
    assert eng.cache.free_pages == eng.cache.n_pages - 1
    # parity: the same tight pool, non-speculative
    base = Engine(model, params, dataclasses.replace(ecfg, spec=None))
    bh = [base.submit(p, 8) for p in prompts]
    base.drain()
    assert [h.tokens for h in handles] == [h.tokens for h in bh]


# ---------------------------------------------------------------------------
# acceptance accounting on truncated bursts
# ---------------------------------------------------------------------------


def test_truncated_burst_accounting_budget(qwen_setup):
    """A request that hits max_new mid-burst counts only drafts at emittable
    positions: the self-proposer oracle must report acceptance EXACTLY 1.0
    (the old ``+= k`` over-count diluted it with never-emittable drafts
    whose beyond-budget context is scratch garbage by design)."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(9,))
    # max_new=4, k=4: prefill emits token 1, the single verify burst may only
    # emit 3 of its up-to-5 tokens → truncation guaranteed
    _, hs = _run(model, params, prompts, max_new=4,
                 spec=SpecConfig(k=4, proposer="self"))
    h = hs[0]
    assert len(h.tokens) == 4 and h.finish_reason == "max_tokens"
    assert h.draft_proposed == 3  # only the emittable drafts
    assert h.draft_accepted == 3
    assert h.acceptance_rate() == 1.0


def test_truncated_burst_accounting_eos(qwen_setup):
    """EOS inside an accepted burst likewise stops the count at the emitted
    prefix — acceptance stays exactly 1.0 for the self oracle."""
    cfg, model, params = qwen_setup
    prompt = _prompts(cfg, lens=(9,), seed=6)[0]
    ref = greedy_generate(model, params, jnp.asarray(prompt)[None],
                          max_new=8, max_len=24)[0].tolist()
    # an eos value first reached during the decode phase (index ≥ 1), so at
    # least one verify burst runs before emission stops on it
    eos = next(t for i, t in enumerate(ref[1:], 1) if t not in ref[:i])
    _, hs = _run(model, params, [prompt], max_new=8, eos_id=eos,
                 spec=SpecConfig(k=3, proposer="self"))
    h = hs[0]
    assert h.finish_reason == "eos" and h.tokens[-1] == eos
    assert h.draft_proposed > 0
    assert h.draft_accepted == h.draft_proposed
    assert h.acceptance_rate() == 1.0


def test_rejected_burst_counts_all_drafts(qwen_setup):
    """A burst that ends by REJECTION (not EOS/budget) counts all k drafts
    as proposed — the rejected draft's unreached successors were honestly
    scored, and capping them at the emitted prefix would bias acceptance
    upward.  An always-wrong proposer must report acceptance exactly 0.0
    with k proposed per full burst, while staying token-exact (any-proposer
    exactness)."""
    from repro.serve.spec.proposers import Proposer, register_proposer

    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(9,))
    max_new, k = 5, 3
    _, base = _run(model, params, prompts, max_new=max_new)
    ref = base[0].tokens

    @register_proposer("_always_wrong")
    class AlwaysWrong(Proposer):
        def propose(self, decoding):
            drafts = np.zeros((self.engine.config.n_slots, self.spec.k),
                              np.int32)
            for r in decoding:
                # first draft != the token the engine will emit next
                drafts[r.slot, :] = (ref[len(r.tokens)] + 1) % cfg.vocab_size
            return drafts

    _, hs = _run(model, params, prompts, max_new=max_new,
                 spec=SpecConfig(k=k, proposer="_always_wrong"))
    h = hs[0]
    assert h.tokens == ref  # rejection never changes the emitted stream
    # every burst emits exactly 1 correction token: 3 full bursts (k proposed
    # each) + the final budget-stopped burst (1 emittable position)
    assert h.decode_calls == max_new - 1
    assert h.draft_proposed == k * (max_new - 2) + 1
    assert h.draft_accepted == 0
    assert h.acceptance_rate() == 0.0


# ---------------------------------------------------------------------------
# rollback invariants: monotone logical lengths, page reuse
# ---------------------------------------------------------------------------


def test_spec_rollback_monotone_and_bounded(qwen_setup):
    """Step the spec engine tick by tick: per-slot logical lengths never
    decrease, mapped pages always cover the logical length, and acceptance
    accounting stays within [0, proposed]."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(5, 11, 8))
    eng = Engine(model, params, EngineConfig(
        n_slots=3, max_len=32, page_size=8, kv_dtype="mxfp4", prefill_chunk=8,
        spec=SpecConfig(k=3, proposer="ngram")))
    handles = [eng.submit(p, 8) for p in prompts]
    logical_seen: dict[int, int] = {}
    while eng.sched.pending:
        eng.step()
        for req in eng.sched.decoding():
            logical = req.prompt_len + len(req.tokens) - 1
            assert logical >= logical_seen.get(req.rid, 0)  # monotone
            logical_seen[req.rid] = logical
            # pages mapped on the slot always cover the logical prefix
            assert (eng.cache.mapped_pages(req.slot) * eng.cache.page_size
                    >= logical)
            assert 0 <= req.draft_accepted <= req.draft_proposed
        # free list stays sorted descending through every truncate/ensure
        assert eng.cache._free == sorted(eng.cache._free, reverse=True)
    assert all(h.done for h in handles)
    agg = aggregate_stats(handles)
    assert 0.0 <= agg["acceptance_rate"] <= 1.0


def test_truncate_frees_trailing_pages_and_reuse():
    """PagedCache.truncate: frees only wholly-trailing pages, keeps the free
    list sorted, and the released pages are handed out again low-ids-first
    (page-reuse-after-rollback, extending the PR 3 ``free`` invariant)."""
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    cache = PagedCache(model, n_slots=2, pages_per_slot=4, page_size=4,
                       kv_dtype="dense")
    cache.alloc(0, 16)  # pages 1,2,3,4
    assert cache.tables[0].tolist() == [1, 2, 3, 4]
    # rollback to 9 tokens → pages covering 0..8 stay (3 pages), page 4 freed
    assert cache.truncate(0, 9) == 1
    assert cache.tables[0].tolist() == [1, 2, 3, 0]
    assert cache.mapped_pages(0) == 3
    assert cache._free == sorted(cache._free, reverse=True)
    # another slot grabs the freed page (lowest id first)
    cache.alloc(1, 4)
    assert cache.tables[1].tolist() == [4, 0, 0, 0]
    # re-extending slot 0 reuses the next lowest free id
    added = cache.ensure(0, 16)
    assert added == 1
    assert cache.tables[0].tolist() == [1, 2, 3, 5]
    # truncate to a page boundary frees nothing extra
    assert cache.truncate(0, 12) == 1 and cache.truncate(0, 12) == 0
    # ensure respects pages_per_slot
    with pytest.raises(ValueError):
        cache.ensure(0, 17)


def test_ensure_noop_when_covered():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    cache = PagedCache(model, n_slots=1, pages_per_slot=3, page_size=4,
                       kv_dtype="mxfp4")
    cache.alloc(0, 5)  # 2 pages
    free_before = cache.free_pages
    assert cache.ensure(0, 8) == 0  # already covered
    assert cache.free_pages == free_before
    assert cache.ensure(0, 9) == 1


# ---------------------------------------------------------------------------
# sampling: params, determinism, parity
# ---------------------------------------------------------------------------


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_temperature_zero_is_greedy(qwen_setup):
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(7, 10))
    _, base = _run(model, params, prompts)
    _, t0 = _run(model, params, prompts, sampling=SamplingParams())
    for b, s in zip(base, t0):
        assert s.tokens == b.tokens


def test_sampled_engine_matches_greedy_generate(qwen_setup):
    """Engine host sampling and the jitted greedy_generate sampling share
    per-token keys → identical streams for identical SamplingParams."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(7, 12))
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=11)
    _, hs = _run(model, params, prompts, sampling=sp)
    for p, h in zip(prompts, hs):
        ref = greedy_generate(model, params, jnp.asarray(p)[None], max_new=6,
                              max_len=int(p.size) + 6, sampling=sp)
        assert h.tokens == ref[0].tolist()
    # same seed → reproducible; different seed → (almost surely) different
    _, hs2 = _run(model, params, prompts, sampling=sp)
    assert [h.tokens for h in hs] == [h.tokens for h in hs2]
    _, hs3 = _run(model, params, prompts,
                  sampling=dataclasses.replace(sp, seed=12))
    assert [h.tokens for h in hs3] != [h.tokens for h in hs]


def test_sampled_self_spec_matches_nonspec(qwen_setup):
    """Rejection of sampled drafts: the verifier re-draws each position with
    its own key; with self-drafting the logits are bitwise equal, so sampled
    speculation reproduces the sampled non-speculative stream exactly."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(7, 12, 5))
    sp = SamplingParams(temperature=0.9, top_k=50, seed=5)
    _, base = _run(model, params, prompts, sampling=sp)
    _, spec_h = _run(model, params, prompts, sampling=sp,
                     spec=SpecConfig(k=3, proposer="self"))
    for b, s in zip(base, spec_h):
        assert s.tokens == b.tokens
        assert s.acceptance_rate() == 1.0


def test_top_k_one_is_argmax(qwen_setup):
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(9,))
    _, base = _run(model, params, prompts)
    _, hs = _run(model, params, prompts,
                 sampling=SamplingParams(temperature=1.3, top_k=1, seed=3))
    assert hs[0].tokens == base[0].tokens


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def test_plain_decode_accounting(qwen_setup):
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(7, 10))
    _, hs = _run(model, params, prompts)
    for h in hs:
        assert h.tokens_per_decode_call() == 1.0
        assert h.acceptance_rate() is None
        assert h.decode_calls == len(h.tokens) - 1
    agg = aggregate_stats(hs)
    assert agg["tokens_per_decode_call"] == 1.0
    assert agg["acceptance_rate"] is None
