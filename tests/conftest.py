"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real (single-CPU) device; only the dry-run
script sets 512 placeholder devices."""

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
