"""§Perf feature correctness: fp4-allgather path, bf16-exact QDQ, remat
policy, KV padding."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quartet import (
    QUARTET_CONFIG,
    QuartetConfig,
    quartet_linear,
    quartet_linear_pq,
    quest_qdq_gathered,
)


def test_fp4_allgather_forward_bit_identical():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.06
    qc = QuartetConfig(fp4_allgather=True)
    wv, wm = quest_qdq_gathered(w, qc)
    y_pq = quartet_linear_pq(x, wv, wm, jnp.uint32(3), qc)
    y = quartet_linear(x, w, jnp.uint32(3), QUARTET_CONFIG)
    np.testing.assert_array_equal(np.asarray(y_pq), np.asarray(y))


def test_fp4_allgather_grads_match():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 96)) * 0.08
    qc = QuartetConfig(fp4_allgather=True)

    def loss_pq(w):
        wv, wm = quest_qdq_gathered(w, qc)
        return jnp.sum(quartet_linear_pq(x, wv, wm, jnp.uint32(3), qc) ** 2)

    def loss_ref(w):
        return jnp.sum(quartet_linear(x, w, jnp.uint32(3), QUARTET_CONFIG) ** 2)

    g_pq = jax.grad(loss_pq)(w)
    g_ref = jax.grad(loss_ref)(w)
    # same algorithm & seeds → identical backward
    np.testing.assert_allclose(np.asarray(g_pq), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


def test_qdq_values_are_bf16_exact():
    """E2M1 value × E8M0 scale has ≤2 mantissa bits — bf16 must be lossless."""
    from repro.core import quantizers as Q
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 64)) * 3
    v = Q.quest(x).values
    np.testing.assert_array_equal(
        np.asarray(v), np.asarray(v.astype(jnp.bfloat16).astype(jnp.float32)))


def test_expert_ffn_fp4_allgather_path():
    import repro.models.moe as MOE
    from repro.configs import get_reduced_config
    cfg = get_reduced_config("qwen3-moe-235b-a22b")
    cfg4 = dataclasses.replace(cfg, quartet=QuartetConfig(fp4_allgather=True))
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe_ffn(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model), jnp.float32)
    y0, aux0 = MOE.moe_ffn(p, x, jnp.uint32(1), cfg)
    y1, aux1 = MOE.moe_ffn(p, x, jnp.uint32(1), cfg4)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-3)


def test_remat_policy_dots_same_numerics():
    from repro.configs.llama_paper import tiny_llama
    from repro.models import build_model
    cfg = tiny_llama(d=64, layers=2, vocab=256)
    cfg_dots = dataclasses.replace(cfg, remat_policy="dots")
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (2, 32), 0, 256)

    def gnorm(c):
        model = build_model(c)
        params = model.init(key)
        def loss(p):
            logits, _, _ = model.forward(p, toks, jnp.uint32(1))
            return jnp.sum(logits**2) * 1e-6
        g = jax.grad(loss)(params)
        return float(sum(jnp.sum(x.astype(jnp.float32)**2)
                         for x in jax.tree.leaves(g)))

    assert abs(gnorm(cfg) - gnorm(cfg_dots)) < 1e-4 * max(gnorm(cfg), 1e-9)


def test_attention_kv_padding_exact():
    """Non-chunk-multiple KV lengths (1500-frame encoder) must give the same
    output as an unpadded single-chunk computation."""
    from repro.models.attention import blocked_attention
    key = jax.random.PRNGKey(0)
    B, S, T, H, hd = 2, 16, 150, 4, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_chunked = blocked_attention(q, k, v, pos, causal=False, kv_chunk=64)
    out_single = blocked_attention(q, k, v, pos, causal=False, kv_chunk=150)
    np.testing.assert_allclose(np.asarray(out_chunked, np.float32),
                               np.asarray(out_single, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_routing_ignores_padding_lanes():
    """Padding lanes must not compete for expert capacity: at a capacity-tight
    config, a garbage lane with a large router score would displace a real
    token from an expert's top-c selection — so the valid lanes' outputs would
    depend on what happens to sit in the padding.  With ``token_valid`` the
    result on valid lanes must be bit-identical whatever the padding holds."""
    from repro.configs import get_reduced_config
    from repro.models.moe import init_moe_ffn, moe_capacity, moe_ffn

    cfg = dataclasses.replace(get_reduced_config("qwen3-moe-235b-a22b"),
                              capacity_factor=0.25)
    B, S = 2, 256  # row 1 is all padding
    c = moe_capacity(cfg, B * S)
    # the config must actually be capacity-bound for the test to mean anything
    assert c < S * cfg.experts_per_token / cfg.num_experts * 2
    params = init_moe_ffn(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x_valid = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model),
                                jnp.bfloat16)
    mask = jnp.concatenate([jnp.ones((1, S), bool), jnp.zeros((1, S), bool)])

    def run(pad_key, token_valid):
        # large-amplitude garbage: wins router top-c whenever it may compete
        pad = 100.0 * jax.random.normal(pad_key, (1, S, cfg.d_model),
                                        jnp.bfloat16)
        x = jnp.concatenate([x_valid, pad])
        y, _ = moe_ffn(params, x, jnp.uint32(7), cfg,
                       token_valid=token_valid)
        return np.asarray(y[0], np.float32)

    y_a = run(jax.random.PRNGKey(2), mask)
    y_b = run(jax.random.PRNGKey(3), mask)
    np.testing.assert_array_equal(y_a, y_b)
    # regression guard: without the mask the garbage lanes DO perturb routing
    # here (that was the bug) — if this stops failing, the config is no longer
    # capacity-tight and the test above has lost its teeth
    y_a_unmasked = run(jax.random.PRNGKey(2), None)
    y_b_unmasked = run(jax.random.PRNGKey(3), None)
    assert not np.array_equal(y_a_unmasked, y_b_unmasked)
