"""Hadamard transform invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import hadamard as H


@given(st.sampled_from([2, 4, 8, 16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_hadamard_matrix_orthogonal_involutory(g):
    h = H.hadamard_matrix(g)
    np.testing.assert_allclose(h @ h, np.eye(g), atol=1e-5)
    np.testing.assert_allclose(h, h.T, atol=1e-7)


def test_transform_preserves_norm_and_inverts():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    xh = H.hadamard_transform(x, g=32)
    assert abs(float(jnp.linalg.norm(xh)) - float(jnp.linalg.norm(x))) < 1e-3
    back = H.inverse_hadamard_transform(xh, g=32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


def test_randomized_transform_product_exact():
    """(X Ĥ)(Ĥᵀ Wᵀ)ᵀ == X Wᵀ — shared signs keep the GEMM exact."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (16, 64))
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    signs = jax.random.rademacher(jax.random.PRNGKey(3), (64,), dtype=jnp.float32)
    xh = H.randomized_hadamard_transform(x, signs)
    wh = H.randomized_hadamard_transform(w, signs)
    np.testing.assert_allclose(np.asarray(xh @ wh.T), np.asarray(x @ w.T),
                               rtol=1e-4, atol=1e-4)


def test_randomized_inverse():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96))
    signs = jax.random.rademacher(jax.random.PRNGKey(1), (96,), dtype=jnp.float32)
    y = H.randomized_hadamard_transform(x, signs)
    back = H.inverse_randomized_hadamard_transform(y, signs)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


def test_axis_argument():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 5))
    y0 = H.hadamard_transform(x, g=32, axis=0)
    y1 = H.hadamard_transform(x.T, g=32, axis=1).T
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
