"""Format-level invariants: grids, E8M0 scales, rounding semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import formats as F

E2M1_VALUES = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def test_mxfp4_grid_matches_spec():
    pos = [v for v in F.MXFP4.grid if v >= 0]
    assert pos == E2M1_VALUES
    assert F.MXFP4.block == 32 and F.MXFP4.scale_dtype == "e8m0"
    assert F.MXFP4.bits == 4


def test_rtn_matches_native_fp4_cast():
    """Our generic grid RTN must agree with jnp.float4_e2m1fn off ties."""
    x = np.linspace(-7, 7, 4001).astype(np.float32)
    ours = np.asarray(F.rtn_to_grid(jnp.asarray(x), F.MXFP4.grid_array))
    native = np.asarray(jnp.asarray(x).astype(jnp.float4_e2m1fn).astype(jnp.float32))
    mids = {0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0}
    off_tie = ~np.isin(np.abs(x), list(mids))
    np.testing.assert_array_equal(ours[off_tie], native[off_tie])


def test_exp2i_exact():
    e = jnp.arange(-126, 128)
    got = np.asarray(F.exp2i(e), np.float64)
    want = np.exp2(np.arange(-126, 128, dtype=np.float64))
    np.testing.assert_array_equal(got, want.astype(np.float32))


@given(st.floats(1e-30, 1e30))
@settings(max_examples=200, deadline=None)
def test_e8m0_ceil_bounds(s):
    q = float(F.round_scale_e8m0(jnp.float32(s), "ceil"))
    assert q >= np.float32(s) * (1 - 1e-6) or q == 2.0**127
    assert q / 2 < np.float32(s) * (1 + 1e-5) or q == 2.0**-126
    assert np.log2(q) == int(np.log2(q))  # exact power of two


def test_e8m0_code_roundtrip():
    scales = F.exp2i(jnp.arange(-126, 128))
    codes = F.scale_to_e8m0_code(scales)
    back = F.e8m0_code_to_scale(codes)
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(back))


def test_stochastic_round_stays_on_grid():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1024,)) * 3
    u = jax.random.uniform(jax.random.PRNGKey(1), (1024,))
    q = F.stochastic_round_to_grid(x, F.MXFP4.grid_array, u)
    grid = np.asarray(F.MXFP4.grid_array)
    assert np.isin(np.asarray(q), grid).all()


def test_stochastic_round_unbiased_interior():
    """E[SR(x)] == x exactly for in-range values (analytic, not MC)."""
    x = jnp.float32(2.4)  # between grid points 2 and 3
    us = jnp.linspace(0, 1, 10001)[:-1]
    q = F.stochastic_round_to_grid(jnp.full_like(us, x), F.MXFP4.grid_array, us)
    assert abs(float(q.mean()) - 2.4) < 1e-3


def test_gaussian_optimal_clip_sane():
    c = F.gaussian_optimal_clip("mxfp4")
    assert 2.0 < c < 4.0  # literature value ≈ 2.92 for E2M1


def test_blocks_roundtrip():
    x = jnp.arange(96.0).reshape(2, 48)
    xb = F.to_blocks(x, 16)
    assert xb.shape == (2, 3, 16)
    np.testing.assert_array_equal(np.asarray(F.from_blocks(xb)), np.asarray(x))
    with pytest.raises(ValueError):
        F.to_blocks(x, 32)
