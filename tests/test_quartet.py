"""Algorithm-1 level behaviour of quartet_linear."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quartet import (
    BF16_CONFIG,
    QUARTET_CONFIG,
    QuartetConfig,
    quartet_linear,
)

KEY = jax.random.PRNGKey(0)


def _xw(m=64, k=256, n=128, wscale=0.06):
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * wscale
    return x, w


def test_forward_relative_error_small():
    x, w = _xw()
    y = quartet_linear(x, w, jnp.uint32(1), QUARTET_CONFIG)
    ref = x @ w
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert rel < 0.25  # two MXFP4 quantizations ≈ sqrt(2·1.3e-2) each side


def test_bf16_config_is_exact():
    x, w = _xw()
    y = quartet_linear(x, w, jnp.uint32(1), BF16_CONFIG)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=2e-2, atol=1e-2)


def test_gradients_aligned_with_exact():
    x, w = _xw()

    def loss(x, w, cfg):
        return jnp.sum(quartet_linear(x, w, jnp.uint32(3), cfg) ** 2)

    gq = jax.grad(loss, (0, 1))(x, w, QUARTET_CONFIG)
    ge = jax.grad(lambda a, b: jnp.sum((a @ b) ** 2), (0, 1))(x, w)
    for a, b in zip(gq, ge):
        cos = float(jnp.vdot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
        assert cos > 0.9


def test_gradient_unbiasedness_of_sr_backward():
    """E[dW_quartet] ≈ dW of the quantized-forward function (the whole point
    of the SR backward).  MC over seeds; RTN backward shows a visible bias."""
    x, w = _xw(m=128, k=64, n=64, wscale=0.1)
    dy = jax.random.normal(jax.random.PRNGKey(7), (128, 64))

    def dw_of(cfg, seed):
        _, vjp = jax.vjp(lambda ww: quartet_linear(x, ww, seed, cfg), w)
        return vjp(dy)[0]

    seeds = jnp.arange(600, dtype=jnp.uint32)
    dws = jax.vmap(lambda s: dw_of(QUARTET_CONFIG, s))(seeds)
    dw_mean = dws.mean(0)
    # reference: backward of the *forward-quantized* linear without backward
    # quantization (unbiased target)
    cfg_ref = QuartetConfig(bwd_rounding="none", bwd_hadamard="none")
    dw_ref = dw_of(cfg_ref, jnp.uint32(0))
    rel = float(jnp.linalg.norm(dw_mean - dw_ref) / jnp.linalg.norm(dw_ref))
    assert rel < 0.08, rel


def test_non_divisible_output_dim():
    """N not divisible by 32 exercises the exact zero-padding path."""
    x = jax.random.normal(KEY, (64, 64))
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 72)) * 0.1
    g = jax.grad(lambda a, b: jnp.sum(quartet_linear(a, b, jnp.uint32(1),
                                                     QUARTET_CONFIG) ** 2),
                 argnums=(0, 1))(x, w)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in g)
    assert g[1].shape == (64, 72)


def test_zero_gradient_rows_finite():
    x, w = _xw()
    y, vjp = jax.vjp(lambda a, b: quartet_linear(a, b, jnp.uint32(1),
                                                 QUARTET_CONFIG), x, w)
    dy = jnp.zeros_like(y)
    dx, dw = vjp(dy)
    assert bool(jnp.all(jnp.isfinite(dx))) and bool(jnp.all(jnp.isfinite(dw)))
    np.testing.assert_allclose(np.asarray(dx), 0.0, atol=1e-6)


def test_batched_leading_dims():
    x = jax.random.normal(KEY, (2, 8, 4, 64))
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32)) * 0.1
    y = quartet_linear(x, w, jnp.uint32(1), QUARTET_CONFIG)
    assert y.shape == (2, 8, 4, 32)


def test_deterministic_given_seed():
    x, w = _xw()
    f = lambda: jax.grad(lambda a: jnp.sum(
        quartet_linear(a, w, jnp.uint32(42), QUARTET_CONFIG) ** 2))(x)
    np.testing.assert_array_equal(np.asarray(f()), np.asarray(f()))


def test_vmap_over_experts():
    """MoE uses vmap(quartet_linear) over stacked expert weights."""
    x = jax.random.normal(KEY, (4, 32, 64))
    w = jax.random.normal(jax.random.PRNGKey(4), (4, 64, 32)) * 0.1
    y = jax.vmap(lambda a, b: quartet_linear(a, b, jnp.uint32(1),
                                             QUARTET_CONFIG))(x, w)
    assert y.shape == (4, 32, 32)
    g = jax.grad(lambda ww: jnp.sum(jax.vmap(
        lambda a, b: quartet_linear(a, b, jnp.uint32(1), QUARTET_CONFIG)
    )(x, ww) ** 2))(w)
    assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("method", ["luq_int4", "luq_fp4", "jetfire_fp4",
                                    "halo_fp4", "lss_int4"])
def test_baselines_run_and_differentiable(method):
    from repro.core.baselines import baseline_linear
    x, w = _xw(m=64, k=128, n=64)
    g = jax.grad(lambda a, b: jnp.sum(
        baseline_linear(a, b, jnp.uint32(2), method) ** 2), (0, 1))(x, w)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in g)
    assert float(jnp.linalg.norm(g[0])) > 0
