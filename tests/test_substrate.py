"""Substrate tests: optimizer(s), schedule, clipping, checkpointing, data
pipeline determinism, gradient compression, straggler monitor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
from repro.optim import (
    adamw,
    adamw8bit,
    clip_by_global_norm,
    compress_decompress_gradient,
    cosine_warmup,
)
from repro.optim.adamw import apply_updates
from repro.train.straggler import StragglerMonitor


def _quad_problem(opt, steps=300):
    """Minimize ||x - t||² with the optimizer under test."""
    t = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    params = {"x": jnp.zeros((64,))}
    state = opt.init(params)
    for _ in range(steps):
        g = {"x": 2 * (params["x"] - t)}
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(jnp.linalg.norm(params["x"] - t))


def test_adamw_converges():
    assert _quad_problem(adamw(1e-1, weight_decay=0.0)) < 0.05


def test_adamw8bit_converges_close_to_fp32():
    err8 = _quad_problem(adamw8bit(1e-1, weight_decay=0.0))
    err32 = _quad_problem(adamw(1e-1, weight_decay=0.0))
    assert err8 < max(5 * err32, 0.15)


def test_adamw8bit_state_is_int8():
    opt = adamw8bit(1e-3)
    state = opt.init({"w": jnp.zeros((128, 300))})
    assert state["mu"]["w"]["q"].dtype == jnp.int8
    # blocked along the last axis, leading dims preserved (sharding-safe)
    assert state["mu"]["w"]["q"].shape == (128, 2, 256)
    assert state["mu"]["w"]["s"].shape == (128, 2)


def test_cosine_warmup_shape():
    lr = cosine_warmup(1e-3, 1000, warmup_frac=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(100)) - 1e-3) < 1e-9  # peak at end of warmup
    assert float(lr(1000)) < 1e-5
    assert float(lr(50)) < float(lr(100))


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(1000)) < 1e-3
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_grad_compression_error_feedback():
    """Over many steps the accumulated compressed sum tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for i in range(50):
        ghat, err = compress_decompress_gradient(g_true, err, jax.random.PRNGKey(i))
        acc = acc + ghat
    rel = float(jnp.linalg.norm(acc - 50 * g_true) / jnp.linalg.norm(50 * g_true))
    assert rel < 0.01


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    state = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(7),
             "nested": {"b": jnp.ones((2,), jnp.bfloat16)}}
    for s in [10, 20, 30]:
        ckpt.save(s, state, blocking=True)
    assert ckpt.all_steps() == [20, 30]  # keep=2 retention
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, meta = ckpt.restore(like)
    assert meta["step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomicity(tmp_path):
    """A crashed (partial) write must be invisible to readers."""
    ckpt = Checkpointer(str(tmp_path))
    os.makedirs(tmp_path / "step_000000099.tmp")  # simulated crash leftovers
    (tmp_path / "step_000000099.tmp" / "0.npy").write_bytes(b"garbage")
    assert ckpt.all_steps() == []
    state = {"w": jnp.ones((4,))}
    ckpt.save(5, state, blocking=True)
    assert ckpt.all_steps() == [5]
    assert not (tmp_path / "step_000000099.tmp").exists()  # GC'd


def test_async_checkpoint(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, {"w": jnp.ones((1000, 100))})
    ckpt.wait()
    assert ckpt.all_steps() == [1]


def test_data_pipeline_determinism_and_sharding():
    ds = SyntheticC4Dataset(vocab_size=1000, seed=3)
    b0 = TokenBatcher(ds, global_batch=8, seq_len=32)
    a = b0.batch(5)
    b = b0.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # two hosts each take disjoint halves that concatenate to the global batch
    h0 = TokenBatcher(ds, 8, 32, host_index=0, host_count=2).batch(5)
    h1 = TokenBatcher(ds, 8, 32, host_index=1, host_count=2).batch(5)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), a["tokens"])


def test_synthetic_data_has_learnable_structure():
    """Topic-block structure ⇒ within-block entropy ≪ global entropy
    (a context-aware model predicts in ~log(topic_vocab) bits)."""
    V = 4096
    ds = SyntheticC4Dataset(vocab_size=V, seed=0)
    toks = ds.slice(0, 256 * ds.BLOCK)

    def entropy(t):
        c = np.bincount(t, minlength=V).astype(np.float64)
        p = c[c > 0] / c.sum()
        return -(p * np.log(p)).sum()

    h_global = entropy(toks)
    blocks = toks.reshape(-1, ds.BLOCK)
    h_within = np.mean([entropy(b) for b in blocks])
    assert h_within < 0.75 * h_global, (h_within, h_global)


def test_straggler_monitor():
    mon = StragglerMonitor(ewma_alpha=0.5)
    for i in range(10):
        assert mon.observe(i, 1.0)["status"] == "ok"
    assert mon.observe(10, 4.0)["status"] == "straggler"
    assert mon.observe(11, 50.0)["status"] == "hang"
    assert mon.observe(12, 1.0)["status"] == "ok"
    assert mon.straggler_steps == 1 and mon.hang_steps == 1
