"""Elastic restore: a checkpoint written under one configuration restores
onto a different device layout (leaves are stored unsharded; placement is
re-derived at restore — the scale-up/down restart path)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.llama_paper import tiny_llama
from repro.models import build_model
from repro.optim import adamw
from repro.train.state import make_train_state


def test_restore_with_explicit_shardings(tmp_path):
    cfg = tiny_llama(d=64, layers=2, vocab=256)
    model = build_model(cfg)
    opt = adamw(1e-3)
    state = make_train_state(model.init(jax.random.PRNGKey(0)), opt)
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(3, state, blocking=True)

    # restore with explicit per-leaf shardings (single device here; on a new
    # mesh these would be NamedShardings from distributed.sharding)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    shardings = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), state)
    restored, meta = ckpt.restore(like, shardings=shardings)
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_detects_structure_mismatch(tmp_path):
    cfg = tiny_llama(d=64, layers=2, vocab=256)
    model = build_model(cfg)
    opt = adamw(1e-3)
    state = make_train_state(model.init(jax.random.PRNGKey(0)), opt)
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, state, blocking=True)

    wrong = make_train_state(
        build_model(tiny_llama(d=64, layers=3, vocab=256)).init(
            jax.random.PRNGKey(0)), opt)
    import pytest
    with pytest.raises(AssertionError):
        ckpt.restore(wrong)
