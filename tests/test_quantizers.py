"""Quantizer properties: grid membership, scale correctness, SR unbiasedness,
QuEST masks, the paper's Table-2 metric reproduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import formats as F
from repro.core import metrics as M
from repro.core import quantizers as Q

GRID = np.asarray(F.MXFP4.grid_array)


def _on_grid(values, scales, block=32):
    v = np.asarray(values).reshape(-1, block)
    s = np.asarray(scales).reshape(-1, 1)
    codes = v / s
    return np.all(np.isin(codes.round(4), GRID.round(4)))


@given(hnp.arrays(np.float32, (8, 64),
                  elements=st.floats(-100, 100, width=32, allow_nan=False)))
@settings(max_examples=50, deadline=None)
def test_rtn_absmax_on_grid_and_no_clip(x):
    r = Q.rtn_absmax(jnp.asarray(x), F.MXFP4)
    assert _on_grid(r.values, r.scales)
    assert bool(jnp.all(r.mask))  # ceil-mode absmax never clips
    # power-of-two scales
    s = np.asarray(r.scales)
    np.testing.assert_array_equal(np.log2(s), np.round(np.log2(s)))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_sr_absmax_on_grid(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 2.5
    r = Q.sr_absmax(x, jax.random.PRNGKey(seed + 1), F.MXFP4)
    assert _on_grid(r.values, r.scales)


def test_sr_unbiased_monte_carlo():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 1.7
    n = 4000
    vals = jax.vmap(lambda k: Q.sr_absmax(x, k).values)(
        jax.random.split(jax.random.PRNGKey(1), n))
    err = np.asarray(vals.mean(0) - x)
    # CLT bound: per-element sd ≤ gap/2 ≈ scale; 5σ tolerance
    scale = np.asarray(Q.sr_absmax(x, jax.random.PRNGKey(2)).scales).max()
    assert np.abs(err).max() < 5 * scale / np.sqrt(n) * 3


def test_sr_fast_unbiased_monte_carlo():
    """The counter-hash PRNG path must be unbiased too."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 1.7
    n = 4000
    vals = jax.vmap(lambda s: Q.sr_absmax_fast(x, s).values)(
        jnp.arange(n, dtype=jnp.uint32))
    err = np.asarray(vals.mean(0) - x)
    scale = np.asarray(Q.sr_absmax_fast(x, jnp.uint32(0)).scales).max()
    assert np.abs(err).max() < 5 * scale / np.sqrt(n) * 3


def test_quest_mask_marks_clipped():
    x = jnp.array([[0.1] * 31 + [100.0]], jnp.float32)  # one huge outlier
    r = Q.quest(x, F.MXFP4)
    m = np.asarray(r.mask)[0]
    assert not m[-1]  # the outlier is clipped -> gradient masked
    assert m[:-1].all()


def test_quest_beats_rtn_beats_sr_mse_on_gaussian():
    """Table 2's MSE ordering (QuEST < RTN < SR) on Gaussian data."""
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 32))
    mse = lambda r: float(jnp.mean((r.values - x) ** 2) / jnp.mean(x**2))
    m_quest = mse(Q.quest(x))
    m_rtn = mse(Q.rtn_absmax(x))
    m_sr = mse(Q.sr_absmax(x, jax.random.PRNGKey(1)))
    assert m_quest < m_rtn < m_sr
    # paper's Table-2 ballpark: 1.35e-2 / 1.40e-2 / 2.84e-2
    assert 0.011 < m_quest < 0.016
    assert 0.012 < m_rtn < 0.017
    assert 0.024 < m_sr < 0.034


def test_pma_table2_reproduction():
    """Misalignment (1 − E[1/S]): SR ≈ 0, RTN ≈ 1e-2, QuEST ≈ 1.3e-2."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    k = jax.random.PRNGKey(3)
    sr = float(M.pma_misalignment(x, "sr_absmax", k, num_samples=32))
    rtn = float(M.pma_misalignment(x, "rtn_absmax", k, num_samples=32))
    quest = float(M.pma_misalignment(x, "quest", k, num_samples=32))
    pma = float(M.pma_misalignment(x, "rtn_absmax_pma", k, num_samples=32))
    assert abs(sr) < 2e-3
    assert 5e-3 < rtn < 2e-2
    assert 8e-3 < quest < 2.2e-2
    assert abs(pma) < rtn / 2  # pseudo-unbiased correction works on average


def test_half_codes_dequantize():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 2
    r = Q.rtn_absmax(x, F.MXFP4)
    deq = (r.codes.astype(jnp.float32).reshape(4, 2, 32) * 0.5
           * r.scales[..., None]).reshape(4, 64)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(r.values), rtol=1e-6)


def test_nvfp4_and_mxfp8_variants():
    """Alternative hardware formats drive the same quantizer machinery."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    r16 = Q.quest(x, F.NVFP4)
    assert r16.scales.shape == (64, 4)  # block 16
    # E4M3 scales are not powers of two in general
    r8 = Q.quest(x, F.MXFP8)
    mse4 = float(jnp.mean((Q.quest(x, F.MXFP4).values - x) ** 2))
    mse16 = float(jnp.mean((r16.values - x) ** 2))
    mse8 = float(jnp.mean((r8.values - x) ** 2))
    assert mse8 < mse16 <= mse4 * 1.05  # finer scales/bits → lower error


def test_fastrng_uniformity():
    from repro.core import fastrng
    u = np.asarray(fastrng.uniform(jnp.uint32(7), (100_000,)))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.005
    assert abs(np.corrcoef(u[:-1], u[1:])[0, 1]) < 0.01


def test_fastrng_broadcasted_matches_flat_index():
    """Per-dim iota formulation must equal hashing the flat linear index."""
    from repro.core import fastrng
    a = np.asarray(fastrng.random_bits(jnp.uint32(3), (6, 8), salt=5))
    b = np.asarray(fastrng.random_bits(jnp.uint32(3), (48,), salt=5)).reshape(6, 8)
    np.testing.assert_array_equal(a, b)
