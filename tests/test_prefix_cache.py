"""Radix prefix cache over the packed pool: refcounts, COW, eviction, parity.

The tokens-level contract: enabling ``prefix_cache`` must be invisible —
identical emitted tokens to the non-sharing engine across pool dtypes,
decode backends, and speculative decoding — while admissions that share a
previously-served prefix alias its pages instead of re-prefilling them.
Sharing safety rests on copy-on-write: a shared page is copied before any
slot writes into it, so the cached payload never mutates underneath other
holders.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serve import Engine, EngineConfig, PagedCache, PrefixIndex, SpecConfig

pytestmark = pytest.mark.prefix

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _cache(model, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("kv_dtype", "dense")
    kw.setdefault("debug", True)
    return PagedCache(model, **kw)


# ---------------------------------------------------------------------------
# PagedCache refcounts + COW
# ---------------------------------------------------------------------------


def test_refcounted_alias_and_free(dense_setup):
    _, model, _ = dense_setup
    cache = _cache(model)
    total = cache.n_pages - 1
    cache.alloc(0, 9)  # 3 pages
    shared = [int(p) for p in cache.tables[0][:2]]
    cache.alloc(1, 12, shared=shared)  # alias 2, 1 fresh
    assert cache.free_pages == total - 4  # 4 physical pages live
    assert [int(p) for p in cache.tables[1][:2]] == shared
    assert all(int(cache.refcounts[p]) == 2 for p in shared)
    cache.free(0)
    # shared pages survive slot 0's retirement — slot 1 still maps them
    assert all(int(cache.refcounts[p]) == 1 for p in shared)
    assert cache.free_pages == total - 3
    cache.free(1)
    assert cache.free_pages == total
    cache.check_invariants()


def test_external_pin_keeps_page_alive(dense_setup):
    _, model, _ = dense_setup
    cache = _cache(model)
    total = cache.n_pages - 1
    cache.alloc(0, 4)
    pid = int(cache.tables[0][0])
    cache.ref_page(pid)  # the prefix index's pin
    cache.free(0)
    assert int(cache.refcounts[pid]) == 1  # pinned: not freed
    assert cache.free_pages == total - 1
    assert cache.unref_page(pid)  # last holder → page frees
    assert cache.free_pages == total
    with pytest.raises(ValueError):
        cache.unref_page(pid)  # no pin left to drop
    with pytest.raises(ValueError):
        cache.ref_page(pid)  # dead page cannot be pinned


def test_cow_copies_payload_and_remaps_writer(dense_setup):
    _, model, _ = dense_setup
    cache = _cache(model)
    cache.alloc(0, 8)  # 2 pages
    src = int(cache.tables[0][0])
    # stamp a recognizable payload into the shared page
    k = np.zeros(cache.pool["k"].shape, np.float32)
    k[:, src] = 7.0
    cache.pool = {**cache.pool, "k": jax.numpy.asarray(k, cache.pool["k"].dtype)}
    cache.alloc(1, 8, shared=[src])
    copied = cache.cow_range(1, 0, 3)  # slot 1 about to write tokens 0..2
    assert copied == 1
    dst = int(cache.tables[1][0])
    assert dst != src
    # writer remapped to a bit-identical copy; original refcount dropped to 1
    np.testing.assert_array_equal(
        np.asarray(cache.pool["k"][:, dst], np.float32),
        np.asarray(cache.pool["k"][:, src], np.float32))
    assert int(cache.refcounts[src]) == 1 and int(cache.refcounts[dst]) == 1
    # exclusively-owned pages pass through with no copy
    assert cache.cow_range(1, 0, 8) == 0
    cache.check_invariants()


def test_invariant_checker_catches_corruption(dense_setup):
    _, model, _ = dense_setup
    cache = _cache(model, debug=False)
    cache.alloc(0, 4)
    cache.check_invariants()
    # refcount drifts from table mappings + pins
    cache.refcounts[int(cache.tables[0][0])] += 1
    with pytest.raises(AssertionError, match="refcount mismatch"):
        cache.check_invariants()
    cache.refcounts[int(cache.tables[0][0])] -= 1
    # a freed page mapped by a slot (conservation violation)
    cache._free.append(int(cache.tables[0][0]))
    cache._free.sort(reverse=True)
    with pytest.raises(AssertionError):
        cache.check_invariants()


# ---------------------------------------------------------------------------
# radix index: insert / match / evict
# ---------------------------------------------------------------------------


def test_radix_insert_match_full_pages_only(dense_setup):
    _, model, _ = dense_setup
    cache = _cache(model)
    idx = PrefixIndex(page_size=4)
    toks = np.arange(10, dtype=np.int32)  # 2 full pages + 2-token tail
    cache.alloc(0, 10)
    assert idx.insert(cache, toks, cache.tables[0], stamp=1.0) == 2
    assert idx.cached_pages() == 2
    # full-prefix match, root-first page order
    assert idx.match(toks, 2.0) == [int(p) for p in cache.tables[0][:2]]
    # the partial tail page is never indexed
    assert idx.match(toks[:8], 2.0) == idx.match(toks, 2.0)
    # prefix-of-a-prefix matches the covered chain only
    assert idx.match(toks[:6], 2.0) == [int(cache.tables[0][0])]
    # same chunk under a DIFFERENT prefix must not match (KV at position p
    # depends on every position before it)
    other = np.concatenate([toks[4:8], toks[4:8]]).astype(np.int32)
    assert idx.match(other, 2.0) == []
    # re-inserting the same chain adds nothing and keeps the original pages
    cache.alloc(1, 8, shared=idx.match(toks, 3.0))
    assert idx.insert(cache, toks[:8], cache.tables[1], stamp=3.0) == 0


def test_radix_lru_eviction_and_exclude(dense_setup):
    _, model, _ = dense_setup
    cache = _cache(model, pages_per_slot=6, n_pages=13)
    idx = PrefixIndex(page_size=4)
    a = np.arange(8, dtype=np.int32)
    b = np.arange(100, 108, dtype=np.int32)
    cache.alloc(0, 8)
    idx.insert(cache, a, cache.tables[0], stamp=1.0)
    pages_a = idx.match(a, 1.0)
    cache.free(0)
    cache.alloc(0, 8)
    idx.insert(cache, b, cache.tables[0], stamp=2.0)
    pages_b = idx.match(b, 2.0)
    cache.free(0)
    assert idx.evictable_pages(cache) == 4
    assert idx.evictable_pages(cache, exclude=pages_a) == 2
    # chain a is older, but its LEAF (deepest page) goes first — ancestors
    # only become evictable once their children are gone
    idx.evict(cache, 1)
    assert idx.match(a, 3.0) == pages_a[:1]
    assert idx.match(b, 3.0) == pages_b
    # exclude pins chain a's remaining page: eviction must drain chain b
    freed = idx.evict(cache, 2, exclude=pages_a[:1])
    assert freed == 2
    assert idx.match(b, 4.0) == []
    assert idx.match(a, 4.0) == pages_a[:1]
    idx.evict(cache, cache.n_pages)
    assert idx.cached_pages() == 0
    assert cache.free_pages == cache.n_pages - 1


def test_evicting_mapped_page_frees_nothing_until_retire(dense_setup):
    _, model, _ = dense_setup
    cache = _cache(model)
    idx = PrefixIndex(page_size=4)
    toks = np.arange(4, dtype=np.int32)
    cache.alloc(0, 4)
    idx.insert(cache, toks, cache.tables[0], stamp=1.0)
    pid = int(cache.tables[0][0])
    # slot 0 still maps the page: eviction drops the pin but frees nothing
    assert idx.evict(cache, 1) == 0
    assert int(cache.refcounts[pid]) == 1
    cache.free(0)  # the slot was the last holder
    assert cache.free_pages == cache.n_pages - 1


# ---------------------------------------------------------------------------
# engine: warm-vs-cold token exactness, COW under decode / spec rollback
# ---------------------------------------------------------------------------


def _run_engine(model, params, prompts, *, kv, backend, spec=None,
                prefix=False, max_new=4, n_slots=2, page_size=8):
    eng = Engine(model, params, EngineConfig(
        n_slots=n_slots, max_len=48, page_size=page_size, kv_dtype=kv,
        prefill_chunk=page_size, decode_backend=backend,
        prefix_cache=prefix, debug_cache=True,
        spec=SpecConfig(k=3, proposer="self") if spec else None))
    out = []
    for wave in prompts:
        handles = [eng.submit(p, max_new) for p in wave]
        eng.drain()
        out.append([h.tokens for h in handles])
    return eng, out


def _shared_prefix_waves(cfg, page_size=8):
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, 2 * page_size).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
             for n in (3, 5)]
    # wave 0 publishes the prefix; wave 1 hits it — including a pure-prefix
    # prompt (full match → eager COW of the final shared page)
    return [[np.concatenate([prefix, tails[0]])],
            [prefix.copy(), np.concatenate([prefix, tails[1]])]]


@pytest.mark.parametrize("kv", ["dense", "mxfp4"])
@pytest.mark.parametrize("backend", ["paged", "gather"])
@pytest.mark.parametrize("spec", [False, True])
def test_warm_vs_cold_token_exact(dense_setup, kv, backend, spec):
    cfg, model, params = dense_setup
    waves = _shared_prefix_waves(cfg)
    warm_eng, warm = _run_engine(model, params, waves, kv=kv, backend=backend,
                                 spec=spec, prefix=True)
    _, cold = _run_engine(model, params, waves, kv=kv, backend=backend,
                          spec=spec, prefix=False)
    # the mxfp4 gather oracle attends over bf16 in-chunk KV and only sees
    # quantized values for PRIOR chunks, so its logits depend on the chunk
    # decomposition (documented carve-over from the batched-prefill PR) — and
    # a warm admission changes exactly that decomposition.  The paged backend
    # quantizes-on-write before attending and is decomposition-invariant, as
    # is any dense pool.
    if not (kv == "mxfp4" and backend == "gather"):
        assert warm == cold, (kv, backend, spec)
    # the warm engine must actually have shared pages, not coincidentally
    # produced the same tokens with cold admissions
    reg = warm_eng.telemetry.registry
    assert reg.counter("prefix_hit_requests").value >= 2
    assert reg.counter("prefix_cow_pages").value >= 1  # pure-prefix request
    warm_eng.cache.check_invariants()


def test_cached_payload_immutable_under_decode_and_spec(dense_setup):
    """COW keeps the published pages bit-stable: requests that alias the
    prefix (and then decode or speculatively roll back past it) must never
    mutate the cached payload other holders see."""
    cfg, model, params = dense_setup
    for spec in (False, True):
        waves = _shared_prefix_waves(cfg)
        eng, _ = _run_engine(model, params, waves[:1], kv="mxfp4",
                             backend="paged", spec=spec, prefix=True)
        pages = eng.prefix.match(waves[1][0], 0.0)
        assert len(pages) == 2
        before = {name: np.asarray(arr[:, pages])
                  for name, arr in eng.cache.pool.items()}
        for p in waves[1]:
            eng.submit(p, 6)
        eng.drain()
        assert eng.prefix.match(waves[1][0], 0.0) == pages
        for name, arr in eng.cache.pool.items():
            np.testing.assert_array_equal(before[name],
                                          np.asarray(arr[:, pages]),
                                          err_msg=f"{name} spec={spec}")
        eng.cache.check_invariants()


def test_eviction_under_pool_pressure(dense_setup):
    """A full radix index must not wedge admission: when fresh pages run out,
    the engine LRU-evicts cached prefixes to make room, and page conservation
    holds through the whole run."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(13)
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=16, page_size=4, kv_dtype="mxfp4",
        prefill_chunk=4, decode_backend="paged",
        prefix_cache=True, debug_cache=True))
    # distinct prompts: each retire publishes new pages until the index owns
    # most of the pool, forcing later admissions to evict
    handles = []
    for _ in range(6):
        handles.append(eng.submit(
            rng.integers(0, cfg.vocab_size, 9).astype(np.int32), 3))
        eng.drain()
    assert all(len(h.tokens) == 3 for h in handles)
    assert eng.telemetry.registry.counter("prefix_evicted_pages").value > 0
    cache = eng.cache
    cache.check_invariants()
    assert cache.live_pages() + cache.free_pages == cache.n_pages - 1
    # dropping the index releases every remaining page — nothing leaked
    eng.prefix.evict(cache, cache.n_pages)
    assert cache.free_pages == cache.n_pages - 1
