"""Fused MXFP4 paged-attention kernel: interpret-mode parity + engine wiring.

Parity contract (tests marked ``kernels``): over sweeps of page size, GQA
group size, ragged per-slot lengths, and pool dtype, the Pallas kernel must
match ``models.attention.blocked_attention`` run over the gathered
(dequantized) KV — token-exact in dense-pool mode (same values, same
online-softmax math), bit-close in mxfp4 mode (both paths read the identical
packed payload), and bounded-error vs the original unquantized values.

Engine contract: with ``kv_dtype="dense"`` the paged-kernel decode backend is
token-for-token identical to both the gather-dense oracle and sequential
``greedy_generate``; with ``kv_dtype="mxfp4"`` it stays within a log-prob
tolerance of the dense run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import quantizers as Q
from repro.kernels import paged_attention as PA
from repro.models import build_model
from repro.models.attention import blocked_attention

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# pool construction helpers
# ---------------------------------------------------------------------------


def _empty_pool(mode: str, n_pages: int, ps: int, Hkv: int, hd: int) -> dict:
    if mode == "dense":
        shape = (n_pages, ps, Hkv, hd)
        return {"k": jnp.zeros(shape, jnp.float32),
                "v": jnp.zeros(shape, jnp.float32)}
    nb = hd // PA.quant_block(hd)
    return {"k_codes": jnp.zeros((n_pages, ps, Hkv, hd // 2), jnp.uint8),
            "k_scales": jnp.zeros((n_pages, ps, Hkv, nb), jnp.uint8),
            "v_codes": jnp.zeros((n_pages, ps, Hkv, hd // 2), jnp.uint8),
            "v_scales": jnp.zeros((n_pages, ps, Hkv, nb), jnp.uint8)}


def _paged_setup(mode, lengths, ps, Hkv, hd, pages_per_slot, seed=0):
    """Random KV scattered token-by-token into a pool (quantize-on-write in
    mxfp4 mode) + page tables with low ids first — exactly the engine's
    write path.  Returns (pool, tables, k_dense, v_dense) where the dense
    arrays hold the values the pool effectively stores."""
    rng = np.random.default_rng(seed)
    B = len(lengths)
    T = pages_per_slot * ps
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32) * 1.5)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32) * 1.5)
    n_pages = 1 + B * pages_per_slot
    pool = _empty_pool(mode, n_pages, ps, Hkv, hd)
    tables = np.zeros((B, pages_per_slot), np.int32)
    nxt = 1
    for b in range(B):
        for p in range(-(-lengths[b] // ps)):  # only allocated pages mapped
            tables[b, p] = nxt
            nxt += 1
    tables = jnp.asarray(tables)
    for b in range(B):
        for t in range(lengths[b]):
            pool = PA.scatter_token(
                pool, tables[b, t // ps][None], jnp.array([t % ps]),
                k[b, t][None], v[b, t][None])
    if mode == "mxfp4":
        fmt = PA.quant_fmt(hd)
        k = Q.kv_dequantize(Q.kv_quantize(k, fmt), fmt)
        v = Q.kv_dequantize(Q.kv_quantize(v, fmt), fmt)
    return pool, tables, k, v


def _run_both(mode, lengths, ps, Hkv, group, hd=32, seed=0):
    pages_per_slot = max(-(-max(lengths) // ps), 2)
    pool, tables, k, v = _paged_setup(mode, lengths, ps, Hkv, hd,
                                      pages_per_slot, seed)
    B, Hq = len(lengths), Hkv * group
    rng = np.random.default_rng(seed + 99)
    q = jnp.asarray(rng.standard_normal((B, Hq, hd)).astype(np.float32))
    ln = jnp.asarray(np.asarray(lengths, np.int32))
    out = PA.paged_attention(q, pool, tables, ln)
    ref = blocked_attention(q[:, None], k, v, (ln - 1)[:, None],
                            causal=True, kv_chunk=ps)[:, 0]
    return out, ref, (q, k, v, ln)


# ---------------------------------------------------------------------------
# kernel parity sweeps (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.kernels
@pytest.mark.parametrize("ps", [4, 8, 16])
@pytest.mark.parametrize("group", [1, 2, 4])
def test_paged_kernel_parity_dense(ps, group):
    lengths = [7, 1, 2 * ps, ps + 3]  # ragged, incl. single-token + page-exact
    out, ref, _ = _run_both("dense", lengths, ps, Hkv=2, group=group)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


@pytest.mark.kernels
@pytest.mark.parametrize("ps", [4, 8])
@pytest.mark.parametrize("hd", [16, 32, 64])
def test_paged_kernel_parity_mxfp4(ps, hd):
    """mxfp4 pool: the kernel's in-tile dequant must reproduce the jnp
    dequantize-then-attend reference on the identical packed payload; the
    result must also stay close to attention over the original fp values."""
    lengths = [9, 3 * ps, 1]
    out, ref, _ = _run_both("mxfp4", lengths, ps, Hkv=2, group=2, hd=hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


@pytest.mark.kernels
@pytest.mark.parametrize("mode", ["dense", "mxfp4"])
@pytest.mark.parametrize("S", [2, 4])
def test_paged_kernel_multi_query_parity(mode, S):
    """Speculative-verify shape: S consecutive queries per slot with per-row
    causal bounds (row s at absolute position lengths[b]-1+s) must match the
    blocked reference with per-row positions over the same ragged batch."""
    ps, Hkv, group, hd = 4, 2, 2, 32
    lengths = [6, 1, 9]  # first-query visible lengths (ragged, incl. fresh slot)
    pages_per_slot = max(-(-(max(lengths) + S - 1) // ps), 2)
    written = [l + S - 1 for l in lengths]  # burst KV is written before reading
    pool, tables, k, v = _paged_setup(mode, written, ps, Hkv, hd,
                                      pages_per_slot, seed=7)
    B, Hq = len(lengths), Hkv * group
    rng = np.random.default_rng(77)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, hd)).astype(np.float32))
    ln = jnp.asarray(lengths, jnp.int32)
    out = PA.paged_attention(q, pool, tables, ln)
    pos = (ln[:, None] - 1) + jnp.arange(S)[None, :]
    ref = blocked_attention(q, k, v, pos, causal=True, kv_chunk=ps,
                            shared_mask=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)
    # the S == 1 fast path is the same kernel
    out1 = PA.paged_attention(q[:, 0], pool, tables, ln)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(ref[:, 0]),
                               rtol=0, atol=1e-5)


@pytest.mark.kernels
def test_prefill_chunk_layout_write_masking():
    """Valid tokens position onto their own pages; padding of active rows
    lands exactly on the appended all-zero sentinel column; inactive lanes
    sit at position 0 of a zeroed row — every masked write resolves to the
    scratch page."""
    ps, P, C = 4, 3, 5
    tables = jnp.asarray([[1, 2, 3], [4, 5, 0], [7, 8, 9]], jnp.int32)
    mask = jnp.asarray([True, True, False])
    tables = jnp.where(mask[:, None], tables, 0)  # engine zeroes masked rows
    start = jnp.asarray([4, 0, 2], jnp.int32)
    n_valid = jnp.asarray([5, 2, 3], jnp.int32)
    tbl_ext, pos = PA.prefill_chunk_layout(tables, start, n_valid, C, ps, mask)
    assert tbl_ext.shape == (3, P + 1)
    assert bool(jnp.all(tbl_ext[:, -1] == 0))  # sentinel column
    np.testing.assert_array_equal(np.asarray(pos[0]), [4, 5, 6, 7, 8])
    np.testing.assert_array_equal(np.asarray(pos[1]), [0, 1, 12, 12, 12])
    np.testing.assert_array_equal(np.asarray(pos[2]), [0, 0, 0, 0, 0])
    # every position's page lookup: padding/inactive → page 0 (scratch)
    page_ids = np.asarray(tbl_ext)[np.arange(3)[:, None], np.asarray(pos) // ps]
    np.testing.assert_array_equal(page_ids[0], [2, 2, 2, 2, 3])
    np.testing.assert_array_equal(page_ids[1], [4, 4, 0, 0, 0])
    np.testing.assert_array_equal(page_ids[2], [0, 0, 0, 0, 0])


@pytest.mark.kernels
@pytest.mark.parametrize("mode", ["dense", "mxfp4"])
def test_paged_kernel_batched_prefill_parity(mode):
    """Batched-prefill shape: C queries per slot at per-slot start offsets
    with ragged valid counts.  Valid rows must match the blocked reference
    with per-row positions; padding rows scatter only to the scratch page
    (every real pool page is bit-identical to a run that wrote valid tokens
    only)."""
    ps, Hkv, group, hd, C = 4, 2, 2, 32, 6
    starts = [4, 0, 9]
    n_valid = [6, 3, 1]  # full chunk / ragged tail / single-token remainder
    B = len(starts)
    written = [s + n for s, n in zip(starts, n_valid)]
    pages_per_slot = max(-(-max(written) // ps) + 1, 2)
    rng = np.random.default_rng(21)
    T = pages_per_slot * ps
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32))

    # context prefix (positions < start) written token-by-token, engine-style
    n_pages = 1 + B * pages_per_slot
    pool = _empty_pool(mode, n_pages, ps, Hkv, hd)
    tables = np.zeros((B, pages_per_slot), np.int32)
    nxt = 1
    for b in range(B):
        for p in range(-(-written[b] // ps)):
            tables[b, p] = nxt
            nxt += 1
    tables = jnp.asarray(tables)
    for b in range(B):
        for t in range(starts[b]):
            pool = PA.scatter_token(pool, tables[b, t // ps][None],
                                    jnp.array([t % ps]), k[b, t][None], v[b, t][None])

    # the chunk itself goes through the batched layout: padding tokens carry
    # garbage K/V that must only ever reach the scratch page
    mask = jnp.asarray([True] * B)
    start_j = jnp.asarray(starts, jnp.int32)
    nv_j = jnp.asarray(n_valid, jnp.int32)
    tbl_ext, positions = PA.prefill_chunk_layout(tables, start_j, nv_j, C, ps, mask)
    ck = np.asarray(rng.standard_normal((B, C, Hkv, hd)), np.float32)
    cv = np.asarray(rng.standard_normal((B, C, Hkv, hd)), np.float32)
    for b in range(B):  # place the chunk's real K/V into the dense reference
        for s in range(n_valid[b]):
            k = k.at[b, starts[b] + s].set(ck[b, s])
            v = v.at[b, starts[b] + s].set(cv[b, s])
    page_ids = tbl_ext[jnp.arange(B)[:, None], positions // ps]
    pool = PA.scatter_token(pool, page_ids, positions % ps,
                            jnp.asarray(ck), jnp.asarray(cv))

    # write-masking conservation: non-scratch pages match a valid-only write
    pool_ref = _empty_pool(mode, n_pages, ps, Hkv, hd)
    for b in range(B):
        for t in range(written[b]):
            pool_ref = PA.scatter_token(pool_ref, tables[b, t // ps][None],
                                        jnp.array([t % ps]), k[b, t][None],
                                        v[b, t][None])
    for key in pool:
        np.testing.assert_array_equal(np.asarray(pool[key][1:]),
                                      np.asarray(pool_ref[key][1:]))

    if mode == "mxfp4":
        fmt = PA.quant_fmt(hd)
        k = Q.kv_dequantize(Q.kv_quantize(k, fmt), fmt)
        v = Q.kv_dequantize(Q.kv_quantize(v, fmt), fmt)
    q = jnp.asarray(rng.standard_normal((B, C, Hkv * group, hd)), jnp.float32)
    lengths = start_j + 1  # tokens visible to each slot's FIRST chunk row
    out = PA.paged_attention(q, pool, tbl_ext, lengths)
    pos_ref = start_j[:, None] + jnp.arange(C)[None, :]
    ref = blocked_attention(q, k, v, pos_ref, causal=True, kv_chunk=ps,
                            shared_mask=False)
    for b in range(B):  # padding rows are garbage by design — compare valid
        np.testing.assert_allclose(np.asarray(out[b, :n_valid[b]]),
                                   np.asarray(ref[b, :n_valid[b]]),
                                   rtol=0, atol=1e-5)


@pytest.mark.kernels
def test_paged_kernel_mxfp4_bounded_vs_fp():
    """End-to-end quantization error: paged attention over the packed pool
    vs blocked attention over the *original* (unquantized) KV."""
    ps, Hkv, group, hd = 8, 2, 2, 32
    lengths = [13, 25]
    pages_per_slot = 4
    pool, tables, kq, vq = _paged_setup("mxfp4", lengths, ps, Hkv, hd,
                                        pages_per_slot, seed=3)
    # rebuild the original fp values with the same rng stream
    rng = np.random.default_rng(3)
    B, T = len(lengths), pages_per_slot * ps
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32) * 1.5)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32) * 1.5)
    q = jnp.asarray(np.random.default_rng(4).standard_normal((B, Hkv * group, hd)),
                    jnp.float32)
    ln = jnp.asarray(lengths, jnp.int32)
    out = PA.paged_attention(q, pool, tables, ln)
    ref_fp = blocked_attention(q[:, None], k, v, (ln - 1)[:, None],
                               causal=True, kv_chunk=ps)[:, 0]
    err = float(jnp.max(jnp.abs(out - ref_fp)))
    # bounded, not exact: E2M1 grid error on K shifts softmax weights and V
    # rows carry ~2^-2 relative error — observed ≈1.1 max over this workload
    assert err < 1.5, err


@pytest.mark.kernels
def test_paged_kernel_ignores_unmapped_pages():
    """Table rows past the valid length point at the scratch page (id 0);
    whatever it contains must not leak into the output."""
    ps, Hkv, group, hd = 4, 2, 2, 32
    lengths = [5, 2]
    pool, tables, k, v = _paged_setup("dense", lengths, ps, Hkv, hd, 4, seed=1)
    # poison the scratch page
    pool["k"] = pool["k"].at[0].set(1e3)
    pool["v"] = pool["v"].at[0].set(1e3)
    q = jnp.asarray(np.random.default_rng(2).standard_normal((2, Hkv * group, hd)),
                    jnp.float32)
    ln = jnp.asarray(lengths, jnp.int32)
    out = PA.paged_attention(q, pool, tables, ln)
    ref = blocked_attention(q[:, None], k, v, (ln - 1)[:, None],
                            causal=True, kv_chunk=ps)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


@pytest.mark.kernels
@pytest.mark.parametrize("hq,hkv", [(4, 2), (8, 2), (6, 3), (4, 4)])
def test_flash_gqa_in_place(hq, hkv):
    """mha_flash maps query-head → KV-head in the block index map: no
    group×-materialized KV (satellite fix), same outputs as the reference."""
    from repro.kernels.flash_attention import mha_flash

    rng = np.random.default_rng(0)
    B, S, hd = 2, 24, 32
    q = jnp.asarray(rng.standard_normal((B, S, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, hkv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for causal in (True, False):
        o1 = mha_flash(q, k, v, causal=causal, block_q=8, block_k=8)
        o2 = blocked_attention(q, k, v, pos, causal=causal, kv_chunk=8)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# engine integration: paged-kernel decode backend
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen_setup():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _run_engine(model, params, prompts, max_new, kv, backend, n_slots=3):
    from repro.serve import Engine, EngineConfig

    eng = Engine(model, params, EngineConfig(
        n_slots=n_slots, max_len=32, page_size=8, kv_dtype=kv,
        prefill_chunk=8, keep_logits=True, decode_backend=backend))
    handles = [eng.submit(p, max_new) for p in prompts]
    eng.drain()
    return eng, handles


def test_engine_paged_decode_token_exact_dense(qwen_setup):
    """decode_backend="paged" == "gather" == sequential greedy, dense pool."""
    from repro.train.serve import greedy_generate

    cfg, model, params = qwen_setup
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 12)]
    _, paged_h = _run_engine(model, params, prompts, 4, "dense", "paged")
    _, gather_h = _run_engine(model, params, prompts, 4, "dense", "gather")
    for p, hp, hg in zip(prompts, paged_h, gather_h):
        assert hp.tokens == hg.tokens
        ref = greedy_generate(model, params, jnp.asarray(p)[None], max_new=4,
                              max_len=int(p.size) + 4)
        assert hp.tokens == ref[0].tolist()


def test_engine_paged_decode_mxfp4_bounded(qwen_setup):
    """mxfp4 paged-kernel decode stays close to the dense-cache run (the
    self-token is quantized on write before it attends to itself, so this is
    a slightly stronger quantization than the gather oracle applies)."""
    cfg, model, params = qwen_setup
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    _, dense_h = _run_engine(model, params, [prompt], 4, "dense", "paged")
    _, fp4_h = _run_engine(model, params, [prompt], 4, "mxfp4", "paged")
    d0 = np.asarray(jax.nn.log_softmax(dense_h[0].logits_trace[0]))
    q0 = np.asarray(jax.nn.log_softmax(fp4_h[0].logits_trace[0]))
    assert np.max(np.abs(d0 - q0)) < 2.5
    assert np.mean(np.abs(d0 - q0)) < 0.5


def test_engine_moe_paged_decode_token_exact_dense():
    """MoE layers route through the same attention dispatch — paged decode
    must stay token-exact vs the gather oracle in dense mode."""
    cfg = get_reduced_config("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, 9).astype(np.int32)]
    _, paged_h = _run_engine(model, params, prompts, 3, "dense", "paged")
    _, gather_h = _run_engine(model, params, prompts, 3, "dense", "gather")
    assert paged_h[0].tokens == gather_h[0].tokens


# ---------------------------------------------------------------------------
# allocator: free() restores the low-ids-first contract (satellite fix)
# ---------------------------------------------------------------------------


def test_free_list_low_ids_first_after_out_of_order_retire():
    from repro.serve import PagedCache

    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    cache = PagedCache(model, n_slots=3, pages_per_slot=2, page_size=4,
                       kv_dtype="dense")
    cache.alloc(0, 8)   # pages 1, 2
    cache.alloc(1, 8)   # pages 3, 4
    cache.alloc(2, 4)   # page 5
    assert cache.tables[0].tolist() == [1, 2]
    cache.free(2)       # out-of-order retirement …
    cache.free(0)       # … returns 5 then {1, 2}
    # pop() must hand out low ids first regardless of retirement order
    cache.alloc(2, 8)
    assert cache.tables[2].tolist() == [1, 2]
    cache.alloc(0, 4)
    assert cache.tables[0].tolist() == [5, 0]
    # invariant: the free list stays descending so pop() is always the min
    assert cache._free == sorted(cache._free, reverse=True)
