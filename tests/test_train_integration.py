"""Integration: the full training loop (loss goes down, resume is exact),
microbatching equivalence, serving round-trip, roofline analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.llama_paper import tiny_llama
from repro.data.pipeline import SyntheticC4Dataset, TokenBatcher
from repro.models import build_model
from repro.optim import adamw, cosine_warmup
from repro.train.loop import train
from repro.train.state import make_train_state
from repro.train.steps import make_train_step


def _setup(d=64, layers=2, vocab=256):
    cfg = tiny_llama(d=d, layers=layers, vocab=vocab)
    model = build_model(cfg)
    ds = SyntheticC4Dataset(vocab_size=vocab, seed=1)
    batcher = TokenBatcher(ds, global_batch=8, seq_len=64)
    return cfg, model, batcher


def test_quartet_training_reduces_loss():
    cfg, model, batcher = _setup()
    opt = adamw(cosine_warmup(3e-3, 30), weight_decay=0.0)
    _, hist = train(model, opt, batcher, 30, log_every=0, checkpoint_dir=None)
    first = np.mean([h["loss"] for h in hist[:4]])
    last = np.mean([h["loss"] for h in hist[-4:]])
    # 0.15: on CPU jax 0.4.x this 30-step run lands at ≈ −0.19 (−0.25+ on
    # the original calibration environment); margin stays well above the
    # ~0.03 window-to-window noise of the loss trace
    assert last < first - 0.15, (first, last)


def test_resume_is_bit_exact(tmp_path):
    cfg, model, batcher = _setup()
    opt = adamw(cosine_warmup(1e-3, 20), weight_decay=0.0)
    sA, _ = train(model, opt, batcher, 12, log_every=0,
                  checkpoint_dir=str(tmp_path / "a"), checkpoint_every=6)
    # second run: interrupted at 6 (simulated by fresh call resuming from ckpt)
    train(model, opt, batcher, 6, log_every=0,
          checkpoint_dir=str(tmp_path / "b"), checkpoint_every=6)
    sB, _ = train(model, opt, batcher, 12, log_every=0,
                  checkpoint_dir=str(tmp_path / "b"), checkpoint_every=6)
    for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_microbatch_grads_match_full_batch():
    """mb=2 accumulation ≡ full-batch gradients when the per-microbatch seeds
    are fixed — here we check the bf16 (deterministic) method exactly."""
    cfg, model, batcher = _setup()
    opt = adamw(1e-3)
    params = model.init(jax.random.PRNGKey(0))
    state = make_train_state(params, opt)
    batch = {k: jnp.asarray(v) for k, v in batcher.batch(0).items()}

    s1 = make_train_step(model, opt, method="bf16", microbatch=1)
    s2 = make_train_step(model, opt, method="bf16", microbatch=2)
    st1, m1 = jax.jit(s1)(state, batch)
    state2 = make_train_state(params, opt)
    st2, m2 = jax.jit(s2)(state2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)


def test_grad_compress_training_still_learns():
    cfg, model, batcher = _setup()
    opt = adamw(cosine_warmup(3e-3, 25), weight_decay=0.0)
    _, hist = train(model, opt, batcher, 25, log_every=0, grad_compress=True)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.15


def test_greedy_generate_roundtrip():
    from repro.train.serve import greedy_generate
    cfg, model, _ = _setup()
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = greedy_generate(model, params, prompt, max_new=6, max_len=16)
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_chunked_loss_matches_unchunked():
    from repro.train.losses import chunked_lm_loss, cross_entropy_loss
    cfg, model, batcher = _setup()
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in batcher.batch(0).items()}
    feats, _, _ = model.forward(params, batch["tokens"], jnp.uint32(1),
                                features_only=True, method="bf16")
    logits = model.head(params, feats, jnp.uint32(1), "bf16")
    full, _ = cross_entropy_loss(logits, batch["labels"])
    chunked, _ = chunked_lm_loss(model.head, params, feats, batch["labels"],
                                 jnp.uint32(1), chunk=16, method="bf16")
    assert abs(float(full) - float(chunked)) < 1e-4


def test_roofline_hlo_parser_on_known_matmul():
    """Analytic check: parser flops for a plain matmul == 2·M·N·K, and scan
    bodies are multiplied by their trip count."""
    from repro.launch.roofline import aggregate, parse_hlo

    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    L, M, K = 7, 32, 64
    w = jnp.zeros((L, K, K))
    x = jnp.zeros((M, K))
    compiled = jax.jit(f).lower(w, x).compile()
    comps, entry = parse_hlo(compiled.as_text())
    agg = aggregate(comps, entry)
    want = 2 * M * K * K * L
    assert abs(agg["flops"] - want) / want < 0.05, (agg["flops"], want)
