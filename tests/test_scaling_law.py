"""Scaling-law machinery: fit recovery, efficiency factors, optimality
regions (Fig. 1 b/c) under the paper's own speedup model."""

import numpy as np

from repro.core.scaling_law import (
    PAPER_COEFFS,
    SPEEDUPS,
    ScalingLaw,
    effective_loss,
    fit_baseline,
    fit_efficiencies,
    harmonic_training_speedup,
    optimality_region,
)


def _paper_law():
    return ScalingLaw(A=PAPER_COEFFS["A"], alpha=PAPER_COEFFS["alpha"],
                      B=PAPER_COEFFS["B"], beta=PAPER_COEFFS["beta"],
                      E=PAPER_COEFFS["E"], gamma=PAPER_COEFFS["gamma"])


def _grid(law, en=1.0, ed=1.0):
    return [(n, n * r, float(law.loss(n, n * r, en, ed)))
            for n in [30e6, 50e6, 100e6, 200e6]
            for r in [25, 50, 100, 200, 400, 800]]


def test_stage1_fit_recovers_planted_law():
    truth = ScalingLaw(1.5e5, 0.58, 5.2e5, 0.55, 1.35, 0.28)
    law = fit_baseline(_grid(truth))
    for n, d, l in _grid(truth):
        assert abs(law.loss(n, d) - l) / l < 1e-4


def test_stage2_recovers_planted_efficiencies():
    truth = _paper_law()
    runs = _grid(truth, en=0.64, ed=0.94)
    en, ed = fit_efficiencies(truth, runs)
    assert abs(en - 0.64) < 0.02
    assert abs(ed - 0.94) < 0.02


def test_stage2_robust_to_noise():
    rng = np.random.default_rng(0)
    truth = _paper_law()
    runs = [(n, d, l * float(np.exp(rng.normal(0, 0.003))))
            for n, d, l in _grid(truth, en=0.5, ed=0.8)]
    en, ed = fit_efficiencies(truth, runs)
    assert abs(en - 0.5) < 0.06 and abs(ed - 0.8) < 0.08


def test_harmonic_speedup_matches_paper_table1():
    # sptr = 1/(1/3/spfw + 2/3/spbw): FP4:FP8 → 1.2, FP8:FP4 → 1.5, FP4:FP4 → 2
    assert abs(harmonic_training_speedup(2.0, 1.0) - 1.2) < 1e-9
    assert abs(harmonic_training_speedup(1.0, 2.0) - 1.5) < 1e-9
    assert abs(harmonic_training_speedup(2.0, 2.0) - 2.0) < 1e-9
    for k, v in SPEEDUPS.items():
        assert abs(harmonic_training_speedup(v["spfw"], v["spbw"]) - v["sptr"]) < 1e-6


def test_fp4_optimality_region_grows_with_fp4_backward():
    """Fig. 1(b) vs (c): an FP4 backward enlarges the FP4-forward-optimal
    region (paper's headline qualitative claim)."""
    law = _paper_law()
    eff = {"fp4": (0.64, 0.94), "fp8": (1.0, 1.0)}

    def region(backward):
        methods = {}
        for fwd in ("fp4", "fp8"):
            sp = SPEEDUPS[(fwd, backward)]
            methods[fwd] = dict(eff_n=eff[fwd][0],
                                eff_d=1.0 if backward == "fp8" else eff[fwd][1],
                                spfw=sp["spfw"], sptr=sp["sptr"])
        ns = np.logspace(8, 11, 12)
        rs = np.logspace(1, 3.2, 12)
        return optimality_region(law, methods, ns, rs)

    r_fp8bwd = region("fp8")
    r_fp4bwd = region("fp4")
    frac8 = (r_fp8bwd == "fp4").mean()
    frac4 = (r_fp4bwd == "fp4").mean()
    assert frac4 > frac8  # FP4 backward expands the FP4 region
    assert frac4 > 0.3  # FP4 is optimal in a substantial regime


def test_effective_loss_prefers_faster_precision_under_budget():
    law = _paper_law()
    # same budget: fp4 trains on 2x data (sptr=2, spfw=2 → D·sptr/spfw = D)
    sp4 = SPEEDUPS[("fp4", "fp4")]
    l_fp4 = effective_loss(law, 1e9, 2e10, 0.64, 0.94, sp4["spfw"], sp4["sptr"])
    l_fp8 = effective_loss(law, 1e9, 2e10, 1.0, 1.0, 1.0, 1.0)
    # at this (N, D/N≈20, inference-weighted) point FP4 wins on efficiency
    assert l_fp4 < l_fp8 * 1.02
