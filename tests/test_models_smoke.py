"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-style grad step on CPU, asserting output shapes + no NaNs (per spec)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import build_model

B, S = 2, 64
KEY = jax.random.PRNGKey(0)


def _extra(cfg):
    if cfg.family == "encdec":
        return {"source_embeds": jax.random.normal(
            KEY, (B, cfg.max_source_len, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"image_embeds": jax.random.normal(
            KEY, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)}
    return {}


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_reduced_config(request.param)
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    return request.param, cfg, model, params, tokens


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params, tokens = arch_setup
    logits, caches, aux = model.forward(params, tokens[:, :-1], jnp.uint32(1),
                                        extra=_extra(cfg) or None)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert caches is None
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_grad_step_finite(arch_setup):
    arch, cfg, model, params, tokens = arch_setup
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    extra = _extra(cfg)

    def loss_fn(p):
        logits, _, aux = model.forward(p, inp, jnp.uint32(1), extra=extra or None)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll) + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    assert 1.0 < float(loss) < 12.0  # ≈ ln(V) at init
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), path
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads)) ** 0.5
    assert gnorm > 1e-3  # every family actually receives gradient


def test_full_configs_have_exact_paper_dims():
    """The full (non-reduced) configs must match the assigned table."""
    spec = {
        "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096, num_heads=64,
                                    num_kv_heads=4, d_ff=1536, vocab_size=151936,
                                    num_experts=128, experts_per_token=8),
        "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                            num_kv_heads=8, d_ff=4864, vocab_size=32000,
                            num_experts=128, experts_per_token=2,
                            moe_dense_residual=True),
        "qwen3-1.7b": dict(num_layers=28, d_model=2048, num_heads=16,
                           num_kv_heads=8, d_ff=6144, vocab_size=151936,
                           qk_norm=True),
        "internlm2-20b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=92544),
        "deepseek-7b": dict(num_layers=30, d_model=4096, num_heads=32,
                            num_kv_heads=32, d_ff=11008, vocab_size=102400),
        "starcoder2-3b": dict(num_layers=30, d_model=3072, num_heads=24,
                              num_kv_heads=2, d_ff=12288, vocab_size=49152),
        "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                          d_ff=14336, vocab_size=32000, ssm_state=64,
                          attn_every=6),
        "whisper-tiny": dict(num_layers=4, encoder_layers=4, d_model=384,
                             num_heads=6, d_ff=1536, vocab_size=51865),
        "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096, num_heads=32,
                                     num_kv_heads=8, d_ff=14336,
                                     vocab_size=128256, cross_attn_every=5),
        "falcon-mamba-7b": dict(num_layers=64, d_model=4096, vocab_size=65024,
                                ssm_state=16, ssm_variant="mamba1"),
    }
    for arch, want in spec.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_decode_step_matches_prefill_suffix():
    """Incremental decode == teacher-forced forward on the same tokens
    (cache correctness), for one dense arch and the SSM arch."""
    from repro.train.serve import init_cache, make_decode_step, make_prefill_step

    for arch in ["deepseek-7b", "falcon-mamba-7b"]:
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        params = model.init(KEY)
        toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
        # full forward (teacher-forced) logits at the last position
        full_logits, _, _ = model.forward(params, toks, jnp.uint32(0))
        # prefill on the first 8, then decode 4 steps
        prefill = make_prefill_step(model)
        decode = make_decode_step(model)
        caches = init_cache(model, 2, 16)
        logits, caches, pos = prefill(params, toks[:, :8], caches)
        for t in range(8, 12):
            logits, caches, pos = decode(params, toks[:, t:t + 1], pos, caches)
        import numpy as np
        a = np.asarray(jax.nn.log_softmax(logits))
        b = np.asarray(jax.nn.log_softmax(full_logits[:, -1]))
        # 0.5: the SSM fp32 recurrence amplifies chunked-vs-full ulp
        # differences to ~0.38 on CPU jax 0.4.x (dense stays ~1e-2)
        assert np.max(np.abs(a - b)) < 0.5, (arch, np.max(np.abs(a - b)))
        assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() >= 0.5
