"""Unified state-pool serving (``serve.state_pool``): every non-attention
family's per-slot decode state behind pooled planes.

Parity contract: with ``kv_dtype="dense"`` the pool's planes hold bit-exact
values, so the state-pool engine must be token-for-token the
``DenseSlotCache`` oracle for ssm / hybrid / encdec / vlm — greedy AND
sampled, through slot recycling.  With ``kv_dtype="mxfp4"`` exactness is
claimed *within* the pool: an enc-dec request admitted warm (cross-KV
aliased from the CrossIndex) must emit exactly the tokens of a cold
admission, because both read the same packed pages.  Allocator/ring audits
(``check_invariants``) must hold with mixed tenant kinds live at once, and
the config gates must reject exactly the combinations that have no pooled
representation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import quantizers as Q
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, SamplingParams, SpecConfig,
                         StatePool, TelemetryConfig)

pytestmark = pytest.mark.statepool

KEY = jax.random.PRNGKey(0)

STATE_ARCHS = [
    "falcon-mamba-7b",      # ssm    (rings only)
    "zamba2-7b",            # hybrid (attn-KV plane + mamba rings)
    "whisper-tiny",         # encdec (self-KV plane + cross-KV plane)
    "llama-3.2-vision-11b", # vlm    (self-KV plane + cross-KV plane)
]


def _extra(cfg, key=KEY):
    if cfg.family == "encdec":
        return {"source_embeds": jax.random.normal(
            key, (1, cfg.max_source_len, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"image_embeds": jax.random.normal(
            key, (1, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)}
    return None


@pytest.fixture(scope="module", params=STATE_ARCHS)
def state_setup(request):
    cfg = get_reduced_config(request.param)
    model = build_model(cfg)
    params = model.init(KEY)
    return request.param, cfg, model, params


def _tiny(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


# ---------------------------------------------------------------------------
# token-exactness vs the DenseSlotCache oracle
# ---------------------------------------------------------------------------


def test_statepool_matches_oracle(state_setup):
    """One workload per backend covering all three parity axes at once:
    greedy and sampled requests, concurrent different-length prompts, and
    more requests than slots (retired rings/pages recycle mid-run and the
    recycled state must never leak into a later request)."""
    arch, cfg, model, params = state_setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 12, 5, 9, 11)]  # 5 requests, 3 slots
    sampling = [None,  # greedy
                SamplingParams(temperature=0.9, top_k=8, seed=11),
                None,
                SamplingParams(temperature=1.3, top_p=0.9, seed=5),
                None]

    def run(backend):
        eng = Engine(model, params, EngineConfig(
            n_slots=3, max_len=32, page_size=8, kv_dtype="dense",
            prefill_chunk=8, decode_backend=backend, debug_cache=True))
        hs = [eng.submit(p, 4, extra=_extra(cfg), sampling=s)
              for p, s in zip(prompts, sampling)]
        eng.drain()
        if backend == "statepool":
            eng.cache.check_invariants()
        return [h.tokens for h in hs]

    pooled, oracle = run("statepool"), run("dense_slots")
    assert pooled == oracle, (arch, pooled, oracle)
    assert all(len(t) == 4 for t in pooled)


# ---------------------------------------------------------------------------
# cross-KV sharing: warm admission must be token-exact vs cold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["whisper-tiny", "llama-3.2-vision-11b"])
def test_cross_sharing_warm_exact_vs_cold(arch):
    cfg, model, params = _tiny(arch)
    rng = np.random.default_rng(7)
    p1 = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    ex = _extra(cfg)
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, page_size=8, kv_dtype="mxfp4",
        prefill_chunk=8, prefix_cache=True, debug_cache=True))
    cold = eng.submit(p1, 5, extra=ex)
    eng.drain()
    warm = eng.submit(p1, 5, extra=ex)
    other = eng.submit(p2, 5, extra=ex)  # same conditioning, new prompt
    eng.drain()
    # warm reads the very pages cold encoded — exactness within the pool
    assert warm.tokens == cold.tokens
    assert len(other.tokens) == 5
    reg = eng.telemetry.registry
    assert reg.counter("cross_encode_calls").value == 1  # encoded ONCE
    assert reg.counter("prefix_hit_requests").value == 2
    assert reg.counter("prefix_shared_tokens").value == 2 * eng.cache.cross_tokens
    # distinct conditioning forces a fresh encode (no false sharing)
    ex2 = _extra(cfg, jax.random.PRNGKey(9))
    eng.submit(p1, 3, extra=ex2)
    eng.drain()
    assert reg.counter("cross_encode_calls").value == 2
    eng.cache.check_invariants()


def test_cross_index_evicts_under_pressure():
    """Distinct conditioning tensors pin page sets until the cross plane's
    headroom runs out; later admissions must LRU-evict refcount-one entries
    rather than wedging admission."""
    cfg, model, params = _tiny("whisper-tiny")
    rng = np.random.default_rng(8)
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, page_size=8, kv_dtype="mxfp4",
        prefill_chunk=8, prefix_cache=True, debug_cache=True))
    for i in range(6):
        ex = _extra(cfg, jax.random.PRNGKey(100 + i))
        p = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        h = eng.submit(p, 2, extra=ex)
        eng.drain()
        assert len(h.tokens) == 2
    eng.cache.check_invariants()
    assert eng.telemetry.registry.counter("cross_encode_calls").value == 6


# ---------------------------------------------------------------------------
# mixed tenants: allocator + ring audits mid-flight
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["zamba2-7b", "whisper-tiny"])
def test_mixed_tenant_invariants_mid_flight(arch):
    """Audit the pool while several tenant kinds are live at once (attn-KV
    pages + active rings for hybrid, self-KV + cross-KV pages for enc-dec),
    not just at quiescence: step partway, audit, finish, audit again."""
    cfg, model, params = _tiny(arch)
    rng = np.random.default_rng(9)
    eng = Engine(model, params, EngineConfig(
        n_slots=3, max_len=32, page_size=8, kv_dtype="mxfp4",
        prefill_chunk=8, debug_cache=True))
    for n in (7, 12, 9):
        eng.submit(rng.integers(0, cfg.vocab_size, n).astype(np.int32), 4,
                   extra=_extra(cfg))
    for _ in range(3):  # mid-flight: prefill + decode slots coexist
        eng.step()
        eng.cache.check_invariants()
    stats = eng.cache.plane_stats()
    assert len(stats) >= 2, stats  # genuinely mixed tenant kinds
    live = [k for k, s in stats.items() if s["occupancy"] > 0]
    assert len(live) >= 2, stats
    eng.drain()
    eng.cache.check_invariants()
    # everything retired: pooled pages recycled, rings deactivated
    after = eng.cache.plane_stats()
    assert all(s["occupancy"] == 0 for s in after.values()), after


# ---------------------------------------------------------------------------
# ring plane unit: sentinel reads, dense/packed roundtrip, cursor contract
# ---------------------------------------------------------------------------


def test_ring_plane_roundtrip_and_sentinel():
    from repro.serve.state_pool import RingPlane

    leaf = (3, 5, 7)  # [layers, *state] — deliberately not a multiple of 32
    for kv_dtype in ("dense", "mxfp4"):
        plane = RingPlane("h", leaf, jnp.float32, 2, kv_dtype)
        pool = plane.pool
        fresh = plane.gather(pool, jnp.asarray(np.zeros(2, np.int32)))
        assert fresh.shape == (3, 2, 5, 7)
        assert bool(jnp.all(fresh == 0))  # page 0 = zero sentinel
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((3, 2, 5, 7)).astype(np.float32))
        write = jnp.asarray(np.array([1, 3], np.int32))
        pool = plane.scatter(pool, write, x)
        y = plane.gather(pool, write)
        if kv_dtype == "dense":
            assert bool(jnp.all(y == x))
        else:
            # quantize-on-write: the page holds exactly one E2M1+E8M0 pass
            flat = jnp.moveaxis(x, 1, 0).reshape(2, -1)
            ref = Q.state_dequantize(Q.state_quantize(flat), flat.shape[-1],
                                     dtype=jnp.float32)
            got = jnp.moveaxis(y, 1, 0).reshape(2, -1)
            assert bool(jnp.all(got == ref))
            assert plane.page_bytes() * 8 / plane.padded == 4.25
        # masked lane: an id-0 (sentinel) write must not disturb live pages
        pool = plane.scatter(pool, jnp.asarray(np.array([0, 3], np.int32)),
                             jnp.zeros_like(x))
        again = plane.gather(pool, write)
        assert bool(jnp.all(again[:, 0] == y[:, 0]))


def test_ring_cursor_contract():
    cfg = get_reduced_config("falcon-mamba-7b")
    model = build_model(cfg)
    sp = StatePool(model, n_slots=2, max_len=16, page_size=8)
    assert sp.kv is None and sp.cross is None and sp.rings
    sp.alloc(0, 10)
    mask = np.array([True, False])
    read0, write0 = sp.ring_ids(mask)
    assert read0[0] == 0  # fresh slot reads the zero sentinel
    assert read0[1] == 0 and write0[1] == 0  # masked lane -> sentinel page
    w = sp.ring_write_id(0)
    assert write0[0] == w and w != 0
    sp.ring_advance(mask)
    read1, write1 = sp.ring_ids(mask)
    assert read1[0] == w             # read what was just written
    assert write1[0] not in (0, w)   # double buffer: write flips pages
    sp.ring_advance(mask)
    read2, write2 = sp.ring_ids(mask)
    assert read2[0] == write1[0] and write2[0] == w
    sp.check_invariants()
    sp.free(0)
    sp.check_invariants()
    assert sp.ring_ids(mask)[0][0] == 0  # deactivated -> sentinel again


def test_statepool_bytes_win():
    """Packed per-decode-step state traffic beats the dense per-slot caches
    >= 4x on the pure-SSM family (recurrent state packs to 4.25 b/elem)."""
    cfg = get_reduced_config("falcon-mamba-7b")
    model = build_model(cfg)
    sp = StatePool(model, n_slots=4, max_len=64, page_size=8)
    ratio = (sp.dense_state_bytes_per_decode_step(64)
             / sp.state_bytes_per_decode_step(64))
    assert ratio >= 4.0, ratio
    assert sp.bits_per_element() <= 4.5


# ---------------------------------------------------------------------------
# config gates: lifted where pooled serving works, precise errors elsewhere
# ---------------------------------------------------------------------------


def test_gate_spec_rejected_for_state_families():
    _, model, params = _tiny("falcon-mamba-7b")
    with pytest.raises(ValueError, match="one token per state transition"):
        Engine(model, params, EngineConfig(spec=SpecConfig(k=2)))


def test_gate_prefix_cache_lifted_for_cross_families():
    for arch in ("whisper-tiny", "llama-3.2-vision-11b"):
        _, model, params = _tiny(arch)
        eng = Engine(model, params, EngineConfig(
            n_slots=2, max_len=32, page_size=8, prefix_cache=True))
        assert eng.cross_share  # gate lifted: sharing active
    # ...but not for families without shareable pages
    for arch in ("falcon-mamba-7b", "zamba2-7b"):
        _, model, params = _tiny(arch)
        with pytest.raises(ValueError, match="shareable pages"):
            Engine(model, params, EngineConfig(prefix_cache=True))
    # and not on the dense-slot oracle backend (no pages at all)
    _, model, params = _tiny("whisper-tiny")
    with pytest.raises(ValueError, match="shareable pages"):
        Engine(model, params, EngineConfig(prefix_cache=True,
                                           decode_backend="dense_slots"))


def test_gate_tp_rejected_for_ring_families():
    class _TP2:  # placement duck-type: the gate fires before any device use
        tp = 2

    for arch in ("falcon-mamba-7b", "zamba2-7b"):
        _, model, params = _tiny(arch)
        with pytest.raises(ValueError, match="no head axis"):
            Engine(model, params, EngineConfig(), placement=_TP2())


def test_gate_unknown_backend_and_missing_extra():
    cfg, model, params = _tiny("whisper-tiny")
    with pytest.raises(ValueError, match="dense_slots"):
        Engine(model, params, EngineConfig(decode_backend="paged"))
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, page_size=8, prefill_chunk=8))
    # cross-KV is encoded ONCE at admission, so the conditioning tensors
    # must arrive with submit() — rejected up front, not at prefill time
    with pytest.raises(ValueError, match="source_embeds"):
        eng.submit(np.arange(5, dtype=np.int32), 2)  # no conditioning


# ---------------------------------------------------------------------------
# telemetry: per-kind plane gauges + state quant health
# ---------------------------------------------------------------------------


def test_statepool_telemetry_gauges():
    cfg, model, params = _tiny("zamba2-7b")
    rng = np.random.default_rng(10)
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, page_size=8, kv_dtype="mxfp4", prefill_chunk=8,
        telemetry=TelemetryConfig(quant_stride=1)))
    eng.submit(rng.integers(0, cfg.vocab_size, 9).astype(np.int32), 4)
    eng.drain()
    snap = eng.telemetry.snapshot()
    g = snap["gauges"]
    assert g["pool_pages_total_attn_kv"] > 0
    assert g["pool_pages_total_state_ring"] > 0
    assert g["pool_pages_total_cross_kv"] == 0  # hybrid: no cross plane
    assert snap["counters"]["quant_health_samples"] >= 1
    assert 0.0 <= g["state_zero_fraction"] <= 1.0
    assert 0.0 <= g["kv_clip_fraction_k"] <= 1.0
    assert snap["meta"]["kv_dtype"] == "mxfp4"
    assert snap["meta"]["decode_backend"] == "statepool"
