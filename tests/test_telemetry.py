"""Engine telemetry: registry correctness, tracing, schema stability, and
the zero-interference contract.

The load-bearing guarantees pinned here:

* histogram percentiles match ``np.quantile`` exactly below the reservoir
  bound; counters/gauges/EWMA do what their docstrings say,
* request-lifecycle spans derive TTFT / TPOT / queue-wait / latency exactly
  from scripted event timelines AND from a real engine run on a virtual
  clock,
* the snapshot schema is stable: every metric in the catalog appears in
  every snapshot (even all-zero ones), under its declared kind,
* **zero interference**: an instrumented engine (sinks + per-tick pool
  health sampling) compiles exactly the same step shapes and emits exactly
  the same tokens as a default-telemetry engine,
* the sampler compile cache stays at ONE entry across many distinct seeds
  (the per-request-seed recompile leak regression),
* the BENCH_serve.json / metrics-stream validators accept conforming
  documents and reject broken ones.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serve import Engine, EngineConfig, SamplingParams, TelemetryConfig
from repro.serve.sampling import _COMPILED, get_sampler
from repro.serve.telemetry import CATALOG, EngineTelemetry
from repro.serve.telemetry.registry import (EwmaRate, Histogram,
                                            MetricsRegistry, merge_registries)
from repro.serve.telemetry.schema import (BENCH_SCHEMA, validate_bench,
                                          validate_metrics_file,
                                          validate_snapshot)
from repro.serve.telemetry.tracing import Tracer

pytestmark = pytest.mark.telemetry

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _run_engine(model, params, cfg, *, telemetry=None, spec=None,
                n_requests=3, max_new=5):
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8,
        telemetry=telemetry, spec=spec))
    rng = np.random.default_rng(7)
    for i in range(n_requests):
        eng.submit(rng.integers(1, cfg.vocab_size, size=5 + 3 * i),
                   max_new=max_new, arrival_time=0.0)
    t = 0.0
    while eng.sched.pending:
        eng.step(now=t)
        t += 0.01
    return eng, t


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------


def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(3.0)
    g.set_max(1.0)
    assert g.value == 3.0
    g.set_max(7.5)
    assert g.value == 7.5
    g.set_min(2.0)
    assert g.value == 2.0
    # create-or-get with a different kind is a bug, not a new metric
    with pytest.raises(TypeError):
        reg.gauge("c")


@pytest.mark.parametrize("n", [1, 2, 17, 500])
def test_histogram_percentiles_match_numpy(n):
    rng = np.random.default_rng(n)
    xs = rng.exponential(1.0, size=n)
    h = Histogram(max_samples=1000)
    for x in xs:
        h.observe(float(x))
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        assert h.percentile(q) == pytest.approx(np.quantile(xs, q), rel=1e-12)
    s = h.summary()
    assert s["count"] == n
    assert s["min"] == xs.min() and s["max"] == xs.max()
    assert s["mean"] == pytest.approx(xs.mean())


def test_histogram_reservoir_keeps_recent_window():
    h = Histogram(max_samples=10)
    for x in range(100):
        h.observe(float(x))
    assert h.count == 100  # streaming stats see everything
    assert h.vmin == 0.0 and h.vmax == 99.0
    assert h.percentile(0.0) == 90.0  # reservoir holds the last 10


def test_ewma_rate():
    r = EwmaRate(halflife_s=1.0)
    assert r.rate is None
    r.mark(10, t=0.0)
    r.mark(10, t=1.0)  # first measurable gap: 10 pending + 10 over 1s
    assert r.rate == pytest.approx(20.0)
    # a mark at a non-advancing clock accumulates instead of dividing by 0
    r.mark(5, t=1.0)
    assert r.rate == pytest.approx(20.0)
    r.mark(5, t=2.0)  # (5 pending + 5) / 1s = 10/s, blended at alpha=0.5
    assert r.rate == pytest.approx(15.0)


def test_binned_histogram_set_vs_merge():
    reg = MetricsRegistry()
    b = reg.binned("b", 4)
    b.set_counts([0, 1, 2, 0])
    b.set_counts([0, 3, 0, 0])  # gauge-like: replaced, not accumulated
    assert b.counts == [0, 3, 0, 0]
    b.merge_counts([1, 0, 0, 2])
    assert b.counts == [1, 3, 0, 2]
    s = b.summary()
    assert s["nonzero_bins"] == 3 and s["bin_min"] == 0 and s["bin_max"] == 3
    with pytest.raises(ValueError):
        b.set_counts([1, 2])


def test_registry_reset_preserves_schema():
    reg = MetricsRegistry()
    reg.counter("c").inc(5)
    reg.histogram("h").observe(1.0)
    names_before = reg.names()
    reg.reset()
    assert reg.names() == names_before
    assert reg.counter("c").value == 0
    assert reg.histogram("h").count == 0


def test_prometheus_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s", "latency")
    for v in (0.0001, 0.003, 0.003, 0.7, 120.0):  # 120 > last bound → +Inf
        h.observe(v)
    text = reg.prometheus_text()
    assert "# TYPE lat_s histogram" in text
    # le-labelled buckets are CUMULATIVE (each includes everything below)
    assert 'lat_s_bucket{le="0.0001"} 1' in text
    assert 'lat_s_bucket{le="0.0025"} 1' in text
    assert 'lat_s_bucket{le="0.005"} 3' in text   # the two 3ms samples joined
    assert 'lat_s_bucket{le="1"} 4' in text
    assert 'lat_s_bucket{le="60"} 4' in text      # 120s overflows every bound
    assert 'lat_s_bucket{le="+Inf"} 5' in text    # +Inf always equals _count
    assert "lat_s_sum 120.706" in text  # %g, 6 sig figs
    assert "lat_s_count 5" in text
    # boundary semantics: observe(bound) lands in that bound's bucket (le=)
    reg2 = MetricsRegistry()
    reg2.histogram("x").observe(0.005)
    assert 'x_bucket{le="0.005"} 1' in reg2.prometheus_text()


def test_merge_registries_pools_histograms():
    regs = [MetricsRegistry() for _ in range(3)]
    for i, reg in enumerate(regs):
        reg.counter("engine_ticks").inc(10 * (i + 1))
        reg.gauge("pool_occupancy").set(0.2 * (i + 1))
        reg.gauge("pool_occupancy_peak").set(0.3 * (i + 1))
        reg.gauge("pool_pages_free_watermark").set(10.0 - i)
        for v in np.linspace(0.01 * (i + 1), 0.05 * (i + 1), 20):
            reg.histogram("tick_s").observe(float(v))
    merged = merge_registries(regs)
    assert merged.meta["replicas"] == 3
    assert merged.counter("engine_ticks").value == 60
    assert merged.gauge("pool_occupancy").value == pytest.approx(0.4)  # mean
    assert merged.gauge("pool_occupancy_peak").value == pytest.approx(0.9)
    assert merged.gauge("pool_pages_free_watermark").value == pytest.approx(8.0)
    # histograms are POOLED, not averaged: percentiles computed over the
    # union of all replicas' samples — the previous aggregate dropped them
    h = merged.histogram("tick_s")
    allv = np.concatenate([np.linspace(0.01 * (i + 1), 0.05 * (i + 1), 20)
                           for i in range(3)])
    assert h.count == 60
    assert h.summary()["sum"] == pytest.approx(allv.sum())
    assert h.vmin == pytest.approx(allv.min())
    assert h.vmax == pytest.approx(allv.max())
    for q in (0.5, 0.95):
        assert h.percentile(q) == pytest.approx(np.quantile(allv, q))
    # cumulative bucket counts add elementwise
    assert sum(h.bucket_counts) == 60
    # a metric present on only some replicas merges over those that have it
    regs[0].counter("only_here").inc(7)
    assert merge_registries(regs).counter("only_here").value == 7
    # mismatched bucket layouts refuse to pool silently
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("h")
    b._metrics["h"] = Histogram(buckets=(1.0, 2.0))
    with pytest.raises(ValueError):
        merge_registries([a, b])


# ---------------------------------------------------------------------------
# tracing: span ordering + latency derivation
# ---------------------------------------------------------------------------


def test_trace_derivation_scripted(tmp_path):
    reg = MetricsRegistry()
    path = str(tmp_path / "trace.jsonl")
    tr = Tracer(reg, path=path)
    tr.event(1, "submit", 1.0)
    tr.event(1, "admit", 2.0)
    tr.event(1, "first_token", 3.0)
    tr.tokens(1, 3.0, 1)
    tr.tokens(1, 4.0, 2)
    tr.event(1, "retire", 5.0)
    tr.close()

    done = tr.completed[-1]
    assert [n for n, _ in done.events] == ["submit", "admit", "first_token",
                                           "retire"]
    spans = done.spans()
    assert spans == [("queued", 1.0, 2.0), ("prefill", 2.0, 3.0),
                     ("decode", 3.0, 5.0)]
    d = done.derived()
    assert d["queue_wait_s"] == 1.0
    assert d["ttft_s"] == 2.0
    assert d["tpot_s"] == pytest.approx((4.0 - 3.0) / (3 - 1))
    assert d["request_latency_s"] == 4.0
    assert d["n_tokens"] == 3
    # derived latencies land in the registry histograms on retire
    assert reg.histogram("ttft_s").count == 1
    assert reg.histogram("ttft_s").percentile(0.5) == 2.0
    # and the trace file round-trips
    line = json.loads(open(path).read())
    assert line["rid"] == 1 and line["derived"]["ttft_s"] == 2.0


def test_trace_single_token_has_no_tpot():
    tr = Tracer(None)
    tr.event(2, "submit", 0.0)
    tr.event(2, "admit", 0.0)
    tr.event(2, "first_token", 1.0)
    tr.tokens(2, 1.0, 1)
    tr.event(2, "retire", 1.0)
    assert tr.completed[-1].derived()["tpot_s"] is None


def test_engine_trace_derivation_virtual_clock(dense_setup):
    """TTFT/TPOT from a real engine run on a deterministic virtual clock."""
    cfg, model, params = dense_setup
    eng, _ = _run_engine(model, params, cfg)
    snap = eng.telemetry.snapshot()
    done = list(eng.telemetry.tracer.completed)
    assert len(done) == 3
    for trace in done:
        req = next(r for r in eng.completed if r.rid == trace.rid)
        d = trace.derived()
        # the tracer's derivations must agree with the Request bookkeeping
        assert d["ttft_s"] == pytest.approx(req.ttft())
        assert d["request_latency_s"] == pytest.approx(req.latency())
        assert d["n_tokens"] == len(req.tokens)
        assert d["queue_wait_s"] >= 0.0
    assert snap["histograms"]["ttft_s"]["count"] == 3
    assert snap["histograms"]["tpot_s"]["count"] == 3  # max_new=5 > 1 token
    assert snap["counters"]["tokens_generated"] == sum(
        len(r.tokens) for r in eng.completed)
    # first tokens ride on prefill calls, the rest on decode ticks
    assert snap["counters"]["decode_tokens"] == snap["counters"][
        "tokens_generated"] - 3


# ---------------------------------------------------------------------------
# schema stability + snapshot/bench validators
# ---------------------------------------------------------------------------


def test_snapshot_carries_full_catalog(dense_setup):
    """Every catalog metric appears in every snapshot under its kind — even
    before the engine ever steps (consumers can code against the names)."""
    cfg, model, params = dense_setup
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8))
    snap = eng.telemetry.snapshot()
    section = {"counter": "counters", "gauge": "gauges",
               "histogram": "histograms", "binned": "binned", "ewma": "rates"}
    for name, (kind, _) in CATALOG.items():
        assert name in snap[section[kind]], f"{name} missing from snapshot"
    # and nothing undeclared leaks in
    declared = set(CATALOG)
    for sec in ("counters", "gauges", "histograms", "binned", "rates"):
        assert set(snap[sec]) <= declared
    assert validate_snapshot(snap) == []


def test_metrics_file_validator(tmp_path):
    tel = EngineTelemetry(TelemetryConfig(
        metrics_path=str(tmp_path / "m.jsonl"), emit_every_ticks=0))
    tel.registry.counter("engine_ticks").inc()
    tel.emit(1.0)
    tel.finalize(2.0)
    assert validate_metrics_file(str(tmp_path / "m.jsonl")) == 2
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"schema": "nope"}\n')
    with pytest.raises(ValueError):
        validate_metrics_file(str(bad))
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(ValueError):
        validate_metrics_file(str(empty))


def test_bench_validator():
    import importlib.util
    import pathlib
    mod_path = (pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
                / "serve_throughput.py")
    spec = importlib.util.spec_from_file_location("serve_throughput", mod_path)
    st = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(st)
    num = {"mxfp4": dict.fromkeys(
        ("tokens_per_sec", "ttft_p50_s", "ttft_p95_s", "tpot_p50_s",
         "tpot_p95_s", "latency_p50_s", "latency_p95_s", "queue_wait_p50_s",
         "decode_tick_p50_s", "decode_tick_p95_s", "prefill_tick_p50_s",
         "pool_occupancy_peak", "free_page_watermark", "cache_bytes",
         "bits_per_kv_elem"), 1.0)}
    num["dense"] = dict(num["mxfp4"])
    rep = {
        "arch": "a", "family": "dense", "n_requests": 2, "max_new": 2,
        "n_slots": 2, **num,
        "decode_backends": {"mxfp4/gather": {"tokens_per_sec": 1.0}},
        "cache_ratio": 3.8, "decode_bytes_ratio_gather_over_paged": 8.0,
        "spec": {"k": 3, "proposer": "self"},
    }
    doc = st.make_bench_baseline(rep)
    assert doc["schema"] == BENCH_SCHEMA
    assert validate_bench(doc) == []
    # null-able fields may be null; required numbers may not
    doc["spec"]["acceptance_rate"] = None
    assert validate_bench(doc) == []
    doc["throughput"]["mxfp4_paged_tok_per_s"] = None
    assert validate_bench(doc) != []
    del doc["pool"]
    assert any("pool" in e for e in validate_bench(doc))
    assert validate_bench({"schema": BENCH_SCHEMA}) != []
    # the sharding block is nullable as a whole (single-device runs) but
    # must conform when present; nested tp_run/dp_run are nullable too
    doc = st.make_bench_baseline(rep)
    assert doc["sharding"] is None and validate_bench(doc) == []
    doc["sharding"] = {
        "tp": 2, "dp": 2, "devices": 8,
        "single": {"decode_tok_per_s": 1.0, "ttft_p50_s": 0.1,
                   "tpot_p50_s": None, "wall_sec": 0.5},
        "tp_run": None,
        "dp_run": {"aggregate_decode_tok_per_s": 2.0,
                   "speedup_vs_one_replica": 2.0, "parity_vs_single": 1.0,
                   "pool_bytes_per_shard": 1024, "wall_sec": 0.3},
    }
    assert validate_bench(doc) == []
    doc["sharding"]["dp_run"]["parity_vs_single"] = None
    assert any("parity_vs_single" in e for e in validate_bench(doc))
    doc["sharding"] = "not-an-object"
    assert any("object|null" in e for e in validate_bench(doc))


# ---------------------------------------------------------------------------
# zero interference: compiles + tokens identical with sinks on
# ---------------------------------------------------------------------------


def test_instrumented_engine_is_bit_identical(dense_setup, tmp_path):
    cfg, model, params = dense_setup
    plain, _ = _run_engine(model, params, cfg, telemetry=None)
    instrumented, t = _run_engine(
        model, params, cfg,
        telemetry=TelemetryConfig(metrics_path=str(tmp_path / "m.jsonl"),
                                  trace_path=str(tmp_path / "t.jsonl"),
                                  emit_every_ticks=2, quant_stride=1))
    # token streams bit-identical
    assert ({r.rid: r.tokens for r in plain.completed}
            == {r.rid: r.tokens for r in instrumented.completed})
    # exactly the same step shapes compiled — sinks and per-tick pool-health
    # sampling add ZERO jit compilations to the engine's step functions
    assert plain.compile_counts() == instrumented.compile_counts()
    assert instrumented.compile_counts()["decode_all"] == 1
    assert instrumented.compile_counts()["prefill_all"] == 1
    assert instrumented.compile_counts()["prefill_chunk"] == 0  # paged path
    snap = instrumented.telemetry.finalize(t)
    assert snap["counters"]["quant_health_samples"] > 0
    assert snap["gauges"]["pool_occupancy_peak"] > 0
    assert snap["binned"]["kv_scale_hist_k"]["nonzero_bins"] >= 1
    assert 0.0 <= snap["gauges"]["kv_clip_fraction_k"] <= 1.0
    assert validate_metrics_file(str(tmp_path / "m.jsonl")) >= 1


def test_pool_gauges_and_conservation(dense_setup):
    cfg, model, params = dense_setup
    eng, t = _run_engine(model, params, cfg)
    snap = eng.telemetry.snapshot(t)
    g = snap["gauges"]
    total = g["pool_pages_total"]
    assert total == eng.cache.n_pages - 1
    # everything retired: the pool drained back to empty
    assert g["pool_pages_free"] == total
    assert 0 < g["pool_pages_free_watermark"] < total
    assert g["pool_occupancy"] == 0.0
    assert 0.0 < g["pool_occupancy_peak"] <= 1.0
    assert eng.cache.mapped_total() + eng.cache.free_pages == total


def test_quant_health_dense_pool_is_none(dense_setup):
    from repro.serve.telemetry.quant_health import sample_pool_health
    cfg, model, params = dense_setup
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8, kv_dtype="dense"))
    assert sample_pool_health(eng.cache) is None  # nothing quantized
    eng2 = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8))
    assert sample_pool_health(eng2.cache) is None  # mxfp4 but nothing mapped


# ---------------------------------------------------------------------------
# sampler compile-cache regression (satellite: one compile per distribution)
# ---------------------------------------------------------------------------


def test_sampler_cache_one_compile_across_seeds():
    dist = dict(temperature=0.7, top_k=13, top_p=0.9)
    before = len(_COMPILED)
    samplers = [get_sampler(SamplingParams(**dist, seed=s)) for s in range(10)]
    assert len(_COMPILED) - before == 1, \
        "per-seed sampler recompile leak is back"
    logits = np.linspace(-2, 2, 64).astype(np.float32)
    toks = {s(logits, 3) for s in samplers}
    assert len(toks) > 1, "distinct seeds should decorrelate draws"
    # all draws share ONE compiled executable
    fn = samplers[0]._fn
    assert all(s._fn is fn for s in samplers)
    assert fn._cache_size() == 1
    # determinism: the same (params, token_idx) always draws the same token
    assert samplers[3](logits, 5) == get_sampler(
        SamplingParams(**dist, seed=3))(logits, 5)


def test_sampler_seed_matches_trace_time_seed():
    """The runtime-seed path must draw exactly what baking the seed into the
    trace (the old implementation) would have drawn."""
    from repro.serve.sampling import sample_row
    sp = SamplingParams(temperature=1.1, top_k=7, seed=42)
    logits = jnp.asarray(np.random.default_rng(0).normal(size=96), jnp.float32)
    baked = int(sample_row(logits, sp, jnp.int32(0), jnp.int32(4)))
    runtime = get_sampler(sp)(np.asarray(logits), 4)
    assert baked == runtime
