"""Multi-device serving: TP/DP token-exactness, sharded-pool conservation,
replica placement, and mesh validation.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — device-
dependent tests skip themselves when the process sees too few devices (the
tier-1 suite runs single-device by design; see tests/conftest.py).

The exactness contract (ISSUE 8 / serve/README.md): a TP=2 engine — and a
TP=2 x DP=2 ReplicatedEngine — on a forced-host-device mesh emits
bit-identical tokens to the single-device engine across
dense/MoE x paged/gather x spec on/off x prefix-cache on/off.  Sharding is
exactness-preserving by construction (head/expert slices + tiled all_gather
concats, never a cross-shard reduction), so these are equality asserts, not
tolerance checks.
"""

import itertools

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.launch.mesh import make_local_mesh, make_serve_meshes
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, Placement, ReplicaPlacer,
                         ReplicatedEngine, ShardingConfig, SpecConfig,
                         make_engine)

pytestmark = pytest.mark.sharded

N_DEV = len(jax.devices())
HINT = " (run with XLA_FLAGS=--xla_force_host_platform_device_count=8)"
needs2 = pytest.mark.skipif(N_DEV < 2, reason="needs >= 2 devices" + HINT)
needs4 = pytest.mark.skipif(N_DEV < 4, reason="needs >= 4 devices" + HINT)

ARCHS = ["qwen3-1.7b", "qwen3-moe-235b-a22b"]  # dense, moe
_MODELS: dict = {}


def _setup(arch):
    if arch not in _MODELS:
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        _MODELS[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _MODELS[arch]


def _prompts(cfg, n=4):
    rng = np.random.default_rng(0)
    ps = [rng.integers(0, cfg.vocab_size, 12 + i).astype(np.int32)
          for i in range(n)]
    for i in range(1, n):  # shared 8-token prefix exercises aliasing/COW
        ps[i][:8] = ps[0][:8]
    return ps


def _run(arch, backend, tp=1, dp=1, joint=False):
    """Drain a small workload; returns ([tokens per request], engine)."""
    cfg, model, params = _setup(arch)
    sh = ShardingConfig(tp=tp, dp=dp) if (tp > 1 or dp > 1) else None
    ec = EngineConfig(
        n_slots=2, max_len=64, page_size=8, kv_dtype="mxfp4",
        prefill_chunk=8, decode_backend=backend, sharding=sh,
        # spec + prefix toggle jointly ("on" combos); the self-proposer is
        # the exactness oracle and rides the engine's own sharded steps
        spec=SpecConfig(k=2, proposer="self") if (joint and backend == "paged")
        else None,
        prefix_cache=joint)
    eng = make_engine(model, params, ec)
    for p in _prompts(cfg):
        eng.submit(p, 8)
    done = eng.drain()
    return [r.tokens for r in sorted(done, key=lambda r: r.rid)], eng


# ---------------------------------------------------------------------------
# token-exactness: the 8-combo TP=2 sweep + TP=2 x DP=2
# ---------------------------------------------------------------------------


@needs2
@pytest.mark.parametrize("arch,backend,joint",
                         list(itertools.product(ARCHS, ["paged", "gather"],
                                                [False, True])))
def test_tp2_token_exact(arch, backend, joint):
    base, _ = _run(arch, backend, tp=1, joint=joint)
    tp2, eng = _run(arch, backend, tp=2, joint=joint)
    assert tp2 == base
    assert eng.placement.tp == 2


@needs4
@pytest.mark.parametrize("arch", ARCHS)
def test_tp2_dp2_token_exact(arch):
    base, _ = _run(arch, "paged", tp=1, joint=True)
    tpdp, eng = _run(arch, "paged", tp=2, dp=2, joint=True)
    assert tpdp == base
    assert isinstance(eng, ReplicatedEngine)
    # both replicas actually served work (placer spread the 4 requests)
    assert all(e.completed for e in eng.engines)


# ---------------------------------------------------------------------------
# sharded pool: placement + per-shard conservation
# ---------------------------------------------------------------------------


@needs2
def test_pool_sharded_on_head_axis():
    cfg, model, params = _setup("qwen3-1.7b")
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=64, page_size=8, kv_dtype="mxfp4",
        sharding=ShardingConfig(tp=2)))
    H = cfg.num_kv_heads
    for name, leaf in eng.cache.pool.items():
        assert leaf.shape[3] == H
        shards = leaf.addressable_shards
        assert len(shards) == 2, name
        # each shard holds exactly its H/2-head slice — together they
        # conserve the full pool (no replication, no overlap)
        for s in shards:
            assert s.data.shape[3] == H // 2, name
        lo = sorted(shards, key=lambda s: s.index[3].start or 0)
        full = np.concatenate([np.asarray(s.data) for s in lo], axis=3)
        np.testing.assert_array_equal(full, np.asarray(leaf))


@needs2
def test_sharded_pool_survives_workload_invariants():
    """Allocator invariants (page conservation, refcounts) are host-side and
    must hold regardless of device layout; the pool stays head-sharded after
    a full drain (steps' out_shardings keep the placement)."""
    _, eng = _run("qwen3-1.7b", "paged", tp=2, joint=True)
    eng.cache.check_invariants()
    leaf = next(iter(eng.cache.pool.values()))
    assert len(leaf.addressable_shards) == 2
    assert {s.data.shape[3] for s in leaf.addressable_shards} == {
        leaf.shape[3] // 2}


# ---------------------------------------------------------------------------
# replica placement (pure host logic)
# ---------------------------------------------------------------------------


def test_replica_placer_prefers_free_pages():
    p = ReplicaPlacer(3)
    assert p.place([1, 9, 4], [1, 1, 1]) == 1
    assert p.place([4, 4, 9], [1, 1, 1]) == 2


def test_replica_placer_breaks_ties_by_slots_then_round_robin():
    p = ReplicaPlacer(2)
    assert p.place([5, 5], [1, 3]) == 1  # pages tie → slots decide
    p2 = ReplicaPlacer(3)
    # exact ties round-robin instead of piling onto replica 0
    seen = [p2.place([2, 2, 2], [1, 1, 1]) for _ in range(3)]
    assert seen == [0, 1, 2]


def test_replica_placer_validates():
    with pytest.raises(ValueError):
        ReplicaPlacer(0)


# ---------------------------------------------------------------------------
# mesh / config validation
# ---------------------------------------------------------------------------


def test_make_local_mesh_rejects_non_divisor():
    with pytest.raises(ValueError, match="does not divide"):
        make_local_mesh(model=N_DEV + 1)
    if N_DEV >= 2:  # 3 never divides a power-of-two device count
        bad = 3 if N_DEV % 3 else 5
        if N_DEV % bad:
            with pytest.raises(ValueError, match="does not divide"):
                make_local_mesh(model=bad)


def test_make_local_mesh_rejects_nonpositive():
    with pytest.raises(ValueError, match=">= 1"):
        make_local_mesh(model=0)


def test_make_local_mesh_valid_divisors():
    for m in range(1, N_DEV + 1):
        if N_DEV % m == 0:
            mesh = make_local_mesh(model=m)
            assert mesh.shape["model"] == m
            assert mesh.shape["data"] * m == N_DEV


def test_make_serve_meshes_disjoint_groups():
    with pytest.raises(ValueError, match=">= 1"):
        make_serve_meshes(tp=0)
    with pytest.raises(ValueError, match="devices"):
        make_serve_meshes(tp=N_DEV + 1)
    if N_DEV >= 4:
        meshes = make_serve_meshes(tp=2, dp=2)
        assert len(meshes) == 2
        devs = [d for m in meshes for d in m.devices.flat]
        assert len(set(devs)) == 4  # disjoint


def test_sharding_config_validates():
    with pytest.raises(ValueError):
        ShardingConfig(tp=0)
    with pytest.raises(ValueError):
        Placement(tp=0)


@needs2
def test_engine_rejects_dp_and_nonpaged_tp():
    cfg, model, params = _setup("qwen3-1.7b")
    with pytest.raises(ValueError, match="ReplicatedEngine"):
        Engine(model, params,
               EngineConfig(sharding=ShardingConfig(tp=1, dp=2)))
    ssm_cfg = get_reduced_config("falcon-mamba-7b")
    ssm = build_model(ssm_cfg)
    with pytest.raises(ValueError, match="paged family"):
        Engine(ssm, ssm.init(jax.random.PRNGKey(0)),
               EngineConfig(sharding=ShardingConfig(tp=2)))


@needs4
def test_replicated_engine_unique_rids_and_merge_order():
    _, eng = _run("qwen3-1.7b", "paged", tp=2, dp=2)
    rids = [r.rid for r in eng.completed]
    assert rids == sorted(rids) and len(set(rids)) == len(rids)
    assert {getattr(r, "replica", None) for r in eng.completed} <= {0, 1}


# ---------------------------------------------------------------------------
# DP telemetry aggregation + merged profile trace
# ---------------------------------------------------------------------------


@needs2
def test_dp_aggregate_pools_histograms_and_merges_trace(tmp_path):
    from repro.serve import TelemetryConfig
    from repro.serve.telemetry.profiling import validate_trace_file

    cfg, model, params = _setup("qwen3-1.7b")
    eng = make_engine(model, params, EngineConfig(
        n_slots=2, max_len=64, page_size=8, kv_dtype="mxfp4", prefill_chunk=8,
        sharding=ShardingConfig(tp=1, dp=2),
        telemetry=TelemetryConfig(
            profile_trace_path=str(tmp_path / "dp_trace.json"))))
    assert isinstance(eng, ReplicatedEngine)
    for p in _prompts(cfg):
        eng.submit(p, 8, arrival_time=0.0)
    eng.drain()
    assert all(e.completed for e in eng.engines)  # placer spread the work

    agg = eng.aggregate_telemetry()
    regs = [e.telemetry.registry for e in eng.engines]
    assert agg["replicas"] == 2
    # counters sum across replicas
    assert agg["counters"]["engine_ticks"] == sum(
        r.counter("engine_ticks").value for r in regs)
    assert agg["counters"]["decode_calls"] == sum(
        r.counter("decode_calls").value for r in regs)
    # histograms are POOLED, not dropped (the old aggregate carried only
    # counters + a few gauges): aggregate counts/sums span both replicas
    for hname in ("tick_s", "decode_tick_s", "ttft_s"):
        per = [r.histogram(hname) for r in regs]
        assert agg["histograms"][hname]["count"] == sum(h.count for h in per)
        assert agg["histograms"][hname]["sum"] == pytest.approx(
            sum(h.total for h in per))
    assert agg["histograms"]["tick_s"]["count"] > 0
    # profiler gauges averaged across replicas, nonzero with profiling on
    assert agg["gauges"]["roofline_util_decode"] > 0

    # one merged Perfetto document: a process lane per replica
    path = eng.write_profile()
    doc = validate_trace_file(path)
    payload = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert {e["pid"] for e in payload} == {0, 1}
    cats = {e.get("cat") for e in payload}
    assert {"tick", "phase", "request"} <= cats
