"""Continuous-batching engine: paged FP4 KV cache, scheduler, parity.

Parity contract: with concurrent requests of different prompt lengths, the
engine's dense-cache outputs are token-for-token those of sequential
``greedy_generate`` for every model family; FP4-cache mode stays within a
log-prob tolerance of dense-cache mode while using ≥ 3× fewer cache bytes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import formats as F
from repro.core import quantizers as Q
from repro.models import build_model
from repro.serve import Engine, EngineConfig, PagedCache
from repro.train.serve import greedy_generate

KEY = jax.random.PRNGKey(0)

# one representative per family in the reduced registry
FAMILY_ARCHS = [
    "qwen3-1.7b",          # dense   (paged KV)
    "qwen3-moe-235b-a22b", # moe     (paged KV)
    "falcon-mamba-7b",     # ssm     (dense slots)
    "zamba2-7b",           # hybrid  (dense slots)
    "whisper-tiny",        # encdec  (dense slots, cross-KV)
    "llama-3.2-vision-11b",# vlm     (dense slots, cross-KV)
]


def _extra(cfg, batch=1):
    if cfg.family == "encdec":
        return {"source_embeds": jax.random.normal(
            KEY, (batch, cfg.max_source_len, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"image_embeds": jax.random.normal(
            KEY, (batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)}
    return None


@pytest.fixture(scope="module", params=FAMILY_ARCHS)
def family_setup(request):
    cfg = get_reduced_config(request.param)
    model = build_model(cfg)
    params = model.init(KEY)
    return request.param, cfg, model, params


# ---------------------------------------------------------------------------
# packed MXFP4 payload (core + Pallas kernel)
# ---------------------------------------------------------------------------


def test_nibble_pack_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32) * 3)
    q = Q.rtn_absmax(x, scale_mode="nearest")
    on_grid = F.to_blocks(q.values, 32) / F.e8m0_code_to_scale(
        F.scale_to_e8m0_code(q.scales))[..., None]
    nib = F.e2m1_to_nibble(on_grid)
    assert bool(jnp.all(F.nibble_to_e2m1(nib) == on_grid))
    packed = F.pack_nibbles(F.from_blocks(nib))
    assert packed.dtype == jnp.uint8 and packed.shape == (5, 32)
    assert bool(jnp.all(F.unpack_nibbles(packed) == F.from_blocks(nib)))


def test_kv_quantize_matches_rtn_absmax():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 9, 64)).astype(np.float32) * 2)
    pq = Q.kv_quantize(x)
    y = Q.kv_dequantize(pq)
    ref = Q.rtn_absmax(x, scale_mode="nearest")
    assert bool(jnp.all(y == ref.values))
    bits = (pq.codes.nbytes + pq.scales.nbytes) * 8 / x.size
    assert bits == 4.25


def test_kv_pack_kernel_matches_reference():
    from repro.kernels import ops

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((17, 96)).astype(np.float32) * 4)
    codes, scales = ops.kv_quant_pack(x)
    ref = Q.kv_quantize(x)
    assert bool(jnp.all(codes == ref.codes))
    assert bool(jnp.all(scales == ref.scales))
    y = ops.kv_dequant_unpack(codes, scales)
    assert bool(jnp.all(y == Q.kv_dequantize(ref)))


# ---------------------------------------------------------------------------
# PagedCache allocator
# ---------------------------------------------------------------------------


def test_paged_allocator_freelist():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    # debug=True re-audits refcounts / conservation after every mutation
    cache = PagedCache(model, n_slots=2, pages_per_slot=4, page_size=8,
                       n_pages=6, kv_dtype="dense", debug=True)
    assert cache.free_pages == 5  # page 0 reserved as scratch
    cache.alloc(0, 17)  # 3 pages
    assert cache.free_pages == 2
    assert 0 not in cache.tables[0][:3]
    assert cache.can_alloc(16) and not cache.can_alloc(17)
    with pytest.raises(RuntimeError):
        cache.alloc(1, 25)
    cache.free(0)
    assert cache.free_pages == 5
    assert cache.can_alloc(32)  # pages_per_slot bound
    assert not cache.can_alloc(33)
    with pytest.raises(ValueError):
        cache.alloc(1, 8 * 5)  # exceeds pages_per_slot
    cache.check_invariants()


def test_alloc_conserves_pages_on_realloc():
    """Re-allocating a slot that still holds live mappings must return the
    old pages to the free list first — zeroing the table row alone would
    silently leak them (free_pages + mapped == n_pages - 1 must hold through
    any alloc/free ordering regression)."""
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    cache = PagedCache(model, n_slots=2, pages_per_slot=4, page_size=8,
                       kv_dtype="dense", debug=True)
    total = cache.n_pages - 1

    def mapped():
        return sum(cache.mapped_pages(s) for s in range(cache.n_slots))

    cache.alloc(0, 17)  # 3 pages
    assert cache.free_pages + mapped() == total
    cache.alloc(0, 9)  # re-alloc WITHOUT free: old 3 pages must come back
    assert cache.mapped_pages(0) == 2
    assert cache.free_pages + mapped() == total
    # the recycled low ids are handed out again (freed pages weren't lost)
    cache.alloc(1, 32)
    assert cache.free_pages + mapped() == total
    assert cache.free_pages == total - 2 - 4
    cache.free(0)
    cache.free(1)
    assert cache.free_pages == total


def test_paged_cache_fp4_bytes():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    dense = PagedCache(model, n_slots=2, pages_per_slot=2, page_size=8,
                       kv_dtype="dense")
    fp4 = PagedCache(model, n_slots=2, pages_per_slot=2, page_size=8,
                     kv_dtype="mxfp4")
    assert dense.cache_bytes() / fp4.cache_bytes() >= 3.0
    assert fp4.bits_per_element() == 4.25


# ---------------------------------------------------------------------------
# greedy_generate boundary (satellite fix)
# ---------------------------------------------------------------------------


def test_greedy_generate_max_new_1():
    cfg = get_reduced_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init(KEY)
    prompt = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    one = greedy_generate(model, params, prompt, max_new=1, max_len=12)
    three = greedy_generate(model, params, prompt, max_new=3, max_len=12)
    assert one.shape == (2, 1)
    assert bool(jnp.all(one[:, 0] == three[:, 0]))
    with pytest.raises(ValueError):
        greedy_generate(model, params, prompt, max_new=0, max_len=12)


# ---------------------------------------------------------------------------
# engine vs sequential greedy_generate — every family
# ---------------------------------------------------------------------------


def test_engine_matches_greedy_all_families(family_setup):
    arch, cfg, model, params = family_setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 12)]  # concurrent, different lengths
    max_new = 4

    engine = Engine(model, params, EngineConfig(
        n_slots=3, max_len=32, page_size=8, kv_dtype="dense",
        prefill_chunk=8, keep_logits=True))
    handles = [engine.submit(p, max_new, extra=_extra(cfg)) for p in prompts]
    engine.drain()

    for p, h in zip(prompts, handles):
        ref = greedy_generate(model, params, jnp.asarray(p)[None],
                              max_new=max_new, max_len=int(p.size) + max_new,
                              extra=_extra(cfg))
        assert h.tokens == ref[0].tolist(), (arch, h.tokens, ref[0].tolist())
        # logits parity at the first generated position: engine chunked
        # prefill vs one whole-prompt teacher-forced forward.  Recurrent-state
        # families (ssm/hybrid) compute a *different chunk decomposition* of
        # the same recurrence, and the FP4 forward quantizer amplifies that
        # epsilon discontinuously (observed ≤1.3 in the log-prob tail while
        # argmax stays identical) — same effect test_models_smoke's
        # decode-suffix test sees without the engine.  Dense/attention
        # families have no cross-chunk state, so they sit at ~1e-2.
        tol = 1.5 if cfg.family in ("ssm", "hybrid") else 0.35
        full, _, _ = model.forward(params, jnp.asarray(p)[None], jnp.uint32(0),
                                   extra=_extra(cfg))
        a = np.asarray(jax.nn.log_softmax(h.logits_trace[0]))
        b = np.asarray(jax.nn.log_softmax(full[0, -1]))
        assert np.max(np.abs(a - b)) < tol, (arch, np.max(np.abs(a - b)))


def test_engine_fp4_close_to_dense_and_3x_smaller():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)

    traces, nbytes = {}, {}
    for kv in ("dense", "mxfp4"):
        eng = Engine(model, params, EngineConfig(
            n_slots=2, max_len=32, page_size=8, kv_dtype=kv,
            prefill_chunk=8, keep_logits=True))
        h = eng.submit(prompt, 4)
        eng.drain()
        traces[kv], nbytes[kv] = h.logits_trace, eng.cache_bytes()

    assert nbytes["dense"] / nbytes["mxfp4"] >= 3.0
    # 4-bit cache error stays bounded relative to the dense-cache run (the
    # reduced model's logit std is ~1, so a couple of nats is "close")
    d0 = np.asarray(jax.nn.log_softmax(traces["dense"][0]))
    q0 = np.asarray(jax.nn.log_softmax(traces["mxfp4"][0]))
    assert np.max(np.abs(d0 - q0)) < 2.5
    assert np.mean(np.abs(d0 - q0)) < 0.5


# ---------------------------------------------------------------------------
# scheduler behaviour
# ---------------------------------------------------------------------------


def test_engine_queueing_and_slot_reuse():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(5)
    engine = Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, page_size=8, kv_dtype="mxfp4", prefill_chunk=8))

    handles = [engine.submit(rng.integers(0, cfg.vocab_size, 5 + i).astype(np.int32), 3)
               for i in range(5)]  # 5 requests, 2 slots
    assert len(engine.sched.queue) == 5
    engine.step()
    assert len(engine.sched.active) == 2  # only 2 admitted
    engine.drain()
    assert all(h.done and len(h.tokens) == 3 for h in handles)
    assert engine.cache.free_pages == engine.cache.n_pages - 1  # all recycled
    assert len(engine.sched.free_slots) == 2


def test_engine_eos_early_stop():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    first = int(greedy_generate(model, params, jnp.asarray(prompt)[None],
                                max_new=1, max_len=16)[0, 0])

    engine = Engine(model, params, dataclasses.replace(
        EngineConfig(n_slots=2, max_len=32, page_size=8, kv_dtype="dense",
                     prefill_chunk=8), eos_id=first))
    h = engine.submit(prompt, 8)
    engine.drain()
    assert h.tokens == [first] and h.finish_reason == "eos"
