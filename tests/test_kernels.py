"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exactness.

Kernels run interpret=True on CPU (the Pallas interpreter executes the
kernel body faithfully); the oracles are independent implementations from
repro.core, so agreement is a real two-implementation check.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastrng
from repro.kernels import ref as R
from repro.kernels.hadamard_quant import hadamard_quest_quantize
from repro.kernels.mxfp4_matmul import mxfp4_matmul
from repro.kernels.sr_hadamard_quant import sr_hadamard_quantize

pytestmark = pytest.mark.kernels

SHAPES = [(32, 32), (8, 64), (96, 256), (128, 96), (257, 64), (64, 1024)]
BLOCKS = [(32, 32), (64, 128), (256, 512)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hadamard_quest_kernel_vs_ref(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 1.9).astype(dtype)
    c1, s1, m1 = hadamard_quest_quantize(x, block_m=64, block_k=128)
    c2, s2, m2 = R.hadamard_quest_quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=0)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@pytest.mark.parametrize("bm,bk", BLOCKS)
def test_hadamard_quest_kernel_block_sweep(bm, bk):
    x = jax.random.normal(jax.random.PRNGKey(1), (160, 512)) * 0.7
    c1, s1, m1 = hadamard_quest_quantize(x, block_m=bm, block_k=bk)
    c2, s2, m2 = R.hadamard_quest_quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("shape", SHAPES)
def test_sr_kernel_vs_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(2), shape) * 2.3
    signs = fastrng.rademacher(jnp.uint32(9), shape[1])
    u = fastrng.uniform(jnp.uint32(5), shape)
    c1, s1 = sr_hadamard_quantize(x, signs, u, block_m=64, block_k=128)
    c2, s2 = R.sr_hadamard_quantize_ref(x, signs, u)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_sr_kernel_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32)) * 1.1
    signs = jnp.ones((32,), jnp.float32)

    def one(seed):
        u = fastrng.uniform(seed, (4, 32))
        c, s = sr_hadamard_quantize(x, signs, u, block_m=4, block_k=32,
                                    prescale=1.0)
        return c.astype(jnp.float32) * 0.5 * s[..., :1]

    n = 3000
    vals = jax.vmap(one)(jnp.arange(n, dtype=jnp.uint32))
    from repro.core.hadamard import hadamard_transform
    target = hadamard_transform(x, g=32)
    err = np.abs(np.asarray(vals.mean(0) - target)).max()
    assert err < 0.08  # ≈ 5σ MC bound for gap ≤ 1


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 128, 96), (96, 256, 192),
                                   (100, 64, 50), (256, 512, 128)])
def test_mxfp4_matmul_vs_ref(m, k, n):
    x = jax.random.normal(jax.random.PRNGKey(4), (m, k)) * 1.5
    w = jax.random.normal(jax.random.PRNGKey(5), (k, n)) * 0.5
    ac, asc, _ = R.hadamard_quest_quantize_ref(x)
    bct, bsct, _ = R.hadamard_quest_quantize_ref(w.T)
    bc, bsc = bct.T, bsct.T
    y1 = mxfp4_matmul(ac, asc, bc, bsc, block_m=64, block_n=64, block_k=128)
    y2 = R.mxfp4_matmul_ref(ac, asc, bc, bsc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6, atol=1e-5)


def test_matmul_block_sweep():
    x = jax.random.normal(jax.random.PRNGKey(6), (128, 256))
    w = jax.random.normal(jax.random.PRNGKey(7), (256, 128))
    ac, asc, _ = R.hadamard_quest_quantize_ref(x)
    bct, bsct, _ = R.hadamard_quest_quantize_ref(w.T)
    ref = R.mxfp4_matmul_ref(ac, asc, bct.T, bsct.T)
    for bm, bn, bk in [(32, 32, 32), (128, 128, 256), (64, 128, 64)]:
        y = mxfp4_matmul(ac, asc, bct.T, bsct.T, block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-6, atol=1e-5)


def test_kernel_path_forward_matches_jnp_path():
    """quartet_linear(use_kernels=True) ≡ the jnp reference path (bit-exact
    QDQ forward)."""
    from repro.core.quartet import QuartetConfig, quartet_linear
    x = jax.random.normal(jax.random.PRNGKey(8), (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(9), (256, 128)) * 0.05
    yk = quartet_linear(x, w, jnp.uint32(5), QuartetConfig(use_kernels=True))
    yj = quartet_linear(x, w, jnp.uint32(5), QuartetConfig(use_kernels=False))
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yj), atol=1e-5)


def test_kernel_path_backward_close_to_jnp_path():
    from repro.core.quartet import QuartetConfig, quartet_linear
    x = jax.random.normal(jax.random.PRNGKey(10), (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(11), (256, 128)) * 0.05
    f = lambda cfg: jax.grad(
        lambda a, b: jnp.sum(quartet_linear(a, b, jnp.uint32(3), cfg) ** 2),
        argnums=(0, 1))(x, w)
    gk = f(QuartetConfig(use_kernels=True))
    gj = f(QuartetConfig(use_kernels=False))
    for a, b in zip(gk, gj):
        cos = float(jnp.vdot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
        assert cos > 0.95  # same algorithm, independent SR randomness


@pytest.mark.parametrize("s,t,causal", [(128, 128, True), (128, 128, False),
                                        (256, 384, False), (100, 150, False),
                                        (64, 64, True)])
def test_flash_attention_vs_ref(s, t, causal):
    from repro.kernels.flash_attention import flash_attention
    if causal:
        t = s  # causal masking assumes aligned q/kv positions
    q = jax.random.normal(jax.random.PRNGKey(0), (4, s, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (4, t, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (4, t, 64))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = R.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_mha_flash_matches_blocked_attention():
    """The Pallas serving kernel ≡ the jnp training attention (GQA)."""
    from repro.kernels.flash_attention import mha_flash
    from repro.models.attention import blocked_attention
    B, S, Hq, Hkv, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hq, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_flash = mha_flash(q, k, v, causal=True, block_q=64, block_k=64)
    out_jnp = blocked_attention(q, k, v, pos, causal=True, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out_flash, np.float32),
                               np.asarray(out_jnp, np.float32),
                               rtol=2e-3, atol=2e-3)
