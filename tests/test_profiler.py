"""Engine performance profiler: trace export, cost accounting, the
zero-interference contract, and the bench-regression gate.

Pinned here:

* Chrome trace-event documents are structurally valid (required keys,
  per-lane monotonic microsecond timestamps, metadata events) and a real
  engine run's trace carries tick-phase spans, request-lifecycle spans, and
  jit-compile events on one shared clock,
* per-call cost accounting is **deterministic**: AOT-lowering the same
  engine's steps twice (and on a freshly-built identical engine) yields
  bit-identical FLOPs/bytes — the HLO is a pure function of the avals,
* **zero interference**: profiling on vs off emits bit-identical tokens and
  compiles exactly the same step shapes (AOT ``lower().compile()`` never
  touches the call-site jit cache),
* roofline-utilization / effective-bandwidth gauges appear in the snapshot
  with physical values, and ``profile_report`` produces a schema-valid v4
  ``profile`` block,
* the regression gate passes a baseline against itself, soft-warns (and
  strict-fails) on an injected 20% throughput regression, hard-fails on
  parity/deterministic drift or a hard field going null, and the CLI exits
  nonzero accordingly.
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serve import Engine, EngineConfig, SpecConfig, TelemetryConfig
from repro.serve.telemetry import CATALOG
from repro.serve.telemetry.profiling import (EngineProfiler, TraceEventSink,
                                             profile_report,
                                             step_example_args,
                                             validate_trace,
                                             validate_trace_file, write_trace)
from repro.serve.telemetry import regression
from repro.serve.telemetry.schema import validate_bench

pytestmark = pytest.mark.profile

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _run_engine(model, params, cfg, *, telemetry=None, spec=None,
                n_requests=3, max_new=5):
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8,
        telemetry=telemetry, spec=spec))
    rng = np.random.default_rng(7)
    for i in range(n_requests):
        eng.submit(rng.integers(1, cfg.vocab_size, size=5 + 3 * i),
                   max_new=max_new, arrival_time=0.0)
    t = 0.0
    while eng.sched.pending:
        eng.step(now=t)
        t += 0.01
    return eng, t


# ---------------------------------------------------------------------------
# trace-event sink: schema + timestamps
# ---------------------------------------------------------------------------


def test_trace_sink_schema_and_monotonic_ts(tmp_path):
    sink = TraceEventSink(pid=0, process_name="engine")
    sink.complete("tick", "tick", ts_s=0.0, dur_s=0.01)
    sink.complete("decode", "phase", ts_s=0.001, dur_s=0.005)
    sink.instant("jit_compile:decode_all", "compile", ts_s=0.002)
    sink.thread_name(2, "req 0")
    sink.complete("queued", "request", ts_s=0.0, dur_s=0.01, tid=2)
    path = str(tmp_path / "trace.json")
    doc = write_trace(path, [sink])
    assert validate_trace(doc) == []
    on_disk = validate_trace_file(path)  # raises on structural problems
    assert on_disk == doc
    evs = doc["traceEvents"]
    # metadata first: process_name + both thread lanes
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in metas} == {"process_name", "thread_name"}
    # complete events carry microsecond ts/dur
    tick = next(e for e in evs if e["name"] == "tick")
    assert tick["ph"] == "X" and tick["dur"] == pytest.approx(10_000)
    # payload sorted by timestamp within the document
    payload_ts = [e["ts"] for e in evs if e["ph"] != "M"]
    assert payload_ts == sorted(payload_ts)
    # negative durations are clamped, never emitted
    sink.complete("weird", "phase", ts_s=1.0, dur_s=-5.0)
    assert all(e.get("dur", 0) >= 0 for e in sink.trace_events())


def test_validate_trace_rejects_broken_docs():
    assert validate_trace({}) == ["missing traceEvents"]
    assert validate_trace({"traceEvents": []}) != []
    bad_ts = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 10.0, "dur": 1.0},
        {"name": "b", "ph": "X", "pid": 0, "tid": 0, "ts": 5.0, "dur": 1.0}]}
    assert any("monotonic" in e for e in validate_trace(bad_ts))
    no_dur = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 1.0}]}
    assert any("dur" in e for e in validate_trace(no_dur))
    missing = {"traceEvents": [{"ph": "X", "ts": 1.0, "dur": 1.0}]}
    errs = validate_trace(missing)
    assert any("name" in e for e in errs) and any("pid" in e for e in errs)


# ---------------------------------------------------------------------------
# cost accounting: determinism + physical sanity
# ---------------------------------------------------------------------------


def test_cost_accounting_deterministic(dense_setup):
    cfg, model, params = dense_setup
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8))
    p1 = EngineProfiler(eng, registry=None)
    p2 = EngineProfiler(eng, registry=None)
    c1, c2 = p1.phase_costs(), p2.phase_costs()
    assert c1 == c2, "same engine, different costs — lowering is not pure"
    assert set(c1) >= {"decode_all", "prefill_all", "prefill_chunk"}
    for name, cost in c1.items():
        assert cost["flops"] > 0, f"{name}: zero FLOPs"
        assert cost["hbm_bytes"] > 0, f"{name}: zero bytes"
    # batched prefill over a chunk costs more than a single decode token
    assert c1["prefill_all"]["flops"] > c1["decode_all"]["flops"]
    # a second, identically-configured engine costs the same (avals define it)
    eng2 = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8))
    assert EngineProfiler(eng2, registry=None).phase_costs() == c1


def test_step_example_args_cover_spec_verify(dense_setup):
    cfg, model, params = dense_setup
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=48, page_size=8, prefill_chunk=8,
        spec=SpecConfig(k=3, proposer="self")))
    examples = step_example_args(eng)
    assert "verify_all" in examples
    # verify operand is the k+1 multi-query token block
    assert examples["verify_all"][1].shape == (2, 4)
    costs = EngineProfiler(eng, registry=None).phase_costs()
    # verifying k+1 tokens costs more than decoding one
    assert costs["verify_all"]["flops"] > costs["decode_all"]["flops"]


# ---------------------------------------------------------------------------
# zero interference + live gauges + trace contents
# ---------------------------------------------------------------------------


def test_zero_interference_profiling_on_vs_off(dense_setup, tmp_path):
    cfg, model, params = dense_setup
    plain, _ = _run_engine(model, params, cfg, telemetry=None)
    profiled, t = _run_engine(
        model, params, cfg,
        telemetry=TelemetryConfig(
            profile_trace_path=str(tmp_path / "trace.json")))
    # token streams bit-identical
    assert ({r.rid: r.tokens for r in plain.completed}
            == {r.rid: r.tokens for r in profiled.completed})
    # exactly the same step shapes compiled: the profiler's AOT
    # lower().compile() never populates the call-site jit cache
    assert plain.compile_counts() == profiled.compile_counts()
    assert profiled.compile_counts()["decode_all"] == 1
    assert profiled.compile_counts()["prefill_all"] == 1
    assert profiled.compile_counts()["prefill_chunk"] == 0  # paged path

    snap = profiled.telemetry.finalize(t)
    g = snap["gauges"]
    assert g["profile_flops_per_call_decode"] > 0
    assert g["profile_hbm_bytes_per_call_decode"] > 0
    assert 0 < g["roofline_util_decode"] <= 1.0
    assert g["effective_bw_decode"] > 0
    assert g["roofline_util_prefill"] > 0
    # profiler gauges are declared in the catalog (snapshot schema stability)
    for phase in ("prefill", "decode", "verify"):
        for stem in ("profile_flops_per_call_", "profile_hbm_bytes_per_call_",
                     "roofline_util_", "effective_bw_"):
            assert CATALOG[stem + phase][0] == "gauge"

    doc = validate_trace_file(str(tmp_path / "trace.json"))
    evs = doc["traceEvents"]
    cats = {e.get("cat") for e in evs if e["ph"] != "M"}
    assert {"tick", "phase", "request", "compile"} <= cats
    # every engine tick got a span, every phase span sits on the tick lane
    ticks = [e for e in evs if e.get("cat") == "tick"]
    assert len(ticks) == snap["counters"]["engine_ticks"]
    phases = [e for e in evs if e.get("cat") == "phase"]
    assert {e["name"] for e in phases} <= {"prefill", "decode", "verify"}
    assert all(e["tid"] == 0 for e in ticks + phases)
    # request lanes: one queued/prefill/decode span triple per retired request
    reqs = [e for e in evs if e.get("cat") == "request" and e["ph"] == "X"]
    assert {e["name"] for e in reqs} == {"queued", "prefill", "decode"}
    assert len({e["tid"] for e in reqs}) == 3  # one lane per request
    # compile events name the step and happened on the engine lane
    compiles = [e for e in evs if e.get("cat") == "compile"]
    assert {e["name"] for e in compiles} >= {"jit_compile:decode_all",
                                             "jit_compile:prefill_all"}


def test_profiling_off_has_no_profiler(dense_setup):
    cfg, model, params = dense_setup
    eng, t = _run_engine(model, params, cfg, telemetry=None)
    assert eng.telemetry.profiler is None
    snap = eng.telemetry.snapshot(t)
    # gauges exist (catalog) but stay at zero with profiling off
    assert snap["gauges"]["roofline_util_decode"] == 0.0
    assert snap["gauges"]["profile_flops_per_call_decode"] == 0.0


# ---------------------------------------------------------------------------
# bench profile block (schema v4)
# ---------------------------------------------------------------------------


def test_profile_report_schema_valid(dense_setup):
    import importlib.util
    import pathlib
    cfg, model, params = dense_setup
    eng, t = _run_engine(model, params, cfg)
    snap = eng.telemetry.finalize(t)
    block = profile_report(eng, snap)
    assert block is not None
    assert block["decode"] is not None
    assert block["decode"]["flops_per_call"] > 0
    assert block["decode"]["calls"] == snap["counters"]["decode_calls"]
    assert block["decode"]["roofline_util_mean"] > 0
    assert block["verify"] is None  # no speculation in this run
    # splice the block into a minimal bench doc: must validate as v4
    mod_path = (pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
                / "serve_throughput.py")
    spec = importlib.util.spec_from_file_location("serve_throughput", mod_path)
    st = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(st)
    num = {"mxfp4": dict.fromkeys(
        ("tokens_per_sec", "ttft_p50_s", "ttft_p95_s", "tpot_p50_s",
         "tpot_p95_s", "latency_p50_s", "latency_p95_s", "queue_wait_p50_s",
         "decode_tick_p50_s", "decode_tick_p95_s", "prefill_tick_p50_s",
         "pool_occupancy_peak", "free_page_watermark", "cache_bytes",
         "bits_per_kv_elem"), 1.0)}
    num["dense"] = dict(num["mxfp4"])
    rep = {
        "arch": "a", "family": "dense", "n_requests": 2, "max_new": 2,
        "n_slots": 2, **num,
        "decode_backends": {"mxfp4/gather": {"tokens_per_sec": 1.0}},
        "cache_ratio": 3.8, "decode_bytes_ratio_gather_over_paged": 8.0,
        "spec": {"k": 3, "proposer": "self"},
        "profile": block,
    }
    doc = st.make_bench_baseline(rep)
    assert validate_bench(doc) == []
    # the whole section and each phase block are nullable
    doc["profile"]["verify"] = None
    assert validate_bench(doc) == []
    doc["profile"] = None
    assert validate_bench(doc) == []
    # but a present phase block must be complete
    doc["profile"] = {"peak_flops": 1.0, "peak_bw": 1.0,
                      "prefill": None, "decode": {"flops_per_call": 1.0},
                      "verify": None}
    assert any("decode" in e for e in validate_bench(doc))


# ---------------------------------------------------------------------------
# regression gate
# ---------------------------------------------------------------------------


def _fake_bench() -> dict:
    """A small but structurally faithful bench doc for gate tests."""
    return {
        "schema": "repro.bench_serve/v4",
        "arch": "qwen3-1.7b-reduced",
        "family": "dense",
        "config": {"n_requests": 4, "max_new": 4, "n_slots": 2},
        "throughput": {"mxfp4_paged_tok_per_s": 100.0,
                       "dense_paged_tok_per_s": 120.0,
                       "mxfp4_gather_tok_per_s": 80.0},
        "latency": {"ttft_p50_s": 0.1, "ttft_p95_s": 0.2},
        "kv": {"cache_bytes_dense": 1000, "cache_bytes_mxfp4": 266,
               "cache_ratio": 3.76,
               "decode_bytes_ratio_gather_over_paged": 8.5},
        "spec": {"k": 3, "proposer": "self", "acceptance_rate": 1.0},
        "sharding": None,
        "profile": {"peak_flops": 1e14, "peak_bw": 8e11,
                    "decode": {"flops_per_call": 2e7,
                               "hbm_bytes_per_call": 1e6},
                    "verify": None},
    }


def test_gate_passes_on_identical_docs():
    base = _fake_bench()
    ok, deltas, report = regression.gate(base, json.loads(json.dumps(base)))
    assert ok
    assert not any(d.failed or d.warned for d in deltas)
    assert "PASS" in report


def test_gate_on_injected_20pct_throughput_regression():
    base = _fake_bench()
    fresh = json.loads(json.dumps(base))
    fresh["throughput"]["mxfp4_paged_tok_per_s"] *= 0.8  # -20%
    # wall-clock metrics are soft: visible warning, clean exit by default…
    ok, deltas, report = regression.gate(base, fresh)
    assert ok
    d = next(d for d in deltas
             if d.path == "throughput.mxfp4_paged_tok_per_s")
    assert d.warned and d.rel == pytest.approx(-0.2)
    assert "WARN" in report
    # …and a demonstrable failure under --strict (dedicated hardware)
    ok_strict, _, report_strict = regression.gate(base, fresh, strict=True)
    assert not ok_strict
    assert "FAIL" in report_strict
    # a within-band wobble (-5%) neither warns nor fails
    mild = json.loads(json.dumps(base))
    mild["throughput"]["mxfp4_paged_tok_per_s"] *= 0.95
    ok_mild, deltas_mild, _ = regression.gate(base, mild, strict=True)
    assert ok_mild and not any(x.warned for x in deltas_mild)


def test_gate_hard_fails_on_parity_fields():
    base = _fake_bench()
    # deterministic compression ratio drifts → hard fail, no --strict needed
    worse = json.loads(json.dumps(base))
    worse["kv"]["cache_ratio"] = 1.1
    ok, deltas, _ = regression.gate(base, worse)
    assert not ok
    assert next(d for d in deltas if d.path == "kv.cache_ratio").failed
    # a hard field going null (the paged path disappeared) → hard fail
    gone = json.loads(json.dumps(base))
    gone["kv"]["decode_bytes_ratio_gather_over_paged"] = None
    ok, deltas, _ = regression.gate(base, gone)
    assert not ok
    # schema mismatch → hard fail
    old = json.loads(json.dumps(base))
    old["schema"] = "repro.bench_serve/v3"
    ok, _, _ = regression.gate(base, old)
    assert not ok
    # both-null sections compare clean; newly-measured fields never fail
    base2 = json.loads(json.dumps(base))
    fresh2 = json.loads(json.dumps(base))
    fresh2["profile"]["verify"] = {"flops_per_call": 1.0}
    ok, deltas, _ = regression.gate(base2, fresh2)
    assert ok
    assert all(d.status in ("ok", "new", "info", "gone")
               for d in deltas if d.path.startswith("profile."))


def test_gate_cli_exit_codes(tmp_path):
    base_path = tmp_path / "base.json"
    fresh_path = tmp_path / "fresh.json"
    base = _fake_bench()
    base_path.write_text(json.dumps(base))
    fresh_path.write_text(json.dumps(base))
    argv = [str(fresh_path), "--baseline", str(base_path)]
    assert regression.main(argv) == 0
    bad = json.loads(json.dumps(base))
    bad["throughput"]["mxfp4_paged_tok_per_s"] *= 0.8
    fresh_path.write_text(json.dumps(bad))
    assert regression.main(argv) == 0          # soft by default
    assert regression.main(argv + ["--strict"]) == 1
    bad["kv"]["cache_ratio"] = 1.0
    fresh_path.write_text(json.dumps(bad))
    out_json = tmp_path / "report.json"
    assert regression.main(argv + ["--json", str(out_json)]) == 1
    rows = json.loads(out_json.read_text())
    assert any(r["path"] == "kv.cache_ratio" and r["status"] == "fail"
               for r in rows)
    assert regression.main([str(tmp_path / "missing.json"),
                            "--baseline", str(base_path)]) == 2


def test_gate_accepts_committed_baseline_against_itself():
    """The committed BENCH_serve.json must pass the gate vs itself — the
    exact comparison CI's smoke job re-runs with a fresh measurement."""
    import pathlib
    bench_path = (pathlib.Path(__file__).resolve().parent.parent
                  / "BENCH_serve.json")
    base = json.loads(bench_path.read_text())
    ok, _, report = regression.gate(base, json.loads(json.dumps(base)))
    assert ok, report
