"""Distribution-layer tests: sharding rules, cache partitioning, and a
small-mesh end-to-end lowering (8 fake devices, subprocess — the main test
process must keep seeing 1 device)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as SH


class FakeMesh:
    """axis_names/shape-only stand-in (rule logic is pure arithmetic)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _spec(path, shape, mesh=MESH):
    return SH._spec_for(path, shape, mesh, SH.fsdp_axes(mesh, True))


def test_column_parallel_rules():
    assert _spec("layers/attn/wq/w", (28, 2048, 2048)) == P(None, ("data",), "model")
    assert _spec("layers/mlp/gate/w", (28, 2048, 6144)) == P(None, ("data",), "model")


def test_row_parallel_rules():
    assert _spec("layers/attn/wo/w", (28, 2048, 2048)) == P(None, "model", ("data",))
    assert _spec("layers/mlp/down/w", (28, 6144, 2048)) == P(None, "model", ("data",))


def test_moe_expert_rules():
    assert _spec("layers/moe/gate", (94, 128, 4096, 1536)) == \
        P(None, "model", ("data",), None)
    assert _spec("layers/moe/down", (94, 128, 1536, 4096)) == \
        P(None, "model", None, ("data",))


def test_embed_rules():
    assert _spec("embed/table", (151936, 2048)) == P("model", ("data",))


def test_divisibility_fallback():
    # 24 heads × hd 128 = 3072 divides 16 → sharded via the fused projection
    assert _spec("layers/attn/wq/w", (30, 3072, 3072)) == P(None, ("data",), "model")
    # a dim that does NOT divide the axis falls back to None
    assert _spec("layers/attn/wq/w", (2, 100, 100)) == P(None, None, None)


def test_norms_replicated():
    assert _spec("layers/attn_norm/scale", (28, 2048)) == P(None, None)


def test_multipod_fsdp_includes_pod():
    spec = _spec("layers/mlp/gate/w", (28, 2048, 6144), MESH3)
    assert spec == P(None, ("pod", "data"), "model")


def test_batch_partition_fallbacks():
    assert SH.batch_partition(MESH, 256, 4096) == P(("data",), None)
    # batch of 1: context parallelism over the sequence
    assert SH.batch_partition(MESH, 1, 524288) == P(None, "data")
    assert SH.batch_partition(MESH3, 256, 4096) == P(("pod", "data"), None)


def test_cache_partition_heads_and_seq():
    cache = jax.ShapeDtypeStruct((28, 128, 32768, 8, 128), jnp.bfloat16)
    spec = SH.cache_partition(cache, MESH, 128)
    # batch → data; kv-heads too small (8 < 16) → largest dim (seq) → model
    assert spec == P(None, ("data",), "model", None, None)
    long = jax.ShapeDtypeStruct((13, 1, 524288, 32, 112), jnp.bfloat16)
    spec = SH.cache_partition(long, MESH, 1)
    assert spec[3] == "model" and "data" in spec  # heads→model, seq→data


def test_param_partition_covers_whole_tree():
    """Every leaf of a real model gets a spec of matching rank."""
    from repro.models import build_model
    from repro.configs import get_reduced_config
    cfg = get_reduced_config("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = FakeMesh({"data": 2, "model": 2})
    specs = SH.param_partition(params, mesh)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) == len(p.shape)


DRYRUN_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import repro.launch.dryrun as DR

# shrink the production mesh to 2x4 for the in-CI lowering (M._mk handles
# the AxisType presence/absence across jax versions)
import repro.launch.mesh as M
M.make_production_mesh = lambda multi_pod=False: M._mk(
    (2, 2, 2) if multi_pod else (2, 4),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
DR.make_production_mesh = M.make_production_mesh

import repro.configs.registry as REG
import dataclasses
cfg = REG.get_reduced_config("qwen3-1.7b")
REG._MODULES_SAVE = None
orig_get = REG.get_config
REG.get_config = lambda name, **kw: cfg
DR.get_config = REG.get_config

rep, _, compiled = DR.lower_cell("qwen3-1.7b", "train_4k", False)
assert rep["compile_s"] >= 0
assert compiled.cost_analysis() is not None
rep2, _, c2 = DR.lower_cell("qwen3-1.7b", "decode_32k", True)
print("OK", rep["dominant"], rep2["mesh"])
"""


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """End-to-end dry-run machinery on an 8-device fake mesh (subprocess so
    the parent keeps its single-device view)."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", DRYRUN_SMOKE], capture_output=True,
                       text=True, timeout=1200, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
