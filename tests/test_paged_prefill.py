"""Batched paged prefill: one jitted call advances every prefilling slot.

Contracts pinned here:

* **Token-exactness (dense pool)** — the batched paged-prefill engine is
  token-for-token identical to BOTH the per-slot gather prefill oracle
  (``decode_backend="gather"``) and sequential ``greedy_generate``, over
  ragged prompt lengths that straddle page boundaries and prompts shorter
  than one chunk.
* **Chunk invariance (mxfp4 pool)** — on the paged path every token's KV is
  quantized on write and every query reads the packed pool, so prefill
  results do not depend on the chunk decomposition at all: chunk = 8, 3 and
  1 produce identical streams.  (The gather oracle does NOT have this
  property — inside a chunk it attends to raw pre-quantization KV — which is
  the same carve-out the speculative verify documents for mxfp4+gather.)
* **Batching** — all prefilling paged slots advance through ONE
  ``prefill_all`` invocation per engine tick; the per-slot ``[1, C]`` /
  ``[1, 1]`` shapes never run on the default paged backend.
* **Write masking** — ragged-tail padding never corrupts live pages: a
  prompt whose final chunk is mostly padding still matches the oracle.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serve import Engine, EngineConfig
from repro.train.serve import greedy_generate

KEY = jax.random.PRNGKey(0)

# prompt lengths chosen to straddle page (8) and chunk (8) boundaries:
# shorter than one chunk, exactly one chunk/page, chunk+1, two pages + 1
RAGGED_LENS = (3, 8, 9, 17)


@pytest.fixture(scope="module")
def qwen_setup():
    cfg = get_reduced_config("qwen3-1.7b")
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _prompts(cfg, lens=RAGGED_LENS, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in lens]


def _run(model, params, prompts, max_new=4, *, kv="dense", backend="paged",
         prefill_chunk=8, n_slots=4, keep_logits=False):
    eng = Engine(model, params, EngineConfig(
        n_slots=n_slots, max_len=32, page_size=8, kv_dtype=kv,
        prefill_chunk=prefill_chunk, decode_backend=backend,
        keep_logits=keep_logits))
    handles = [eng.submit(p, max_new) for p in prompts]
    eng.drain()
    return eng, handles


def test_batched_prefill_token_exact_dense(qwen_setup):
    """paged prefill ≡ gather-oracle prefill ≡ greedy_generate, dense pool,
    ragged concurrent prompts straddling page boundaries."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg)
    _, paged_h = _run(model, params, prompts, backend="paged")
    _, gather_h = _run(model, params, prompts, backend="gather")
    for p, hp, hg in zip(prompts, paged_h, gather_h):
        assert hp.tokens == hg.tokens
        ref = greedy_generate(model, params, jnp.asarray(p)[None], max_new=4,
                              max_len=int(p.size) + 4)
        assert hp.tokens == ref[0].tolist()


def test_batched_prefill_token_exact_dense_moe():
    """MoE prompts route per token through top-k experts — batched prefill
    (padding rows included) must not perturb real tokens' routing."""
    cfg = get_reduced_config("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(KEY)
    prompts = _prompts(cfg, lens=(5, 9, 12), seed=13)
    _, paged_h = _run(model, params, prompts, max_new=3, backend="paged",
                      n_slots=3)
    _, gather_h = _run(model, params, prompts, max_new=3, backend="gather",
                       n_slots=3)
    for p, hp, hg in zip(prompts, paged_h, gather_h):
        assert hp.tokens == hg.tokens
        ref = greedy_generate(model, params, jnp.asarray(p)[None], max_new=3,
                              max_len=int(p.size) + 3)
        assert hp.tokens == ref[0].tolist()


def test_mxfp4_prefill_chunk_invariant(qwen_setup):
    """The paged path quantizes-then-attends uniformly, so mxfp4 prefill is
    exactly invariant to the chunk decomposition (8 vs 3 vs 1) — a stronger
    contract than the gather oracle, whose intra-chunk attention reads raw
    KV, can offer."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg)
    streams = []
    for chunk in (8, 3, 1):
        _, hs = _run(model, params, prompts, kv="mxfp4", backend="paged",
                     prefill_chunk=chunk)
        streams.append([h.tokens for h in hs])
    assert streams[0] == streams[1] == streams[2]


def test_mxfp4_prefill_bounded_vs_gather(qwen_setup):
    """mxfp4 paged prefill quantizes in-chunk KV before intra-chunk attention
    (slightly stronger quantization than the gather oracle applies) — the
    first generated position's distribution stays within the usual 4-bit
    tolerance of the oracle's."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg, lens=(11,))
    _, hp = _run(model, params, prompts, kv="mxfp4", backend="paged",
                 keep_logits=True)
    _, hg = _run(model, params, prompts, kv="mxfp4", backend="gather",
                 keep_logits=True)
    a = np.asarray(jax.nn.log_softmax(hp[0].logits_trace[0]))
    b = np.asarray(jax.nn.log_softmax(hg[0].logits_trace[0]))
    assert np.max(np.abs(a - b)) < 2.5
    assert np.mean(np.abs(a - b)) < 0.5


def test_one_prefill_call_per_tick(qwen_setup):
    """ALL prefilling paged slots advance in ONE jitted prefill_all call per
    engine tick — no per-slot loop, no remainder-single calls."""
    cfg, model, params = qwen_setup
    prompts = _prompts(cfg)  # 4 concurrent prefills, ragged lengths
    eng = Engine(model, params, EngineConfig(
        n_slots=4, max_len=32, page_size=8, kv_dtype="mxfp4",
        prefill_chunk=8, decode_backend="paged"))
    calls = []
    inner = eng._prefill_all

    def counted(*args, **kw):
        calls.append(1)
        return inner(*args, **kw)

    eng._prefill_all = counted
    for p in prompts:
        eng.submit(p, 2)
    eng.step()  # admit + first chunk for all four slots
    assert len(calls) == 1
    # longest prompt is 17 = 8 + 8 + 1 → exactly 3 prefill ticks total, each
    # one call, regardless of the ragged tails of the other slots
    eng.drain()
    assert len(calls) == 3
    assert all(h.done for h in eng.completed)


def test_gather_oracle_keeps_per_slot_prefill(qwen_setup):
    """decode_backend="gather" must NOT take the batched path (it is the
    parity oracle for exactly that path)."""
    cfg, model, params = qwen_setup
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, page_size=8, kv_dtype="dense",
        prefill_chunk=8, decode_backend="gather"))
    assert eng._prefill_all is None


def test_dense_slot_families_keep_per_slot_prefill():
    """SSM recurrences must never consume padding — dense-slot families keep
    the chunk-then-singles per-slot prefill and stay token-exact."""
    cfg = get_reduced_config("falcon-mamba-7b")
    model = build_model(cfg)
    params = model.init(KEY)
    prompts = _prompts(cfg, lens=(7, 12), seed=5)
    eng = Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, kv_dtype="dense", prefill_chunk=8))
    assert eng._prefill_all is None
    handles = [eng.submit(p, 3) for p in prompts]
    eng.drain()
    for p, h in zip(prompts, handles):
        ref = greedy_generate(model, params, jnp.asarray(p)[None], max_new=3,
                              max_len=int(p.size) + 3)
        assert h.tokens == ref[0].tolist()
