"""GQA attention behind one pluggable backend dispatch.

Every family (dense/MoE/hybrid/enc-dec/VLM) routes its attention through
:func:`attention` → :func:`dispatch_attention`, which selects the backend
from ``ModelConfig.attn_backend``:

* ``"blocked"`` — the differentiable jnp reference below: an online-softmax
  ``lax.scan`` over KV chunks; the S×S score matrix is never materialized,
  which is what makes the 32k-prefill and 500k-decode shapes lowerable.
* ``"flash"``   — the Pallas flash kernel (``kernels/flash_attention``), used
  for from-scratch self-attention (S == T); cached/offset shapes fall back
  to ``blocked``.
* ``"paged"``   — batched decode attends *directly over packed MXFP4 pages*
  via ``kernels/paged_attention`` whenever the cache operand is a
  :class:`~repro.kernels.paged_attention.PagedKV`; dense (non-decode) call
  sites behave as ``blocked``.

All four projections (QKV + output) go through the Quartet linear.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import PagedKV, paged_attention, scatter_token
from repro.models import layers as L

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype, d_kv_source: int | None = None):
    d, hd = cfg.d_model, cfg.head_dim_
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dsrc = d_kv_source or d
    ks = jax.random.split(key, 6)
    p = {
        "wq": L.init_dense(ks[0], d, nq * hd, dtype, cfg.use_bias),
        "wk": L.init_dense(ks[1], dsrc, nkv * hd, dtype, cfg.use_bias),
        "wv": L.init_dense(ks[2], dsrc, nkv * hd, dtype, cfg.use_bias),
        "wo": L.init_dense(ks[3], nq * hd, d, dtype, cfg.use_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd, dtype)
        p["k_norm"] = L.init_rmsnorm(hd, dtype)
    return p


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def blocked_attention(
    q: jnp.ndarray,  # [B, S, Hq, hd]
    k: jnp.ndarray,  # [B, T, Hkv, hd]
    v: jnp.ndarray,  # [B, T, Hkv, hd]
    q_positions: jnp.ndarray,  # [B, S] absolute positions
    causal: bool,
    kv_chunk: int,
    shared_mask: bool = True,  # rows share q_positions (train/prefill); False
    #                            for batched multi-token verify (per-slot
    #                            starts → genuinely per-row masks)
) -> jnp.ndarray:
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = 1.0 / np.sqrt(hd)
    # pad T up to a chunk multiple instead of shrinking the chunk: a 1500-
    # frame encoder would otherwise degrade to ck=4 → a 375-step scan whose
    # saved backward carries cost ~14 GB/device.  Padded keys are masked.
    T_orig = T
    ck = min(kv_chunk, T)
    pad_t = (-T) % ck
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        T = T + pad_t
    nck = T // ck
    need_pad_mask = pad_t > 0

    # For S > 1 (train/prefill) every batch row uses the same arange
    # positions; building the mask per-row would materialize a [B,S,ck] pred
    # that XLA hoists out of the layer scan as a multi-GB loop invariant.
    # Row-shared masks are [S, ck] — 1000× smaller.  Decode (S == 1) and the
    # spec-verify burst (shared_mask=False: each slot starts at its own
    # position) keep genuinely per-row positions; both are small shapes.
    shared_rows = S > 1 and shared_mask
    mpos = q_positions[:1] if shared_rows else q_positions  # [1|B, S]

    qf = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, group, hd)
    # keep the KV stream in its storage dtype; casting the WHOLE cache to f32
    # up-front would materialize 2× the cache (16 GB for a 32k MHA decode) —
    # each chunk is cast in VMEM-sized pieces inside the scan body
    kc = k.reshape(B, nck, ck, Hkv, hd)
    vc = v.reshape(B, nck, ck, Hkv, hd)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp  # kj/vj: [B, ck, Hkv, hd]
        kj = kj.astype(jnp.float32)
        vj = vj.astype(jnp.float32)
        s = jnp.einsum("bskgd,bckd->bskgc", qf, kj,
                       preferred_element_type=jnp.float32)  # k=Hkv, g=group
        kv_pos = j * ck + jnp.arange(ck)
        if causal:
            mask = mpos[:, :, None, None, None] >= kv_pos[None, None, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
        elif need_pad_mask:
            s = jnp.where(kv_pos[None, None, None, None, :] < T_orig, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bskgc,bckd->bskgd", p, vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, Hkv, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, group), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, group, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(nck), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, Hq, hd).astype(q.dtype)


def dispatch_attention(
    q: jnp.ndarray,  # [B, S, Hq, hd]
    k: jnp.ndarray,  # [B, T, Hkv, hd]
    v: jnp.ndarray,  # [B, T, Hkv, hd]
    q_positions: jnp.ndarray,  # [B, S]
    *,
    causal: bool,
    cfg: ModelConfig,
    backend: str | None = None,
    shared_mask: bool = True,
) -> jnp.ndarray:
    """Single dense-attention call site: backend from ``cfg.attn_backend``.

    ``"flash"`` applies to from-scratch self-attention (S == T, where query
    row i sits at absolute position i — true for every no-cache forward in
    this codebase); cached/offset shapes fall back to the blocked reference.
    ``"paged"`` concerns decode-over-pages only (handled in :func:`attention`
    via the ``PagedKV`` cache type), so dense call sites treat it as
    ``blocked``.  ``shared_mask=False`` forces per-row causal masks (batched
    multi-token verify, where every slot starts at its own position).
    """
    backend = backend or cfg.attn_backend
    if backend == "flash" and q.shape[1] == k.shape[1] and shared_mask:
        from repro.kernels.flash_attention import mha_flash

        return mha_flash(q, k, v, causal=causal)
    return blocked_attention(q, k, v, q_positions, causal=causal,
                             kv_chunk=cfg.attn_kv_chunk,
                             shared_mask=shared_mask)


def _tp_slice_heads(q, k, v, cfg: ModelConfig, local_kv_heads: int):
    """Tensor-parallel head slicing inside a shard_map body: the KV cache
    operand carries ``local_kv_heads = Hkv / tp`` heads, so keep only this
    shard's contiguous KV-head block of the freshly-projected k/v — and its
    GQA query group (q's head ordering is kv-head-major: head ``h`` of group
    ``g`` sits at ``g * group + h``, the same ``reshape(B, S, Hkv, group,
    hd)`` layout both attention backends use).  Exactness-preserving: no
    arithmetic happens here, only a slice; the matching ``all_gather(...,
    tiled=True)`` on the attention output is a pure concat."""
    r = jax.lax.axis_index(cfg.tp_axis)
    group = cfg.num_heads // cfg.num_kv_heads
    k = jax.lax.dynamic_slice_in_dim(k, r * local_kv_heads, local_kv_heads, axis=2)
    v = jax.lax.dynamic_slice_in_dim(v, r * local_kv_heads, local_kv_heads, axis=2)
    q = jax.lax.dynamic_slice_in_dim(q, r * local_kv_heads * group,
                                     local_kv_heads * group, axis=2)
    return q, k, v


def _paged_decode(params, x, q, positions, seed, cfg: ModelConfig,
                  paged: PagedKV, method):
    """Batched decode/verify/prefill directly over the packed pool:
    quantize-scatter the S new tokens' KV (positions[b, s] drives the page
    lookup), then run the fused paged-attention kernel with per-row causal
    bounds.  S == 1 is plain decode; S > 1 is the speculative verify step
    (last accepted token + drafted suffix) or a batched prefill chunk (every
    prefilling slot's next S prompt tokens) scored in one call.  Positions
    fully drive write masking: the serve-side layout redirects padding /
    out-of-budget tokens to a page-table column holding the scratch page, so
    this function needs no mask operand."""
    hd, nkv = cfg.head_dim_, cfg.num_kv_heads
    qc = cfg.quartet
    k = _split_heads(L.dense(params["wk"], x, L.seed_fold(seed, 2), qc, method), nkv, hd)
    v = _split_heads(L.dense(params["wv"], x, L.seed_fold(seed, 3), qc, method), nkv, hd)
    if cfg.qk_norm:
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.pos_embed == "rope":
        # slots sit at genuinely different offsets — never share row 0's angles
        k = L.apply_rope(k, positions, cfg.rope_theta, shared=False)

    kleaf = next(iter(paged.pool.values()))
    ps = kleaf.shape[1]
    # tp: a head-sharded pool slice announces itself by shape — each shard
    # quantize-scatters and attends over its local Hkv/tp heads only, then
    # all_gathers the group outputs (exact concat) before the wo projection
    tp_sharded = cfg.tp_axis is not None and kleaf.shape[2] != nkv
    if tp_sharded:
        q, k, v = _tp_slice_heads(q, k, v, cfg, kleaf.shape[2])
    B, S = x.shape[0], x.shape[1]
    bidx = jnp.arange(B)
    page_ids = paged.tables[bidx[:, None], positions // ps]  # [B, S]
    pool = scatter_token(paged.pool, page_ids, positions % ps, k, v)
    lengths = positions[:, 0] + 1  # visible to the first query row
    if S == 1:
        out = paged_attention(q[:, 0], pool, paged.tables, lengths)[:, None]
    else:
        out = paged_attention(q, pool, paged.tables, lengths)
    if tp_sharded:
        out = jax.lax.all_gather(out, cfg.tp_axis, axis=2, tiled=True)
    return out, PagedKV(pool, paged.tables)


def attention(
    params,
    x: jnp.ndarray,  # [B, S, D]
    positions: jnp.ndarray,  # [B, S]
    seed: jnp.ndarray,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    kv_source: jnp.ndarray | None = None,  # cross-attention source
    kv_cache=None,  # (k,v) [B,T,Hkv,hd] | PagedKV | None
    cache_index: jnp.ndarray | None = None,  # [B] write position for decode
    write_kv: bool = False,  # (re)build a full KV cache from kv_source (prefill)
    method: str = "quartet",
    backend: str | None = None,  # override cfg.attn_backend per call
):
    """Returns (out [B,S,D], new_kv_cache | None)."""
    hd, nq, nkv = cfg.head_dim_, cfg.num_heads, cfg.num_kv_heads
    qc = cfg.quartet
    # rows share positions (one arange) in training/prefill forwards; the
    # speculative verify scores rows at per-slot offsets and opts out via
    # its own model build (make_verify_step → attn_rows_shared=False)
    rows_shared = cfg.attn_rows_shared

    q = _split_heads(L.dense(params["wq"], x, L.seed_fold(seed, 1), qc, method), nq, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
    if cfg.pos_embed == "rope" and kv_source is None:
        q = L.apply_rope(q, positions, cfg.rope_theta, shared=rows_shared)

    if isinstance(kv_cache, PagedKV):
        # positions alone drives the paged path: page lookup, quantize-
        # scatter, and the kernel's per-row causal bounds (cache_index is
        # redundant with positions[:, 0] here)
        out, new_cache = _paged_decode(params, x, q, positions, seed, cfg,
                                       kv_cache, method)
        out = out.reshape(*x.shape[:-1], nq * hd)
        return L.dense(params["wo"], out, L.seed_fold(seed, 4), qc, method), new_cache

    new_cache = None
    tp_sharded = False  # head-sharded KV cache under a shard_map tp axis
    if kv_cache is not None and cache_index is None and not write_kv:
        # reuse fully-precomputed KV (e.g. cached cross-attention memory)
        k, v = kv_cache
        new_cache = kv_cache
        # tp: a head-sharded cached cross-KV announces itself by shape, like
        # the decode/insert branch below — but here k/v are ALREADY local, so
        # only q's matching GQA group needs slicing before the tiled
        # all_gather of the outputs
        tp_sharded = cfg.tp_axis is not None and k.shape[2] != nkv
        if tp_sharded:
            local = k.shape[2]
            group = nq // nkv
            r = jax.lax.axis_index(cfg.tp_axis)
            q = jax.lax.dynamic_slice_in_dim(
                q, r * local * group, local * group, axis=2)
    else:
        src = kv_source if kv_source is not None else x
        k = _split_heads(L.dense(params["wk"], src, L.seed_fold(seed, 2), qc, method), nkv, hd)
        v = _split_heads(L.dense(params["wv"], src, L.seed_fold(seed, 3), qc, method), nkv, hd)
        if cfg.qk_norm:
            k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
        if cfg.pos_embed == "rope" and kv_source is None:
            k = L.apply_rope(k, positions, cfg.rope_theta, shared=rows_shared)
        if write_kv:  # build a full cache from kv_source (cross-attn prefill)
            new_cache = (k, v)
        elif kv_cache is not None:  # decode/prefill: insert S new entries at index
            ck_, cv_ = kv_cache
            # tp: a head-sharded dense cache (gather oracle under shard_map)
            # announces itself by shape, exactly like the paged pool
            tp_sharded = cfg.tp_axis is not None and ck_.shape[2] != nkv
            if tp_sharded:
                q, k, v = _tp_slice_heads(q, k, v, cfg, ck_.shape[2])
            upd = lambda c, n: jax.vmap(
                lambda cb, nb, i: jax.lax.dynamic_update_slice(cb, nb, (i, 0, 0))
            )(c, n.astype(c.dtype), cache_index)
            ck_, cv_ = upd(ck_, k), upd(cv_, v)
            k, v = ck_, cv_
            new_cache = (ck_, cv_)

    # note: a causal mask on q_positions subsumes the cache-validity mask
    # (queries at position p never look past p), so no kv_valid is needed.
    # Rows share one mask except when a batch of cached sequences is scored
    # at per-slot offsets (gather-backend spec verify): B > 1 ∧ cache writes.
    out = dispatch_attention(
        q, k, v, positions, causal=causal and kv_source is None,
        cfg=cfg, backend=backend, shared_mask=rows_shared,
    )
    if tp_sharded:
        out = jax.lax.all_gather(out, cfg.tp_axis, axis=2, tiled=True)
    out = out.reshape(*x.shape[:-1], nq * hd)
    out = L.dense(params["wo"], out, L.seed_fold(seed, 4), qc, method)
    return out, new_cache
