"""Uniform model API over the six families.

``build_model(cfg)`` → Model(init, forward, cache_spec) where forward has one
signature for every family:

    forward(params, tokens, seed, *, positions=None, caches=None,
            cache_index=None, extra=None, build_cross=False, method="quartet",
            token_valid=None)
        → (logits f32, new_caches, aux_loss)

``token_valid`` ([B, S] bool) marks lanes that carry real tokens in batched
serving steps; it gates MoE capacity routing (padding lanes must not displace
real tokens from expert capacity) and is ignored by families without
cross-token competition.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax

from repro.configs.base import ModelConfig
from repro.models import layers as L  # noqa: F401  (re-export convenience)
from repro.models.encdec import encdec_cache_spec, encdec_forward, init_encdec_lm
from repro.models.hybrid import hybrid_cache_spec, hybrid_forward, init_hybrid_lm
from repro.models.moe import init_moe_block, moe_block
from repro.models.ssm import init_mamba1_block, mamba1_block, mamba1_cache_spec
from repro.models.transformer import (
    dense_block,
    dense_cache_spec,
    init_dense_block,
    init_lm,
    lm_forward,
    lm_head_apply,
)
from repro.models.vlm import init_vlm_lm, vlm_cache_spec, vlm_forward


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable  # (key) -> params pytree
    forward: Callable  # unified signature above (+ features_only=True)
    cache_spec: Callable  # (batch, max_len) -> cache ShapeDtypeStruct pytree
    head: Callable = None  # (params, features, seed, method) -> f32 logits


def _stacked_spec(spec_fn, n):
    def f(batch, max_len):
        spec = spec_fn(batch)
        return jax.tree.map(lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec)
    return f


def build_model(cfg: ModelConfig, *, attn_backend: str | None = None) -> Model:
    """Build the family's Model; ``attn_backend`` overrides
    ``cfg.attn_backend`` ("blocked" / "flash" / "paged") so callers (engine,
    benchmarks) can select the attention backend without editing configs."""
    if attn_backend is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, attn_backend=attn_backend)
    fam = cfg.family
    if fam in ("dense", "moe", "ssm"):
        block_init = {"dense": init_dense_block, "moe": init_moe_block,
                      "ssm": init_mamba1_block}[fam]
        block_apply = {"dense": dense_block, "moe": moe_block,
                       "ssm": mamba1_block}[fam]

        def init(key):
            return init_lm(key, cfg, block_init)

        def forward(params, tokens, seed, *, positions=None, caches=None,
                    cache_index=None, extra=None, build_cross=False,
                    method="quartet", features_only=False, token_valid=None):
            return lm_forward(params, tokens, cfg, seed, positions=positions,
                              caches=caches, cache_index=cache_index,
                              block_apply=block_apply, method=method, extra=extra,
                              features_only=features_only, token_valid=token_valid)

        if fam == "ssm":
            def cache_spec(batch, max_len):
                spec = mamba1_cache_spec(cfg, batch)
                return jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((cfg.num_layers, *s.shape), s.dtype), spec)
        else:
            def cache_spec(batch, max_len):
                spec = dense_cache_spec(cfg, batch, max_len)
                return jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((cfg.num_layers, *s.shape), s.dtype), spec)
        head = lambda params, x, seed, method="quartet": lm_head_apply(
            params, x, cfg, seed, method)
        return Model(cfg, init, forward, cache_spec, head)

    if fam == "hybrid":
        def forward(params, tokens, seed, *, positions=None, caches=None,
                    cache_index=None, extra=None, build_cross=False,
                    method="quartet", features_only=False, token_valid=None):
            return hybrid_forward(params, tokens, cfg, seed, positions=positions,
                                  caches=caches, cache_index=cache_index,
                                  method=method, extra=extra,
                                  features_only=features_only)
        head = lambda params, x, seed, method="quartet": lm_head_apply(
            params, x, cfg, seed, method)
        return Model(cfg, lambda key: init_hybrid_lm(key, cfg), forward,
                     functools.partial(hybrid_cache_spec, cfg), head)

    if fam == "encdec":
        def forward(params, tokens, seed, *, positions=None, caches=None,
                    cache_index=None, extra=None, build_cross=False,
                    method="quartet", features_only=False, token_valid=None):
            extra = extra or {}
            return encdec_forward(params, tokens, cfg, seed, positions=positions,
                                  source_embeds=extra.get("source_embeds"),
                                  memory=extra.get("memory"), caches=caches,
                                  cache_index=cache_index, build_cross=build_cross,
                                  method=method, features_only=features_only)

        def head(params, x, seed, method="quartet"):
            from repro.distributed.context import constrain_logits
            from repro.models import layers as L
            _, norm = L.make_norm(cfg.norm)
            x = norm(params["decoder"]["final_norm"], x, cfg.norm_eps)
            logits = L.unembed(params["embed"], x, L.seed_fold(seed, 999),
                               cfg.quartet, cfg.quantize_lm_head, method)
            return constrain_logits(logits.astype(jax.numpy.float32))

        return Model(cfg, lambda key: init_encdec_lm(key, cfg), forward,
                     functools.partial(encdec_cache_spec, cfg), head)

    if fam == "vlm":
        def forward(params, tokens, seed, *, positions=None, caches=None,
                    cache_index=None, extra=None, build_cross=False,
                    method="quartet", features_only=False, token_valid=None):
            extra = extra or {}
            return vlm_forward(params, tokens, cfg, seed, positions=positions,
                               image_embeds=extra.get("image_embeds"), caches=caches,
                               cache_index=cache_index, method=method,
                               features_only=features_only)
        head = lambda params, x, seed, method="quartet": lm_head_apply(
            params, x, cfg, seed, method)
        return Model(cfg, lambda key: init_vlm_lm(key, cfg), forward,
                     functools.partial(vlm_cache_spec, cfg), head)

    raise ValueError(f"unknown family {fam!r}")
