"""Llama-3.2-Vision-style VLM backbone: a dense decoder with gated
cross-attention blocks to image patch embeddings every ``cross_attn_every``
layers (40 layers / every 5 → 8 cross blocks).

The vision frontend is a stub per spec: ``image_embeds`` [B, n_img, D] arrive
precomputed.  Cross blocks use tanh-gated residuals (zero-init gates) as in
Llama 3.2 / Flamingo.  Structure is a scan over 8 super-blocks of
[cross-attn → 5 dense blocks] so HLO stays O(1) in depth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import (
    constrain_layer_params,
    constrain_logits,
    constrain_tokens,
)
from repro.models import layers as L
from repro.models.attention import attention, init_attention
from repro.models.transformer import (
    LAYER_SEED_STRIDE,
    dense_block,
    dense_cache_spec,
    init_dense_block,
    init_mlp,
    mlp,
    stacked_init,
)


def _counts(cfg: ModelConfig):
    n_super = cfg.num_layers // cfg.cross_attn_every
    assert n_super * cfg.cross_attn_every == cfg.num_layers, \
        "num_layers must divide by cross_attn_every"
    return n_super, cfg.cross_attn_every


def init_cross_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg, dtype),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def cross_block(params, x, image_embeds, positions, seed, cfg, cache, method):
    """cache: precomputed (k, v) over image tokens, or None (training)."""
    h, new_cache = attention(
        params["attn"], L.rmsnorm(params["attn_norm"], x, cfg.norm_eps), positions,
        L.seed_fold(seed, 100), cfg, causal=False, kv_source=image_embeds,
        kv_cache=cache, write_kv=(cache is not None and image_embeds is not None),
        method=method,
    )
    x = x + jnp.tanh(params["gate_attn"]).astype(x.dtype) * h
    h = mlp(params["mlp"], L.rmsnorm(params["mlp_norm"], x, cfg.norm_eps),
            L.seed_fold(seed, 200), cfg, method)
    return x + jnp.tanh(params["gate_mlp"]).astype(x.dtype) * h, new_cache


def encode_cross_kv(params, image_embeds, cfg: ModelConfig, seed,
                    method="quartet"):
    """Every cross super-block's (k, v) over the image tokens, computed ONCE:
    [B, n_img, D] → stacked (k, v) [n_super, B, n_img, Hkv, hd].

    Bit-identical to what a prefill with ``image_embeds`` writes into its
    cross cache (``cross_block`` → ``attention(write_kv=True)``): same
    per-super seed (``seed + sp_idx * 7919`` then fold 100), same wk/wv
    projection folds (2/3), same optional k-norm, no rope on keys.  The
    serving engine runs this at admission to populate the pooled cross-KV
    plane that decode steps read."""
    hd, nkv = cfg.head_dim_, cfg.num_kv_heads
    qc = cfg.quartet
    n_super, _ = _counts(cfg)

    def body(carry, inp):
        lp, sp_idx = inp
        s = (seed + sp_idx.astype(jnp.uint32) * jnp.uint32(7919)).astype(jnp.uint32)
        sa = L.seed_fold(s, 100)
        ca = lp["attn"]
        k = L.dense(ca["wk"], image_embeds, L.seed_fold(sa, 2), qc, method)
        v = L.dense(ca["wv"], image_embeds, L.seed_fold(sa, 3), qc, method)
        k = k.reshape(*k.shape[:-1], nkv, hd)
        v = v.reshape(*v.shape[:-1], nkv, hd)
        if cfg.qk_norm:
            k = L.rmsnorm(ca["k_norm"], k, cfg.norm_eps)
        return carry, (k, v)

    _, (ks, vs) = jax.lax.scan(
        body, 0, (params["cross_layers"],
                  jnp.arange(n_super, dtype=jnp.uint32)))
    return ks, vs


def init_vlm_lm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    n_super, per = _counts(cfg)
    k_emb, k_d, k_c, k_head = jax.random.split(key, 4)
    params = {
        "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked_init(init_dense_block, k_d, cfg.num_layers, cfg, dtype),
        "cross_layers": stacked_init(init_cross_block, k_c, n_super, cfg, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


def vlm_forward(params, tokens, cfg: ModelConfig, seed, *, positions=None,
                image_embeds=None, caches=None, cache_index=None,
                method="quartet", extra=None, features_only=False):
    """caches: {"self": [L,...], "cross": [n_super, (k,v)]} or None."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if image_embeds is None and extra is not None:
        image_embeds = extra.get("image_embeds")
    x = constrain_tokens(L.embed(params["embed"], tokens))

    n_super, per = _counts(cfg)
    dense_stack = jax.tree.map(
        lambda a: a.reshape(n_super, per, *a.shape[1:]), params["layers"])
    self_caches = caches["self"] if caches is not None else None
    cross_caches = caches["cross"] if caches is not None else None
    if self_caches is not None:
        self_caches = jax.tree.map(
            lambda a: a.reshape(n_super, per, *a.shape[1:]), self_caches)

    def dense_scan(x, group_params, group_caches, seed0):
        def body(carry, inp):
            x = carry
            lp, i, c = inp
            lp = constrain_layer_params(lp)
            s = (seed0 + i.astype(jnp.uint32) * jnp.uint32(LAYER_SEED_STRIDE)).astype(jnp.uint32)
            x, nc, _ = dense_block(lp, x, positions, s, cfg, c, cache_index, method)
            return constrain_tokens(x), nc
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        return jax.lax.scan(body, x, (group_params, jnp.arange(per, dtype=jnp.uint32),
                                      group_caches))

    def super_body(carry, inp):
        x = carry
        sp_idx, cross_p, dense_p, self_c, cross_c = inp
        s = (seed + sp_idx.astype(jnp.uint32) * jnp.uint32(7919)).astype(jnp.uint32)
        x, new_cross_c = cross_block(cross_p, x, image_embeds, positions, s, cfg,
                                     cross_c, method)
        seed0 = (seed + sp_idx.astype(jnp.uint32)
                 * jnp.uint32((per * LAYER_SEED_STRIDE) % (2**32))).astype(jnp.uint32)
        x, new_self_c = dense_scan(x, dense_p, self_c, seed0)
        return x, (new_self_c, new_cross_c)

    if cfg.remat:  # hierarchical remat: without this the outer scan stacks
        # every super's cross-attention intermediates (≈8 GB f32 per tensor)
        super_body = jax.checkpoint(super_body, prevent_cse=False)
    x, (new_self, new_cross) = jax.lax.scan(
        super_body, x,
        (jnp.arange(n_super, dtype=jnp.uint32), params["cross_layers"], dense_stack,
         self_caches, cross_caches),
    )

    from repro.models.transformer import lm_head_apply
    logits = x if features_only else lm_head_apply(params, x, cfg, seed, method)
    new_caches = None
    if caches is not None:
        new_caches = {
            "self": jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), new_self),
            "cross": new_cross,
        }
    return logits, new_caches, jnp.float32(0.0)


def vlm_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    n_super, _ = _counts(cfg)
    hd = cfg.head_dim_
    stack = lambda spec, n: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec)
    cross = (
        jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
    )
    return {
        "self": stack(dense_cache_spec(cfg, batch, max_len), cfg.num_layers),
        "cross": stack(cross, n_super),
    }
