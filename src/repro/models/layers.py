"""Shared layers: norms, Quartet-wired dense, embeddings, RoPE.

Functional style: ``init_*`` returns a param pytree; ``apply`` functions take
(params, inputs).  No framework dependency — params are dicts of jnp arrays,
so sharding rules can address them by path (distributed/sharding.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quartet import QuartetConfig, quartet_linear
from repro.core.baselines import baseline_linear

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -3.0, 3.0, shape)).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, use_bias: bool = False, std: float | None = None):
    std = std if std is not None else 1.0 / np.sqrt(d_in)
    p = {"w": trunc_normal(key, (d_in, d_out), std, dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x, seed, qcfg: QuartetConfig, method: str = "quartet"):
    """Quantized linear: the single entry point every model matmul goes
    through.  ``method`` selects Quartet vs a baseline training scheme."""
    w = params["w"]
    if w.shape[0] % 32 != 0:
        # contraction dim below / not divisible by the MXFP4 group: such GEMMs
        # (e.g. mamba dt_proj at tiny smoke scale) are negligible — keep bf16
        method = "bf16"
    if method == "quartet" and qcfg.fp4_allgather and w.ndim == 2:
        from repro.core.quartet import quartet_linear_pq, quest_qdq_gathered

        w_vals, w_mask = quest_qdq_gathered(w, qcfg)
        y = quartet_linear_pq(x, w_vals, w_mask, seed, qcfg)
    elif method == "quartet":
        y = quartet_linear(x, w, seed, qcfg)
    elif method == "bf16":
        y = jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
    else:
        y = baseline_linear(x, w, seed, method)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(x.dtype)


def make_norm(kind: str):
    return (init_rmsnorm, rmsnorm) if kind == "rmsnorm" else (init_layernorm, layernorm)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               shared: bool = True) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] (absolute token positions).

    ``shared=True``: train/prefill positions are row-identical arange:
    computing cos/sin per row materializes a [B,S,hd] f32 loop invariant —
    share row 0 across rows and let broadcasting fuse it.  Decode (S == 1)
    keeps per-row positions either way.  ``shared=False`` is required when
    S > 1 rows genuinely sit at different offsets (the speculative verify
    burst: each slot scores its drafted suffix from its own position)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    if x.shape[1] > 1 and shared:
        positions = positions[:1]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [1|B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(max_len: int, d: int) -> jnp.ndarray:
    pos = np.arange(max_len)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((max_len, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype):
    # 1/√d keeps tied-unembedding logits O(1) at init
    return {"table": trunc_normal(key, (vocab, d), 1.0 / np.sqrt(d), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, seed, qcfg: QuartetConfig, quantize: bool, method: str = "quartet"):
    """Logits head.  Tied path multiplies by the embedding table transpose."""
    table = params["table"]
    if quantize and method == "quartet":
        return quartet_linear(x, jnp.swapaxes(table, 0, 1), seed, qcfg)
    return jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def seed_fold(seed: jnp.ndarray, salt: int) -> jnp.ndarray:
    """Cheap deterministic per-site seed derivation (uint32 arithmetic)."""
    return (seed * jnp.uint32(1000003) + jnp.uint32(salt)).astype(jnp.uint32)
