"""Dense decoder-only LM (Llama-style): GQA + SwiGLU/GeLU MLP, RMSNorm,
scan-over-layers with optional remat, Quartet linears throughout.

This module also provides the generic LM scaffolding (embed → layer stack →
norm → logits) reused by the MoE / SSM / hybrid / VLM families, which plug in
their own layer body via the ``block_init`` / ``block_apply`` hooks.

Attention routes through ``models.attention``'s backend dispatch
(``ModelConfig.attn_backend``).  The per-layer ``caches`` threaded by the
layer scan are either dense ``(k, v)`` tuples or — for the serving engine's
batched decode — ``PagedKV`` pytrees (packed-pool leaves + page tables, both
carrying the leading ``[L]`` axis the scan consumes), in which case attention
runs the fused paged-attention kernel directly over the packed pages.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import (
    constrain_layer_params,
    constrain_logits,
    constrain_tokens,
)
from repro.models import layers as L
from repro.models.attention import attention, init_attention

LAYER_SEED_STRIDE = 2654435761  # Knuth multiplicative hash increment


@jax.custom_vjp
def _barrier(x):
    """optimization_barrier with a differentiation rule (jax 0.4.x has none):
    identity value/gradient, barrier on both passes."""
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_barrier.defvjp(_barrier_fwd, _barrier_bwd)


def init_mlp(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "gate": L.init_dense(ks[0], d, f, dtype, cfg.use_bias),
            "up": L.init_dense(ks[1], d, f, dtype, cfg.use_bias),
            "down": L.init_dense(ks[2], f, d, dtype, cfg.use_bias),
        }
    return {
        "up": L.init_dense(ks[0], d, f, dtype, cfg.use_bias),
        "down": L.init_dense(ks[1], f, d, dtype, cfg.use_bias),
    }


def mlp(params, x, seed, cfg: ModelConfig, method: str = "quartet"):
    qc = cfg.quartet
    if cfg.mlp == "swiglu":
        g = L.dense(params["gate"], x, L.seed_fold(seed, 11), qc, method)
        u = L.dense(params["up"], x, L.seed_fold(seed, 12), qc, method)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = L.dense(params["up"], x, L.seed_fold(seed, 12), qc, method)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return L.dense(params["down"], h, L.seed_fold(seed, 13), qc, method)


# ---------------------------------------------------------------------------
# Dense transformer block
# ---------------------------------------------------------------------------


def init_dense_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    init_norm, _ = L.make_norm(cfg.norm)
    return {
        "attn_norm": init_norm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "mlp_norm": init_norm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg, dtype),
    }


def dense_block(params, x, positions, seed, cfg: ModelConfig, cache, cache_index,
                method, token_valid=None):
    # token_valid is accepted for signature parity with moe_block (batched
    # serving steps thread it uniformly); dense blocks have no cross-token
    # competition, so padding lanes are already harmless here
    _, norm = L.make_norm(cfg.norm)
    h, new_cache = attention(
        params["attn"], norm(params["attn_norm"], x, cfg.norm_eps), positions,
        L.seed_fold(seed, 100), cfg, causal=cfg.is_causal_lm,
        kv_cache=cache, cache_index=cache_index, method=method,
    )
    x = x + h
    x = x + mlp(params["mlp"], norm(params["mlp_norm"], x, cfg.norm_eps),
                L.seed_fold(seed, 200), cfg, method)
    return x, new_cache, jnp.float32(0.0)


def dense_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.head_dim_
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return (
        jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype)),
    )


# ---------------------------------------------------------------------------
# Generic LM scaffolding (scan over a stack of identical blocks)
# ---------------------------------------------------------------------------


def stacked_init(block_init: Callable, key, n: int, *args):
    """vmap a per-layer init over n keys → leaves with a leading [n] dim."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, *args))(keys)


def init_lm(key, cfg: ModelConfig, block_init=None):
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    block_init = block_init or init_dense_block
    init_norm, _ = L.make_norm(cfg.norm)
    params = {
        "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked_init(block_init, k_layers, cfg.num_layers, cfg, dtype),
        "final_norm": init_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


def _layer_scan(params_layers, x, positions, seed, cfg, caches, cache_index,
                block_apply, method, extra=None, token_valid=None):
    """Scan the block over stacked layer params (+ optional stacked caches)."""
    # forwarded as a kwarg only when present: training callers (and blocks
    # without cross-token routing, e.g. mamba1_block) never see it
    block_kw = {} if token_valid is None else {"token_valid": token_valid}

    def body(carry, inp):
        x, aux = carry
        layer_params, layer_idx, cache = inp
        # anchor the per-layer param slice (and, via the transpose, its
        # gradient) to the parameter sharding rules
        layer_params = constrain_layer_params(layer_params)
        # barrier: stops XLA hoisting the carry's bf16→f32 convert out of the
        # backward while as a whole-stack [L, B, S, D] f32 loop invariant
        x = _barrier(x)
        seed_l = (seed + layer_idx.astype(jnp.uint32) * jnp.uint32(LAYER_SEED_STRIDE)).astype(jnp.uint32)
        x, new_cache, aux_l = block_apply(layer_params, x, positions, seed_l, cfg,
                                          cache, cache_index, method, **block_kw)
        x = constrain_tokens(x)  # anchor the scan carry's DP/SP sharding
        return (x, aux + aux_l), new_cache

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    idxs = jnp.arange(cfg.num_layers, dtype=jnp.uint32)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        (params_layers, idxs, caches))
    return x, new_caches, aux


def lm_head_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                  seed: jnp.ndarray, method: str = "quartet") -> jnp.ndarray:
    """Final norm + unembedding → f32 logits.  Exposed separately so the
    training loss can apply it per sequence chunk (the full [B, S, V] f32
    logits tensor never materializes — see train.losses.chunked_lm_loss)."""
    _, norm = L.make_norm(cfg.norm)
    x = norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x, L.seed_fold(seed, 999), cfg.quartet,
                           cfg.quantize_lm_head, method)
    else:
        logits = L.dense(params["lm_head"], x, L.seed_fold(seed, 999), cfg.quartet,
                         method if cfg.quantize_lm_head else "bf16")
    logits = constrain_logits(logits.astype(jnp.float32))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def lm_forward(
    params: dict,
    tokens: jnp.ndarray,  # [B, S]
    cfg: ModelConfig,
    seed: jnp.ndarray,
    *,
    positions: jnp.ndarray | None = None,
    caches=None,  # stacked per-layer caches [L, ...] or None
    cache_index: jnp.ndarray | None = None,
    block_apply: Callable = dense_block,
    method: str = "quartet",
    extra: Any = None,
    features_only: bool = False,
    token_valid: jnp.ndarray | None = None,  # [B, S] bool — real-token lanes
):
    """Returns (logits [B, S, V] f32 — or [B, S, D] features —, caches, aux)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = constrain_tokens(L.embed(params["embed"], tokens))
    if cfg.pos_embed == "absolute":
        pe = L.sinusoidal_positions(max(4096, S), cfg.d_model)
        x = x + jnp.take(pe, jnp.clip(positions, 0, pe.shape[0] - 1), axis=0).astype(x.dtype)

    x, new_caches, aux = _layer_scan(params["layers"], x, positions, seed, cfg,
                                     caches, cache_index, block_apply, method, extra,
                                     token_valid)

    if features_only:
        return x, new_caches, aux
    return lm_head_apply(params, x, cfg, seed, method), new_caches, aux
