"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a stub per spec: ``source_embeds`` arrive as
precomputed frame embeddings [B, T_src, D].  Encoder = bidirectional self-attn
blocks; decoder = causal self-attn + cross-attn + MLP.  Whisper uses
LayerNorm and absolute (sinusoidal) positions — both selected via the config.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import (
    constrain_layer_params,
    constrain_logits,
    constrain_tokens,
)
from repro.models import layers as L
from repro.models.attention import attention, init_attention
from repro.models.transformer import (
    LAYER_SEED_STRIDE,
    dense_cache_spec,
    init_dense_block,
    init_mlp,
    mlp,
    stacked_init,
)


def init_encdec_lm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_enc, k_dec, k_emb = jax.random.split(key, 3)
    init_norm, _ = L.make_norm(cfg.norm)

    def init_dec_block(k, cfg, dtype):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "self_norm": init_norm(cfg.d_model, dtype),
            "self_attn": init_attention(k1, cfg, dtype),
            "cross_norm": init_norm(cfg.d_model, dtype),
            "cross_attn": init_attention(k2, cfg, dtype),
            "mlp_norm": init_norm(cfg.d_model, dtype),
            "mlp": init_mlp(k3, cfg, dtype),
        }

    return {
        "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "encoder": {
            "layers": stacked_init(init_dense_block, k_enc, cfg.encoder_layers, cfg, dtype),
            "final_norm": init_norm(cfg.d_model, dtype),
        },
        "decoder": {
            "layers": stacked_init(init_dec_block, k_dec, cfg.num_layers, cfg, dtype),
            "final_norm": init_norm(cfg.d_model, dtype),
        },
    }


def encode(params, source_embeds, cfg: ModelConfig, seed, method="quartet"):
    """source_embeds: [B, T_src, D] → memory [B, T_src, D]."""
    _, norm = L.make_norm(cfg.norm)
    B, T, _ = source_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    pe = L.sinusoidal_positions(T, cfg.d_model)
    x = source_embeds + pe[None].astype(source_embeds.dtype)

    def body(x, inp):
        lp, i = inp
        lp = constrain_layer_params(lp)
        s = (seed + i.astype(jnp.uint32) * jnp.uint32(LAYER_SEED_STRIDE)).astype(jnp.uint32)
        h, _ = attention(lp["attn"], norm(lp["attn_norm"], x, cfg.norm_eps), pos,
                         L.seed_fold(s, 100), cfg, causal=False, method=method)
        x = x + h
        x = x + mlp(lp["mlp"], norm(lp["mlp_norm"], x, cfg.norm_eps),
                    L.seed_fold(s, 200), cfg, method)
        return constrain_tokens(x), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["encoder"]["layers"],
                                  jnp.arange(cfg.encoder_layers, dtype=jnp.uint32)))
    return norm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def encode_cross_kv(params, source_embeds, cfg: ModelConfig, seed,
                    method="quartet"):
    """Every decoder layer's cross-attention (k, v) computed ONCE from the
    source: [B, T_src, D] → stacked (k, v) [L, B, T_src, Hkv, hd].

    Bit-identical to what a ``build_cross=True`` forward produces for its
    cross cache — same encoder seed fold (7), same per-layer seed stride and
    cross-attention fold (150), same wk/wv projection folds (2/3) inside
    :func:`~repro.models.attention.attention`, same optional k-norm, no rope
    (cross keys are unrotated).  The serving engine runs this at ADMISSION
    and quantize-scatters the result into the pooled cross-KV plane, so
    every later prefill chunk / decode step reads the pool instead of
    re-running the encoder."""
    memory = encode(params, source_embeds, cfg, L.seed_fold(seed, 7), method)
    hd, nkv = cfg.head_dim_, cfg.num_kv_heads
    qc = cfg.quartet

    def body(carry, inp):
        lp, i = inp
        lp = constrain_layer_params(lp)
        s = (seed + i.astype(jnp.uint32) * jnp.uint32(LAYER_SEED_STRIDE)).astype(jnp.uint32)
        sc = L.seed_fold(s, 150)
        ca = lp["cross_attn"]
        k = L.dense(ca["wk"], memory, L.seed_fold(sc, 2), qc, method)
        v = L.dense(ca["wv"], memory, L.seed_fold(sc, 3), qc, method)
        k = k.reshape(*k.shape[:-1], nkv, hd)
        v = v.reshape(*v.shape[:-1], nkv, hd)
        if cfg.qk_norm:
            k = L.rmsnorm(ca["k_norm"], k, cfg.norm_eps)
        return carry, (k, v)

    _, (ks, vs) = jax.lax.scan(
        body, 0, (params["decoder"]["layers"],
                  jnp.arange(cfg.num_layers, dtype=jnp.uint32)))
    return ks, vs


def encdec_forward(params, tokens, cfg: ModelConfig, seed, *, positions=None,
                   memory=None, source_embeds=None, caches=None, cache_index=None,
                   build_cross=False, method="quartet", extra=None,
                   features_only=False):
    """Decoder forward (teacher-forced or incremental).

    caches: {"self": (k, v) stacked [L, ...], "cross": (k, v) stacked} or None.
    """
    _, norm = L.make_norm(cfg.norm)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if memory is None and (caches is None or build_cross):
        # training / prefill need the encoder; cached decode reuses cross-KV
        assert source_embeds is not None, "need memory or source_embeds"
        memory = encode(params, source_embeds, cfg, L.seed_fold(seed, 7), method)

    pe = L.sinusoidal_positions(max(4096, S), cfg.d_model)
    x = L.embed(params["embed"], tokens)
    x = x + jnp.take(pe, jnp.clip(positions, 0, pe.shape[0] - 1), axis=0).astype(x.dtype)

    self_caches = caches["self"] if caches is not None else None
    cross_caches = caches["cross"] if caches is not None else None

    def body(x, inp):
        lp, i, sc, cc = inp
        lp = constrain_layer_params(lp)
        s = (seed + i.astype(jnp.uint32) * jnp.uint32(LAYER_SEED_STRIDE)).astype(jnp.uint32)
        h, new_sc = attention(lp["self_attn"], norm(lp["self_norm"], x, cfg.norm_eps),
                              positions, L.seed_fold(s, 100), cfg, causal=True,
                              kv_cache=sc, cache_index=cache_index, method=method)
        x = x + h
        h, new_cc = attention(lp["cross_attn"], norm(lp["cross_norm"], x, cfg.norm_eps),
                              positions, L.seed_fold(s, 150), cfg, causal=False,
                              kv_source=memory, kv_cache=cc, write_kv=build_cross,
                              method=method)
        x = x + h
        x = x + mlp(lp["mlp"], norm(lp["mlp_norm"], x, cfg.norm_eps),
                    L.seed_fold(s, 200), cfg, method)
        return constrain_tokens(x), (new_sc, new_cc)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (new_self, new_cross) = jax.lax.scan(
        body, x, (params["decoder"]["layers"],
                  jnp.arange(cfg.num_layers, dtype=jnp.uint32), self_caches, cross_caches))

    if features_only:
        logits = x
    else:
        x = norm(params["decoder"]["final_norm"], x, cfg.norm_eps)
        logits = L.unembed(params["embed"], x, L.seed_fold(seed, 999), cfg.quartet,
                           cfg.quantize_lm_head, method)
        logits = constrain_logits(logits.astype(jnp.float32))
    new_caches = None
    if caches is not None:
        new_caches = {"self": new_self, "cross": new_cross}
    return logits, new_caches, jnp.float32(0.0)


def encdec_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    stack = lambda spec: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers, *s.shape), s.dtype), spec)
    hd = cfg.head_dim_
    cross = (
        jax.ShapeDtypeStruct((batch, cfg.max_source_len, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct((batch, cfg.max_source_len, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
    )
    return {"self": stack(dense_cache_spec(cfg, batch, max_len)), "cross": stack(cross)}
