"""Mixture-of-Experts block (qwen3-moe, arctic) with Quartet expert GEMMs.

Routing is GShard-style grouped capacity-based dispatch, formulated as pure
gather/scatter + einsum so GSPMD can shard it (no shard_map):

  tokens  [G, g, D]   groups G sharded over the DP axes, g tokens per group
  gates   [G, g, E]   dense top-k-masked router weights
  select  [G, E, c]   per (group, expert) the top-c token indices (capacity)
  expert  [G, E, c, D] → FFN (vmapped Quartet linears, experts over "model")
  combine scatter-add back to [G, g, D] (→ all-reduce over the expert axis)

Capacity c = round_up(k·g/E·capacity_factor, 32); tokens over capacity are
dropped (their gate contribution is zero), matching GShard/Switch semantics.
The router itself stays in bf16 — it is a tiny GEMM and accuracy-critical,
mirroring the paper's policy of keeping non-GEMM-dominant ops high precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import attention, init_attention
from repro.models.transformer import init_mlp, mlp

NEG_INF = -1e30


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(np.ceil(cfg.experts_per_token * tokens_per_group / cfg.num_experts
                    * cfg.capacity_factor))
    return max(32, ((c + 31) // 32) * 32)


def init_moe_ffn(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    std = 1.0 / np.sqrt(d)
    p = {
        "router": L.init_dense(ks[0], d, e, dtype),
        "gate": L.trunc_normal(ks[1], (e, d, f), std, dtype),
        "up": L.trunc_normal(ks[2], (e, d, f), std, dtype),
        "down": L.trunc_normal(ks[3], (e, f, d), 1.0 / np.sqrt(f), dtype),
    }
    if cfg.moe_dense_residual:
        p["dense_mlp"] = init_mlp(ks[4], cfg, dtype)
    return p


def _expert_ffn(xe, params, seed, cfg: ModelConfig, method: str,
                expert_offset=0):
    """xe: [E, T', D] → [E, T', D]; per-expert Quartet linears via vmap.

    ``expert_offset`` shifts the per-expert stochastic-rounding seeds to the
    *global* expert index — a tensor-parallel shard computing experts
    [r·E/tp, (r+1)·E/tp) must fold the same seed that the unsharded run
    folds for those experts, or quantization noise (and thus tokens) would
    diverge between sharded and single-device engines."""
    qc = cfg.quartet
    seeds = (L.seed_fold(seed, 20) + expert_offset
             + jnp.arange(xe.shape[0], dtype=jnp.uint32))

    if method == "quartet" and qc.fp4_allgather:
        # quantize the stacked expert weights BEFORE vmap so the FSDP gather
        # moves int8 codes (the sharding constraint can't live under vmap)
        from repro.core.quartet import quartet_linear_pq, quest_qdq_gathered

        wg_v, wg_m = quest_qdq_gathered(params["gate"], qc)
        wu_v, wu_m = quest_qdq_gathered(params["up"], qc)
        wd_v, wd_m = quest_qdq_gathered(params["down"], qc)

        def one(x, gv, gm, uv, um, dv, dm, s):
            g = quartet_linear_pq(x, gv, gm, L.seed_fold(s, 21), qc)
            u = quartet_linear_pq(x, uv, um, L.seed_fold(s, 22), qc)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
            return quartet_linear_pq(h, dv, dm, L.seed_fold(s, 23), qc)

        return jax.vmap(one)(xe, wg_v, wg_m, wu_v, wu_m, wd_v, wd_m, seeds)

    def one(x, wg, wu, wd, s):
        g = L.dense({"w": wg}, x, L.seed_fold(s, 21), qc, method)
        u = L.dense({"w": wu}, x, L.seed_fold(s, 22), qc, method)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return L.dense({"w": wd}, h, L.seed_fold(s, 23), qc, method)

    return jax.vmap(one)(xe, params["gate"], params["up"], params["down"], seeds)


def moe_ffn(params, x, seed, cfg: ModelConfig, method: str = "quartet",
            group_tokens: int = 4096, token_valid=None):
    """x: [B, S, D] → (y [B, S, D], aux_loss scalar).

    ``token_valid`` ([B, S] bool, optional) marks which lanes carry real
    tokens.  Batched serving steps pad inactive slots / ragged prefill tails
    with garbage lanes whose *outputs* are discarded — but without the mask
    those lanes still compete for expert capacity: a garbage token with a
    high router score can displace a real token from an expert's top-c
    selection, perturbing drop patterns at capacity-bound scale as a function
    of batch padding.  Masked lanes get zero gates, so they score ``NEG_INF``
    in capacity selection (losing to every real token), contribute nothing to
    the combine, and drop out of the load-balance statistics."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    g = min(group_tokens, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible into groups of {g}"
    xg = x.reshape(G, g, D)

    # --- router (bf16, tiny) -------------------------------------------------
    logits = L.dense({"w": params["router"]["w"]}, xg, seed, cfg.quartet, "bf16")
    logits = logits.astype(jnp.float32)  # [G, g, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # [G, g, k]
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)
    gates = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32)
                    * top_vals[..., None], axis=2)  # [G, g, E]
    if token_valid is not None:
        gates = gates * token_valid.reshape(G, g)[..., None].astype(gates.dtype)

    # --- aux losses: load balance [Switch] + router z-loss -------------------
    me = jnp.mean(gates > 0, axis=1)  # fraction of tokens per expert [G, E]
    pe = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(me * pe, axis=-1))
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = aux + cfg.router_zloss * zloss

    # --- capacity selection: per (G, E) the top-c gate tokens ----------------
    c = moe_capacity(cfg, g)
    scores = jnp.where(gates > 0, gates, NEG_INF)  # [G, g, E]
    sel_val, sel_idx = jax.lax.top_k(jnp.swapaxes(scores, 1, 2), min(c, g))  # [G, E, c]
    sel_gate = jnp.where(sel_val > 0, sel_val, 0.0)

    # --- dispatch: gather selected tokens -------------------------------------
    xe = jnp.take_along_axis(
        xg[:, None, :, :],  # [G, 1, g, D]
        sel_idx[..., None],  # [G, E, c, 1]
        axis=2,
    )  # [G, E, c, D]

    # --- expert compute (E sharded over "model") ------------------------------
    xe = jnp.swapaxes(xe, 0, 1).reshape(E, G * min(c, g), D)
    tp = (cfg.tp_size
          if (cfg.tp_axis is not None and cfg.tp_size > 1
              and E % cfg.tp_size == 0) else 1)
    if tp > 1:
        # expert parallelism inside a serving shard_map body: each shard runs
        # its contiguous E/tp expert block (weights + dispatched tokens sliced
        # on the expert axis), then all_gathers outputs back to the full
        # expert axis — a pure concat, so the replicated combine below sums
        # in exactly the single-device order.  Routing/capacity selection ran
        # above on replicated inputs, so selection is shard-invariant.
        r = jax.lax.axis_index(cfg.tp_axis)
        El = E // tp
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, r * El, El, axis=0)
        eparams = {**params, "gate": sl(params["gate"]),
                   "up": sl(params["up"]), "down": sl(params["down"])}
        ye = _expert_ffn(sl(xe), eparams, seed, cfg, method,
                        expert_offset=(r * El).astype(jnp.uint32))
        ye = jax.lax.all_gather(ye, cfg.tp_axis, axis=0, tiled=True)
    else:
        ye = _expert_ffn(xe, params, seed, cfg, method)
    ye = jnp.swapaxes(ye.reshape(E, G, min(c, g), D), 0, 1)  # [G, E, c, D]
    ye = ye * sel_gate[..., None].astype(ye.dtype)

    # --- combine: scatter-add back to token order -----------------------------
    # bf16 combine: halves the cross-model-axis reduction bytes (≤ top-k
    # gate-weighted summands per token — bf16 addition is ample)
    out = jnp.zeros((G, g, D), x.dtype)
    gidx = jnp.arange(G)[:, None, None]
    out = out.at[gidx, sel_idx].add(ye.astype(x.dtype))
    y = out.reshape(B, S, D)

    if cfg.moe_dense_residual:
        y = y + mlp(params["dense_mlp"], x, L.seed_fold(seed, 30), cfg, method)
    return y.astype(x.dtype), aux


def init_moe_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    init_norm, _ = L.make_norm(cfg.norm)
    return {
        "attn_norm": init_norm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "mlp_norm": init_norm(cfg.d_model, dtype),
        "moe": init_moe_ffn(k2, cfg, dtype),
    }


def moe_block(params, x, positions, seed, cfg: ModelConfig, cache, cache_index,
              method, token_valid=None):
    _, norm = L.make_norm(cfg.norm)
    # causal flag + backend both come from cfg (attention dispatches through
    # models.attention.dispatch_attention / the PagedKV decode path, exactly
    # like dense_block — MoE layers get paged decode for free)
    h, new_cache = attention(
        params["attn"], norm(params["attn_norm"], x, cfg.norm_eps), positions,
        L.seed_fold(seed, 100), cfg, causal=cfg.is_causal_lm,
        kv_cache=cache, cache_index=cache_index, method=method,
    )
    x = x + h
    h, aux = moe_ffn(params["moe"], norm(params["mlp_norm"], x, cfg.norm_eps),
                     L.seed_fold(seed, 200), cfg, method, token_valid=token_valid)
    return x + h, new_cache, aux
