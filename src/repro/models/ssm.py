"""State-space blocks: Mamba1 (falcon-mamba-7b) and Mamba2/SSD (zamba2-7b).

Quartet applies to every projection GEMM (in/x/dt/out) — the selective-scan
recurrence itself is elementwise and stays in fp32 (see DESIGN.md
§Arch-applicability).  TPU adaptation of the scan:

* mamba1: the recurrence couples (channel × state) inside an exp, so it does
  not factor into GEMMs; we run a `lax.scan` over time on an fp32 [B, Di, N]
  state — the projections around it carry the FLOPs.  This is O(S) compute
  and O(1) state: exactly why `long_500k` is assigned to the SSM archs.
* mamba2: A is a per-head scalar → the SSD chunked form turns the scan into
  chunk-local attention-like matmuls (MXU) + an O(S/Lc) inter-chunk scan.

Both provide a single-token ``*_step`` used by the serving engine, carrying
(conv_state [B, K-1, Di], ssm_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L

# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv.  x: [B, S, Di], w: [K, Di], b: [Di].
    ``state``: [B, K-1, Di] previous inputs (decode); returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, K-1+S, Di]
    y = sum(xp[:, k : k + x.shape[1], :] * w[k][None, None, :] for k in range(K))
    new_state = xp[:, -(K - 1) :, :]
    return y + b[None, None, :], new_state


def _softplus(x):
    return jax.nn.softplus(x.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def init_mamba1_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n, kc = cfg.ssm_state, cfg.ssm_conv
    r = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "norm": L.init_rmsnorm(d, dtype),
        "in_proj": L.init_dense(ks[0], d, 2 * di, dtype),
        "conv_w": L.trunc_normal(ks[1], (kc, di), 1.0 / np.sqrt(kc * di) * np.sqrt(di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.init_dense(ks[2], di, r + 2 * n, dtype),
        "dt_proj": L.init_dense(ks[3], r, di, dtype, use_bias=True),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.init_dense(ks[4], di, d, dtype),
    }


def _mamba1_scan(h0, a, bx):
    """h_t = a_t · h_{t-1} + bx_t over time.  a, bx: [S, B, Di, N]."""

    def body(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    return jax.lax.scan(body, h0, (a, bx))


def mamba1_block(params, x, positions, seed, cfg: ModelConfig, cache, cache_index, method):
    """x: [B, S, D].  cache: (conv_state, h) for decode, else None."""
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n, r = cfg.ssm_state, max(d // 16, 1)
    qc = cfg.quartet
    B, S, _ = x.shape

    xin = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    xz = L.dense(params["in_proj"], xin, L.seed_fold(seed, 1), qc, method)
    x1, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache[0] if cache is not None else None
    x1, new_conv = _causal_conv(x1, params["conv_w"].astype(jnp.float32),
                                params["conv_b"].astype(jnp.float32), conv_state)
    x1 = jax.nn.silu(x1.astype(jnp.float32)).astype(x.dtype)

    proj = L.dense(params["x_proj"], x1, L.seed_fold(seed, 2), qc, method)
    dt_r, Bm, Cm = jnp.split(proj.astype(jnp.float32), [r, r + n], axis=-1)
    dt = _softplus(L.dense(params["dt_proj"], dt_r.astype(x.dtype),
                           L.seed_fold(seed, 3), qc, method))  # [B,S,Di]
    A = -jnp.exp(params["A_log"])  # [Di, N]

    a = jnp.exp(dt[..., None] * A[None, None])  # [B,S,Di,N]
    bx = (dt * x1.astype(jnp.float32))[..., None] * Bm[:, :, None, :]  # [B,S,Di,N]

    h0 = cache[1] if cache is not None else jnp.zeros((B, di, n), jnp.float32)
    hT, hs = _mamba1_scan(h0, jnp.moveaxis(a, 1, 0), jnp.moveaxis(bx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)  # [B,S,Di,N]
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cm) + params["D"][None, None] * x1.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = L.dense(params["out_proj"], y, L.seed_fold(seed, 4), qc, method)

    new_cache = None if cache is None else (new_conv, hT)
    return x + out, new_cache, jnp.float32(0.0)


def mamba1_cache_spec(cfg: ModelConfig, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    return (
        jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, di), jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct((batch, di, cfg.ssm_state), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Mamba2 (SSD chunked form)
# ---------------------------------------------------------------------------


def init_mamba2_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n, kc, hd = cfg.ssm_state, cfg.ssm_conv, cfg.ssm_head_dim
    nh = di // hd
    ks = jax.random.split(key, 4)
    return {
        "norm": L.init_rmsnorm(d, dtype),
        "in_proj": L.init_dense(ks[0], d, 2 * di + 2 * n + nh, dtype),
        "conv_w": L.trunc_normal(ks[1], (kc, di + 2 * n), 1.0 / np.sqrt(kc), dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": L.init_rmsnorm(di, dtype),
        "out_proj": L.init_dense(ks[2], di, d, dtype),
    }


def _ssd_chunk_scan(xh, dt, A, Bm, Cm, h0, chunk: int):
    """SSD: xh [B,S,nh,hd], dt [B,S,nh] (post-softplus), A [nh] (<0),
    Bm/Cm [B,S,N].  Returns (y [B,S,nh,hd], hT [B,nh,hd,N])."""
    B, S, nh, hd = xh.shape
    N = Bm.shape[-1]
    Lc = min(chunk, S)
    while S % Lc != 0:
        Lc //= 2
    nc = S // Lc

    xc = xh.reshape(B, nc, Lc, nh, hd).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Lc, nh)
    Bc = Bm.reshape(B, nc, Lc, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Lc, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]  # [B,nc,Lc,nh] (negative)
    cum = jnp.cumsum(dA, axis=2)

    def body(h, inp):
        xcb, dtb, Bb, Cb, cumb = inp  # per-chunk slices, chunk axis leading removed
        # intra-chunk (attention-like): y[t] = Σ_{s<=t} C_t·B_s exp(cum_t-cum_s) dt_s x_s
        Lmat = cumb[:, :, None, :] - cumb[:, None, :, :]  # [B,Lc,Lc,nh]
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        # mask *inside* the exp: masked entries are exp(-1e30) = 0 with zero
        # gradient; exp-then-where would backprop NaN through the +inf side
        decay = jnp.exp(jnp.where(tri[None, :, :, None], Lmat, -1e30))
        CB = jnp.einsum("btn,bsn->bts", Cb, Bb, preferred_element_type=jnp.float32)
        scores = CB[..., None] * decay * dtb[:, None, :, :]  # [B,t,s,nh]
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, xcb,
                             preferred_element_type=jnp.float32)
        # inter-chunk: incoming state
        y_inter = jnp.einsum("btn,bhdn->bthd", Cb, h,
                             preferred_element_type=jnp.float32) * jnp.exp(cumb)[..., None]
        # state update
        tot = cumb[:, -1:, :]  # [B,1,nh]
        dec_end = jnp.exp(tot - cumb) * dtb  # [B,Lc,nh]
        h_new = jnp.exp(tot[:, 0, :])[:, :, None, None] * h + jnp.einsum(
            "bshd,bsn,bsh->bhdn", xcb, Bb, dec_end, preferred_element_type=jnp.float32)
        return h_new, y_intra + y_inter

    hT, ys = jax.lax.scan(
        body, h0,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0), jnp.moveaxis(Bc, 1, 0),
         jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(cum, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, nh, hd)
    return y, hT


def mamba2_block(params, x, positions, seed, cfg: ModelConfig, cache, cache_index, method):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    qc = cfg.quartet
    B, S, _ = x.shape

    xin = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    zxbcdt = L.dense(params["in_proj"], xin, L.seed_fold(seed, 1), qc, method)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    conv_state = cache[0] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"].astype(jnp.float32),
                                 params["conv_b"].astype(jnp.float32), conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    x1, Bm, Cm = jnp.split(xbc, [di, di + n], axis=-1)

    dt = _softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])  # [B,S,nh]
    A = -jnp.exp(params["A_log"])
    xh = x1.reshape(B, S, nh, hd)

    h0 = cache[1] if cache is not None else jnp.zeros((B, nh, hd, n), jnp.float32)
    y, hT = _ssd_chunk_scan(xh, dt, A, Bm, Cm, h0, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(B, S, di)
    y = L.rmsnorm(params["gate_norm"], (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                  cfg.norm_eps)
    out = L.dense(params["out_proj"], y, L.seed_fold(seed, 4), qc, method)

    new_cache = None if cache is None else (new_conv, hT)
    return x + out, new_cache, jnp.float32(0.0)


def mamba2_cache_spec(cfg: ModelConfig, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    return (
        jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, di + 2 * cfg.ssm_state), jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct((batch, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    )
