"""Zamba2-style hybrid: a stack of Mamba2 blocks with a *shared* attention
block (one parameter set, reused) applied every ``attn_every`` layers.

Structure (L = 81, attn_every = 6): 13 super-blocks of [shared-attn →
6 × mamba2] followed by a 3-layer mamba2 tail.  The shared block's weights
are closure constants of the super-block scan; its 13 applications have
*distinct* KV caches (weights shared, state not).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import (
    constrain_layer_params,
    constrain_logits,
    constrain_tokens,
)
from repro.models import layers as L
from repro.models.ssm import init_mamba2_block, mamba2_block, mamba2_cache_spec
from repro.models.transformer import (
    LAYER_SEED_STRIDE,
    dense_block,
    dense_cache_spec,
    init_dense_block,
    stacked_init,
)


def _split_counts(cfg: ModelConfig) -> tuple[int, int, int]:
    n_super = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_super * cfg.attn_every
    return n_super, cfg.attn_every, tail


def init_hybrid_lm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_m, k_a, k_head = jax.random.split(key, 4)
    params = {
        "embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "mamba_layers": stacked_init(init_mamba2_block, k_m, cfg.num_layers, cfg, dtype),
        "shared_attn": init_dense_block(k_a, cfg, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


def _take(tree, sl):
    return jax.tree.map(lambda x: x[sl], tree)


def hybrid_forward(params, tokens, cfg: ModelConfig, seed, *, positions=None,
                   caches=None, cache_index=None, method="quartet", extra=None,
                   features_only=False):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = constrain_tokens(L.embed(params["embed"], tokens))

    n_super, per, tail = _split_counts(cfg)
    main = _take(params["mamba_layers"], slice(0, n_super * per))
    main = jax.tree.map(lambda a: a.reshape(n_super, per, *a.shape[1:]), main)
    tail_p = _take(params["mamba_layers"], slice(n_super * per, cfg.num_layers))

    attn_caches = caches["attn"] if caches is not None else None
    m_caches = caches["mamba"] if caches is not None else None
    if m_caches is not None:
        m_main = jax.tree.map(lambda a: a.reshape(n_super, per, *a.shape[1:]),
                              _take(m_caches, slice(0, n_super * per)))
        m_tail = _take(m_caches, slice(n_super * per, cfg.num_layers))
    else:
        m_main = m_tail = None

    shared = params["shared_attn"]

    def mamba_scan(x, group_params, group_caches, seed0):
        def body(carry, inp):
            x = carry
            lp, i, c = inp
            lp = constrain_layer_params(lp)
            s = (seed0 + i.astype(jnp.uint32) * jnp.uint32(LAYER_SEED_STRIDE)).astype(jnp.uint32)
            x, nc, _ = mamba2_block(lp, x, positions, s, cfg, c, cache_index, method)
            return constrain_tokens(x), nc
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        n = jax.tree.leaves(group_params)[0].shape[0]
        return jax.lax.scan(body, x, (group_params, jnp.arange(n, dtype=jnp.uint32), group_caches))

    def super_body(carry, inp):
        x = carry
        sp_idx, m_params, m_cache, a_cache = inp
        s_attn = (seed + sp_idx.astype(jnp.uint32) * jnp.uint32(7919)).astype(jnp.uint32)
        x, new_a_cache, _ = dense_block(shared, x, positions, s_attn, cfg,
                                        a_cache, cache_index, method)
        seed0 = (seed + sp_idx.astype(jnp.uint32)
                 * jnp.uint32((per * LAYER_SEED_STRIDE) % (2**32))).astype(jnp.uint32)
        x, new_m_cache = mamba_scan(x, m_params, m_cache, seed0)
        return x, (new_m_cache, new_a_cache)

    if cfg.remat:  # hierarchical remat (see vlm.py): the shared-attention
        # block otherwise saves its intermediates per super application
        super_body = jax.checkpoint(super_body, prevent_cse=False)
    x, (new_m_main, new_attn) = jax.lax.scan(
        super_body, x,
        (jnp.arange(n_super, dtype=jnp.uint32), main, m_main, attn_caches),
    )
    new_m_tail = None
    if tail:
        x, new_m_tail = mamba_scan(x, tail_p, m_tail, L.seed_fold(seed, 4242))

    from repro.models.transformer import lm_head_apply
    logits = x if features_only else lm_head_apply(params, x, cfg, seed, method)

    new_caches = None
    if caches is not None:
        if tail:
            new_m = jax.tree.map(
                lambda a, b: jnp.concatenate([a.reshape(-1, *a.shape[2:]), b], axis=0),
                new_m_main, new_m_tail)
        else:
            new_m = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), new_m_main)
        new_caches = {"attn": new_attn, "mamba": new_m}
    return logits, new_caches, jnp.float32(0.0)


def hybrid_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    n_super, _, _ = _split_counts(cfg)
    attn = dense_cache_spec(cfg, batch, max_len)
    stack = lambda spec, n: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec)
    return {
        "attn": stack(attn, n_super),
        "mamba": stack(mamba2_cache_spec(cfg, batch), cfg.num_layers),
    }
