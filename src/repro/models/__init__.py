"""Model zoo: functional JAX implementations (params are plain pytrees) of
dense / MoE / SSM / hybrid / enc-dec / VLM transformer backbones, with every
linear layer routed through Quartet (or a selectable baseline scheme)."""

from repro.models.registry import build_model  # noqa: F401
