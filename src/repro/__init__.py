"""Quartet reproduction: native MXFP4 training as a TPU-native JAX framework.

Layers (DESIGN.md §3): core (the paper's algorithm), kernels (Pallas),
models (10-arch zoo), configs, data, optim, distributed, checkpoint, train,
launch (mesh / dry-run / roofline / entry points).
"""

__version__ = "1.0.0"
