"""Numeric formats for low-precision training.

Implements the microscaling (MX) formats from the OCP MX spec v1.0 [32] and
NVIDIA Blackwell [31], plus the integer grids used by the paper's baselines.

The central object is :class:`Format`: a (possibly non-uniform) quantization
grid together with its block-scaling rule.  MXFP4 = E2M1 element grid +
E8M0 (power-of-two) scale shared over 1-D blocks of 32 elements.

All grids are represented explicitly as sorted jnp arrays so that RTN /
stochastic rounding can be written once, generically, and verified against
``jnp.float4_e2m1fn`` casts (which JAX implements natively with
round-to-nearest-ties-even semantics — see tests/test_formats.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Element grids
# ---------------------------------------------------------------------------

# E2M1: 1 sign, 2 exponent, 1 mantissa. Positive values:
#   subnormal: 0, 0.5 ;  normals: 1, 1.5, 2, 3, 4, 6
_E2M1_POS = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float64)

# E3M2 (FP6 variant, for completeness / ablations)
_E3M2_POS = np.array(
    [0.0, 0.0625, 0.125, 0.1875, 0.25, 0.3125, 0.375, 0.4375]
    + [v * 2.0**e for e in range(-2, 5) for v in (1.0, 1.25, 1.5, 1.75)],
    dtype=np.float64,
)

# E4M3 (FP8, used as the "lossless" baseline precision in the paper)
def _e4m3_grid() -> np.ndarray:
    vals = [0.0]
    # subnormals: mantissa/8 * 2^-6
    for m in range(1, 8):
        vals.append(m / 8.0 * 2.0**-6)
    # normals: exponent -6..8, 1.m/8 ; top exponent loses 1 code (NaN) -> max 448
    for e in range(-6, 9):
        for m in range(8):
            v = (1.0 + m / 8.0) * 2.0**e
            if v <= 448.0:
                vals.append(v)
    return np.array(sorted(set(vals)), dtype=np.float64)


_E4M3_POS = _e4m3_grid()

# E5M2 (FP8 wide-range variant, gradients in classic mixed precision)
def _e5m2_grid() -> np.ndarray:
    vals = [0.0]
    for m in range(1, 4):
        vals.append(m / 4.0 * 2.0**-14)
    for e in range(-14, 16):
        for m in range(4):
            v = (1.0 + m / 4.0) * 2.0**e
            if v <= 57344.0:
                vals.append(v)
    return np.array(sorted(set(vals)), dtype=np.float64)


_E5M2_POS = _e5m2_grid()


def _int_grid(bits: int) -> np.ndarray:
    """Symmetric integer grid, e.g. INT4 -> -7..7 (symmetric, no -8)."""
    m = 2 ** (bits - 1) - 1
    return np.arange(0, m + 1, dtype=np.float64)


def _signed(pos: np.ndarray) -> np.ndarray:
    return np.unique(np.concatenate([-pos, pos]))


@dataclasses.dataclass(frozen=True)
class Format:
    """A block-scaled quantization format.

    Attributes:
      name: identifier, e.g. "mxfp4".
      grid: full signed grid (sorted 1-D float32 array) of representable
        element values at scale 1.
      block: block size sharing one scale (1-D blocks along the last /
        contraction dimension). ``0`` means per-tensor scale.
      scale_dtype: "e8m0" (power-of-two, MX formats), "e4m3" (NVFP4), or
        "fp32" (idealised).
      bits: element bit-width (for BOPS speedup modelling).
    """

    name: str
    grid: tuple[float, ...]
    block: int
    scale_dtype: Literal["e8m0", "e4m3", "fp32"]
    bits: int

    @property
    def grid_array(self) -> np.ndarray:
        # host-side (numpy) so static masks/splits stay concrete under jit
        return np.asarray(self.grid, dtype=np.float32)

    @property
    def max_value(self) -> float:
        return float(self.grid[-1])

    @property
    def num_levels(self) -> int:
        return len(self.grid)


MXFP4 = Format("mxfp4", tuple(_signed(_E2M1_POS)), 32, "e8m0", 4)
NVFP4 = Format("nvfp4", tuple(_signed(_E2M1_POS)), 16, "e4m3", 4)
MXFP6 = Format("mxfp6", tuple(_signed(_E3M2_POS)), 32, "e8m0", 6)
MXFP8 = Format("mxfp8", tuple(_signed(_E4M3_POS)), 32, "e8m0", 8)
FP8_E4M3 = Format("fp8_e4m3", tuple(_signed(_E4M3_POS)), 0, "fp32", 8)
FP8_E5M2 = Format("fp8_e5m2", tuple(_signed(_E5M2_POS)), 0, "fp32", 8)
INT4 = Format("int4", tuple(_signed(_int_grid(4))), 32, "fp32", 4)
INT8 = Format("int8", tuple(_signed(_int_grid(8))), 32, "fp32", 8)
BF16 = Format("bf16", (), 0, "fp32", 16)  # passthrough sentinel

FORMATS: dict[str, Format] = {
    f.name: f
    for f in (MXFP4, NVFP4, MXFP6, MXFP8, FP8_E4M3, FP8_E5M2, INT4, INT8, BF16)
}


def get_format(name: str) -> Format:
    try:
        return FORMATS[name]
    except KeyError:
        raise ValueError(f"unknown format {name!r}; have {sorted(FORMATS)}") from None


# ---------------------------------------------------------------------------
# E8M0 scale handling
# ---------------------------------------------------------------------------

# OCP E8M0 spans 2^-127..2^127; we clamp the simulation to the f32 *normal*
# floor (2^-126): XLA's exp2 flushes below it (and is inexact near it), which
# would turn all-zero blocks into 0/0 = NaN.  Blocks at that magnitude
# quantize to zero either way, so this is value-exact.
E8M0_MIN_EXP = -126
E8M0_MAX_EXP = 127


def exp2i(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^e for integer-valued e ∈ [-126, 127] via f32 bit manipulation.

    XLA's exp2 is neither exact (≈3e-6 rel. error near the subnormal
    boundary) nor total (flushes 2^-126 to 0 on CPU); power-of-two scales
    must be *bit-exact* for the QDQ GEMM equivalence, so we build the float
    directly: bits = (e + 127) << 23.
    """
    bits = (e.astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def round_scale_e8m0(scale: jnp.ndarray, mode: str = "ceil") -> jnp.ndarray:
    """Quantize positive scales to E8M0 (pure powers of two).

    mode="ceil"   rounds the exponent up — guarantees ``absmax/scale`` stays
                  inside the grid so stochastic rounding never clips; this is
                  the rule used by Tseng et al. [41] and by Quartet's backward.
    mode="nearest" rounds to the nearest power of two (lower MSE; forward).
    """
    scale = jnp.asarray(scale, jnp.float32)
    safe = jnp.maximum(scale, 2.0**E8M0_MIN_EXP)
    log2 = jnp.log2(safe)
    if mode == "ceil":
        e = jnp.ceil(log2 - 1e-6)  # eps: exact powers of two stay put
    elif mode == "floor":
        e = jnp.floor(log2 + 1e-6)
    elif mode == "nearest":
        e = jnp.round(log2)
    else:
        raise ValueError(f"bad e8m0 rounding mode {mode!r}")
    e = jnp.clip(e, E8M0_MIN_EXP, E8M0_MAX_EXP)
    return exp2i(e)


def scale_to_e8m0_code(scale: jnp.ndarray) -> jnp.ndarray:
    """Biased-exponent uint8 code for a power-of-two scale (storage format)."""
    e = jnp.round(jnp.log2(jnp.maximum(scale, 2.0**E8M0_MIN_EXP)))
    return (e + 127.0).astype(jnp.uint8)


def e8m0_code_to_scale(code: jnp.ndarray) -> jnp.ndarray:
    return exp2i(code.astype(jnp.int32) - 127)


def quantize_scale(scale: jnp.ndarray, fmt: Format, mode: str) -> jnp.ndarray:
    """Apply the format's scale-dtype constraint to raw positive scales."""
    if fmt.scale_dtype == "e8m0":
        return round_scale_e8m0(scale, mode)
    if fmt.scale_dtype == "e4m3":
        return scale.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    return scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Generic grid rounding (the reference semantics; kernels mirror this)
# ---------------------------------------------------------------------------


def rtn_to_grid(x: jnp.ndarray, grid: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest onto an arbitrary sorted grid (ties -> lower index).

    For the E2M1 grid this matches ``x.astype(float4_e2m1fn)`` everywhere
    except exact ties, where IEEE uses ties-to-even; the discrepancy set has
    measure zero and is covered explicitly in tests.
    """
    x = jnp.asarray(x, jnp.float32)
    grid = jnp.asarray(grid)
    mids = (grid[1:] + grid[:-1]) / 2.0
    idx = jnp.searchsorted(mids, x, side="right")
    return grid[idx]


_HAS_NATIVE_E2M1 = hasattr(jnp, "float4_e2m1fn")


def rtn_e2m1(x: jnp.ndarray) -> jnp.ndarray:
    """Hardware-exact E2M1 RTN (ties-to-even, saturating).

    Uses the native ``float4_e2m1fn`` cast when this JAX exposes it; otherwise
    an arithmetic fallback with identical semantics: saturate to ±6, then
    round the mantissa to 1 bit per binade with ``jnp.round`` (which is
    round-half-to-even, matching IEEE).  Subnormals (|x| < 1) live on the
    uniform {0, 0.5, 1} grid, so a single half-unit round covers them.  The
    fallback is pure arithmetic (no gathers), so it also lowers inside Pallas
    kernel bodies.
    """
    if _HAS_NATIVE_E2M1:
        return x.astype(jnp.float4_e2m1fn).astype(jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    a = jnp.clip(jnp.abs(x), 0.0, 6.0)
    # normals (1 <= a <= 6): a = m * 2^e with m in [1, 2), e in {0, 1, 2};
    # one mantissa bit => grid step 2^e / 2
    e = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(a, 1.0))), 0.0, 2.0)
    pw = exp2i(e)
    q_norm = jnp.round(a / pw * 2.0) * 0.5 * pw
    q_sub = jnp.round(a * 2.0) * 0.5  # {0, 0.5, 1} uniform region
    q = jnp.where(a >= 1.0, q_norm, q_sub)
    return jnp.sign(x) * q


def stochastic_round_to_grid(
    x: jnp.ndarray, grid: jnp.ndarray, u: jnp.ndarray
) -> jnp.ndarray:
    """Unbiased stochastic rounding onto a symmetric sorted grid.

    Sign-magnitude convention (matches the hardware-style arithmetic SR in
    the Pallas kernels): round |x| up (in magnitude) with probability
    (|x| − lo)/(hi − lo), then reapply the sign.  ``u`` ~ U[0,1) of the same
    shape as ``x``.  Values beyond the grid max saturate (biased there —
    callers pick scales that avoid clipping; Quartet guarantees this via the
    ceil-mode E8M0 scale).
    """
    x = jnp.asarray(x, jnp.float32)
    grid_np = np.asarray(grid)
    pos = jnp.asarray(grid_np[grid_np >= 0])  # positive half (static mask)
    gmax = float(grid_np[-1])
    a = jnp.clip(jnp.abs(x), 0.0, gmax)
    lo_idx = jnp.clip(jnp.searchsorted(pos, a, side="right") - 1, 0, pos.shape[0] - 1)
    hi_idx = jnp.clip(lo_idx + 1, 0, pos.shape[0] - 1)
    lo, hi = pos[lo_idx], pos[hi_idx]
    gap = jnp.where(hi > lo, hi - lo, 1.0)
    p_up = jnp.clip((a - lo) / gap, 0.0, 1.0)
    mag = jnp.where(u < p_up, hi, lo)
    return jnp.sign(x) * mag


# ---------------------------------------------------------------------------
# E2M1 nibble codes (storage format: two elements per byte)
# ---------------------------------------------------------------------------

# Positive E2M1 half-grid in code order: index i encodes sign·_E2M1_POS[i&7],
# bit 3 is the sign — the standard FP4 bit layout (S EE M).
_E2M1_POS_F32 = np.asarray(_E2M1_POS, dtype=np.float32)


def e2m1_to_nibble(q: jnp.ndarray) -> jnp.ndarray:
    """On-grid E2M1 values (scale 1) → 4-bit codes 0..15 (uint8).

    Pure arithmetic (no searchsorted): for |q| ≥ 1 the magnitude index is
    2 + 2·e + m with e = floor(log2|q|) and m the half-step mantissa bit;
    below 1 the grid is uniform at 0.5.  Negative zero maps to code 0.
    """
    q = jnp.asarray(q, jnp.float32)
    a = jnp.abs(q)
    e = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(a, 1.0))), 0.0, 2.0)
    m = a / exp2i(e) * 2.0 - 2.0  # 0 or 1 for on-grid normals
    idx_norm = 2.0 + 2.0 * e + m
    idx = jnp.where(a >= 1.0, idx_norm, a * 2.0)
    sign = (q < 0).astype(jnp.uint8) << 3
    return idx.astype(jnp.uint8) | sign


def nibble_to_e2m1(codes: jnp.ndarray) -> jnp.ndarray:
    """4-bit codes 0..15 (uint8) → f32 E2M1 grid values."""
    mag = jnp.asarray(_E2M1_POS_F32)[(codes & 7).astype(jnp.int32)]
    return jnp.where((codes & 8) > 0, -mag, mag)


def pack_nibbles(codes: jnp.ndarray) -> jnp.ndarray:
    """uint8 codes 0..15 [..., K] → packed uint8 [..., K/2] (even elem = high
    nibble).  K must be even."""
    k = codes.shape[-1]
    if k % 2 != 0:
        raise ValueError(f"last dim {k} not even")
    pairs = codes.reshape(*codes.shape[:-1], k // 2, 2)
    return (pairs[..., 0] << 4) | (pairs[..., 1] & 0xF)


def unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    """packed uint8 [..., K/2] → uint8 codes 0..15 [..., K]."""
    hi = (packed >> 4) & 0xF
    lo = packed & 0xF
    return jnp.stack([hi, lo], axis=-1).reshape(*packed.shape[:-1], -1)


# ---------------------------------------------------------------------------
# Block reshaping helpers
# ---------------------------------------------------------------------------


def to_blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Reshape [..., K] -> [..., K // block, block]. K must divide by block."""
    if block <= 0:
        return x[..., None, :] if x.ndim >= 1 else x
    k = x.shape[-1]
    if k % block != 0:
        raise ValueError(f"last dim {k} not divisible by block {block}")
    return x.reshape(*x.shape[:-1], k // block, block)


def from_blocks(xb: jnp.ndarray) -> jnp.ndarray:
    return xb.reshape(*xb.shape[:-2], xb.shape[-2] * xb.shape[-1])


@functools.lru_cache(maxsize=None)
def gaussian_optimal_clip(fmt_name: str) -> float:
    """Clip multiplier c* minimizing E[(x - Q(clip(x)))^2], x ~ N(0,1).

    QuEST [33] fits the quantization scale to the RMS of the (Hadamard-
    Gaussianized) input: scale = c* · std.  We precompute c* per grid by
    numeric integration over a fine Gaussian quadrature — done once, on host.
    """
    fmt = get_format(fmt_name)
    grid = np.asarray(fmt.grid, dtype=np.float64)
    gmax = grid[-1]
    xs = np.linspace(-12.0, 12.0, 48001)
    pdf = np.exp(-0.5 * xs**2) / np.sqrt(2 * np.pi)

    def mse(c: float) -> float:
        scaled = xs / (c / gmax)  # scale s.t. clip point = c*std
        mids = (grid[1:] + grid[:-1]) / 2.0
        q = grid[np.searchsorted(mids, np.clip(scaled, -gmax, gmax))]
        err = (xs - q * (c / gmax)) ** 2
        return float(np.trapezoid(err * pdf, xs))

    cs = np.linspace(1.0, 8.0, 141)
    errs = [mse(c) for c in cs]
    c0 = cs[int(np.argmin(errs))]
    cs2 = np.linspace(c0 - 0.1, c0 + 0.1, 81)
    errs2 = [mse(c) for c in cs2]
    return float(cs2[int(np.argmin(errs2))])
