"""The paper's quantized-training scaling law (Eq. 1) and its two-stage fit.

    L(N, D, Pf, Pb) = ( A/(N·effN(Pf))^α + B/(D·effD(Pb))^β )^γ + E

Stage 1 fits (A, α, B, β, γ, E) on unquantized baseline runs with a Huber loss
(δ = 1e-4) on log L — identical to Busbridge et al. [8] / Appendix A.2.
Stage 2 freezes those and fits (effN, effD) per quantized method.

Also implements Ingredient 2: the speedup model (Table 1) and the optimality
regions of Fig. 1(b,c) — given a forward compute budget and a training budget,
which (Pf, Pb) pair reaches the lowest loss.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

import numpy as np

# Paper's fitted stage-1 coefficients (Table 6) — used as reference/init.
PAPER_COEFFS = dict(A=1.52e5, alpha=0.589, B=5.25e5, beta=0.544, E=1.35, gamma=0.274)

# Paper's Table 1 speedup model, relative to FP8 (FORWARD:BACKWARD labels).
SPEEDUPS = {
    ("fp4", "fp8"): dict(spfw=2.0, spbw=1.0, sptr=1.2),
    ("fp8", "fp4"): dict(spfw=1.0, spbw=2.0, sptr=1.5),
    ("fp4", "fp4"): dict(spfw=2.0, spbw=2.0, sptr=2.0),
    ("fp8", "fp8"): dict(spfw=1.0, spbw=1.0, sptr=1.0),
}


def harmonic_training_speedup(spfw: float, spbw: float) -> float:
    """sptr = harmonic mean of (spfw, spbw) with weights (1/3, 2/3)."""
    return 1.0 / ((1.0 / 3.0) / spfw + (2.0 / 3.0) / spbw)


@dataclasses.dataclass
class ScalingLaw:
    A: float
    alpha: float
    B: float
    beta: float
    E: float
    gamma: float

    def loss(self, N, D, eff_n: float = 1.0, eff_d: float = 1.0):
        N = np.asarray(N, np.float64)
        D = np.asarray(D, np.float64)
        core = self.A / (N * eff_n) ** self.alpha + self.B / (D * eff_d) ** self.beta
        return core**self.gamma + self.E

    def params(self) -> dict:
        return dataclasses.asdict(self)


def _huber(r: np.ndarray, delta: float) -> np.ndarray:
    a = np.abs(r)
    return np.where(a <= delta, 0.5 * r**2, delta * (a - 0.5 * delta))


def _objective(law: ScalingLaw, runs, eff_n=1.0, eff_d=1.0, delta=1e-4) -> float:
    pred = law.loss(runs[:, 0], runs[:, 1], eff_n, eff_d)
    r = np.log(pred) - np.log(runs[:, 2])
    return float(np.sum(_huber(r, delta)))


def _nelder_mead(f, x0: np.ndarray, iters: int = 4000, scale: float = 0.15) -> np.ndarray:
    """Dependency-free Nelder–Mead in log-ish parameter space."""
    n = len(x0)
    simplex = [x0]
    for i in range(n):
        p = x0.copy()
        p[i] = p[i] + (abs(p[i]) + 1e-3) * scale
        simplex.append(p)
    simplex = np.array(simplex)
    vals = np.array([f(p) for p in simplex])
    for _ in range(iters):
        order = np.argsort(vals)
        simplex, vals = simplex[order], vals[order]
        c = simplex[:-1].mean(axis=0)
        xr = c + (c - simplex[-1])
        fr = f(xr)
        if fr < vals[0]:
            xe = c + 2.0 * (c - simplex[-1])
            fe = f(xe)
            simplex[-1], vals[-1] = (xe, fe) if fe < fr else (xr, fr)
        elif fr < vals[-2]:
            simplex[-1], vals[-1] = xr, fr
        else:
            xc = c + 0.5 * (simplex[-1] - c)
            fc = f(xc)
            if fc < vals[-1]:
                simplex[-1], vals[-1] = xc, fc
            else:
                simplex[1:] = simplex[0] + 0.5 * (simplex[1:] - simplex[0])
                vals[1:] = [f(p) for p in simplex[1:]]
        if np.max(np.abs(vals - vals[0])) < 1e-14:
            break
    return simplex[np.argmin(vals)]


def fit_baseline(runs: Sequence[tuple[float, float, float]], init: Mapping | None = None) -> ScalingLaw:
    """Stage 1: fit (A, α, B, β, E, γ) on (N, D, loss) triples of FP runs."""
    runs = np.asarray(runs, np.float64)
    p0 = dict(PAPER_COEFFS)
    if init:
        p0.update(init)
    # parameterize A, B in log space; squash E, gamma, alpha, beta positive
    x0 = np.array([np.log(p0["A"]), p0["alpha"], np.log(p0["B"]), p0["beta"],
                   p0["E"], p0["gamma"]])

    def unpack(x):
        return ScalingLaw(A=float(np.exp(x[0])), alpha=float(abs(x[1])),
                          B=float(np.exp(x[2])), beta=float(abs(x[3])),
                          E=float(abs(x[4])), gamma=float(abs(x[5])))

    xbest = _nelder_mead(lambda x: _objective(unpack(x), runs), x0)
    return unpack(xbest)


def fit_efficiencies(
    law: ScalingLaw,
    runs: Sequence[tuple[float, float, float]],
    fit_n: bool = True,
    fit_d: bool = True,
) -> tuple[float, float]:
    """Stage 2: fit (effN, effD) ∈ (0, 1] for one quantized method."""
    runs = np.asarray(runs, np.float64)

    def unpack(x):
        en = 1.0 / (1.0 + np.exp(-x[0])) if fit_n else 1.0  # sigmoid -> (0,1)
        ed = 1.0 / (1.0 + np.exp(-x[1])) if fit_d else 1.0
        return en, ed

    def f(x):
        en, ed = unpack(x)
        return _objective(law, runs, en, ed)

    xbest = _nelder_mead(f, np.array([1.0, 1.0]), iters=2000)
    return unpack(xbest)


# ---------------------------------------------------------------------------
# Ingredient 2: optimal-precision regions under a compute budget (Fig. 1 b,c)
# ---------------------------------------------------------------------------


def effective_loss(
    law: ScalingLaw,
    N_max: float,
    D_max: float,
    eff_n: float,
    eff_d: float,
    spfw: float,
    sptr: float,
) -> float:
    """Loss(N_max·spfw, D_max·sptr/spfw, Pf, Pb) — §4.2's budgeted loss.

    A faster forward lets us serve a model `spfw×` larger at equal inference
    cost; a faster training step buys `sptr/spfw×` more data under the fixed
    training budget N·D.
    """
    return float(law.loss(N_max * spfw, D_max * sptr / spfw, eff_n, eff_d))


def optimality_region(
    law: ScalingLaw,
    methods: Mapping[str, dict],
    n_grid: np.ndarray,
    dn_ratio_grid: np.ndarray,
) -> np.ndarray:
    """For each (N, D/N) cell return the argmin method name (Fig. 1 b,c).

    ``methods``: name -> dict(eff_n, eff_d, spfw, sptr).
    """
    names = list(methods)
    out = np.empty((len(n_grid), len(dn_ratio_grid)), dtype=object)
    for i, n in enumerate(n_grid):
        for j, r in enumerate(dn_ratio_grid):
            losses = [
                effective_loss(law, n, n * r, m["eff_n"], m["eff_d"], m["spfw"], m["sptr"])
                for m in methods.values()
            ]
            out[i, j] = names[int(np.argmin(losses))]
    return out
