"""Core of the Quartet reproduction: formats, quantizers, Algorithm 1,
baseline methods, scaling-law machinery, and gradient-quality metrics."""

from repro.core.formats import (  # noqa: F401
    BF16,
    FORMATS,
    INT4,
    INT8,
    MXFP4,
    MXFP8,
    NVFP4,
    Format,
    get_format,
)
from repro.core.hadamard import (  # noqa: F401
    hadamard_transform,
    inverse_hadamard_transform,
    randomized_hadamard_transform,
)
from repro.core.quantizers import (  # noqa: F401
    QuantResult,
    quest,
    rtn_absmax,
    rtn_absmax_pma,
    sr_absmax,
)
from repro.core.quartet import (  # noqa: F401
    BF16_CONFIG,
    FP8_CONFIG,
    QUARTET_CONFIG,
    QuartetConfig,
    quartet_linear,
)
from repro.core.baselines import BASELINE_METHODS, baseline_linear  # noqa: F401
from repro.core.scaling_law import (  # noqa: F401
    ScalingLaw,
    fit_baseline,
    fit_efficiencies,
    optimality_region,
)
from repro.core import metrics  # noqa: F401
