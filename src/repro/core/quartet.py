"""Quartet (Algorithm 1): all three linear-layer GEMMs in MXFP4.

Forward:  fixed block-32 Hadamard on X, W along the contraction dim K →
          QuEST projection (RMSE clip + RTN, E8M0 nearest scales) → LP GEMM.
Backward: randomized block-32 Hadamard Ĥ(·, ξ) along each backward GEMM's
          contraction dim (N for dx, B for dW) with signs ξ shared between the
          two operands → stochastic rounding of ¾·(·) (E8M0 ceil scales → no
          clipping → unbiased) → LP GEMMs → ×16/9 → ⊙ QuEST masks → H⁻¹.

The LP GEMMs run as dequantize-to-f32 + fp32-accumulate contractions, which is
bit-exact w.r.t. native block-scaled FP4 tensor-core GEMMs (DESIGN.md §2).
``use_kernels=True`` routes quantization + GEMM through the Pallas TPU kernels
in ``repro.kernels`` (validated in interpret mode on CPU).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastrng
from repro.core import formats as F
from repro.core import quantizers as Q
from repro.core.hadamard import (
    hadamard_transform,
    randomized_hadamard_transform,
)

SR_PRESCALE = 0.75  # the ¾ factor of Algorithm 1
SR_POSTSCALE = 16.0 / 9.0  # undoes (¾)² on the GEMM product


@dataclasses.dataclass(frozen=True)
class QuartetConfig:
    """Static configuration of the Quartet linear layer."""

    fwd_format: str = "mxfp4"
    bwd_format: str = "mxfp4"
    group: int = 32  # Hadamard group == MXFP4 scale block
    fwd_quantizer: Literal["quest", "rtn_absmax", "sr_absmax", "none"] = "quest"
    bwd_rounding: Literal["sr", "rtn", "none"] = "sr"
    bwd_hadamard: Literal["random", "fixed", "none"] = "random"
    use_kernels: bool = False
    accum_dtype: str = "float32"
    # beyond-paper: FSDP-sharded weights cross the interconnect as 4-bit
    # codes (quantize shard-local → all-gather codes → dequant); exact same
    # math as the paper's forward — the block-32 Hadamard is block-diagonal,
    # so it commutes with K-dim sharding.  See quest_qdq_gathered.
    fp4_allgather: bool = False

    @property
    def fwd_fmt(self) -> F.Format:
        return F.get_format(self.fwd_format)

    @property
    def bwd_fmt(self) -> F.Format:
        return F.get_format(self.bwd_format)


BF16_CONFIG = QuartetConfig(fwd_quantizer="none", bwd_rounding="none", bwd_hadamard="none")
FP8_CONFIG = QuartetConfig(
    fwd_format="mxfp8", bwd_format="mxfp8", fwd_quantizer="rtn_absmax",
    bwd_rounding="rtn", bwd_hadamard="none",
)
QUARTET_CONFIG = QuartetConfig()


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# Quantization helpers (contraction axis must be last)
# ---------------------------------------------------------------------------


def _fwd_quantize(xh: jnp.ndarray, cfg: QuartetConfig, key: jax.Array) -> Q.QuantResult:
    fmt = cfg.fwd_fmt
    if cfg.fwd_quantizer == "quest":
        return Q.quest(xh, fmt)
    if cfg.fwd_quantizer == "rtn_absmax":
        return Q.rtn_absmax(xh, fmt)
    if cfg.fwd_quantizer == "sr_absmax":
        return Q.sr_absmax(xh, key, fmt)
    raise ValueError(cfg.fwd_quantizer)


def _bwd_quantize(gh: jnp.ndarray, cfg: QuartetConfig, seed: jnp.ndarray,
                  salt: int) -> jnp.ndarray:
    """Quantize a backward operand (already Hadamard-rotated, blocks on last
    axis).  SR randomness comes from the fused counter-hash PRNG — threefry
    would materialize a u32 buffer per element (core/fastrng.py)."""
    fmt = cfg.bwd_fmt
    if cfg.bwd_rounding == "sr":
        v = Q.sr_absmax_fast(gh * SR_PRESCALE, seed, fmt, "ceil", salt).values
    elif cfg.bwd_rounding == "rtn":
        v = Q.rtn_absmax(gh * SR_PRESCALE, fmt, scale_mode="ceil").values
    else:
        raise ValueError(cfg.bwd_rounding)
    return v.astype(jnp.bfloat16)  # bf16-exact (see _quartet_fwd)


def _maybe_rht(x: jnp.ndarray, signs: jnp.ndarray, cfg: QuartetConfig, axis: int) -> jnp.ndarray:
    if cfg.bwd_hadamard == "random":
        return randomized_hadamard_transform(x, signs, g=cfg.group, axis=axis)
    if cfg.bwd_hadamard == "fixed":
        return hadamard_transform(x, g=cfg.group, axis=axis)
    return x


def _gemm(a: jnp.ndarray, b: jnp.ndarray, accum_dtype) -> jnp.ndarray:
    """a [..., K] @ b [K, N] with fp32 accumulation (MXU semantics)."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.dtype(accum_dtype),
    )


def _pad32(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to a multiple of 32.  Exact for backward GEMMs:
    padded positions quantize to zero and contribute nothing to the product."""
    n = x.shape[axis]
    pad = (-n) % 32
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# quartet_linear: custom-VJP linear layer
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def quartet_linear(x: jnp.ndarray, w: jnp.ndarray, seed: jnp.ndarray, cfg: QuartetConfig):
    """y = Quartet(x) @ Quartet(w).  x: [..., K], w: [K, N], seed: uint32[]."""
    y, _ = _quartet_fwd(x, w, seed, cfg)
    return y


def _quartet_fwd(x, w, seed, cfg: QuartetConfig):
    if cfg.fwd_quantizer == "none":  # bf16 passthrough (baseline)
        y = _gemm(x, w, cfg.accum_dtype).astype(x.dtype)
        return y, (x, w, seed)

    sent_x = jnp.zeros((0,), x.dtype)  # dtype carriers for the bwd casts
    sent_w = jnp.zeros((0,), w.dtype)

    if cfg.use_kernels:
        from repro.kernels import ops as K

        # Stage 1 (fused Hadamard+QuEST), then Stage 2 (block-scaled GEMM).
        xc, xs, xm = K.hadamard_quest_quantize(x, group=cfg.group)
        wtc, wts, wtm = K.hadamard_quest_quantize(jnp.swapaxes(w, 0, 1), group=cfg.group)
        y = K.mxfp4_matmul(xc, xs, jnp.swapaxes(wtc, 0, 1), jnp.swapaxes(wts, 0, 1))
        y = y.astype(x.dtype)
        # residuals are the true 4-bit payload: codes + per-32 scales + masks
        return y, ((xc, xs), (wtc, wts), xm, jnp.swapaxes(wtm, 0, 1), seed, sent_x, sent_w)

    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    xh = hadamard_transform(x.astype(jnp.float32), g=cfg.group, axis=-1)
    wh = hadamard_transform(w.astype(jnp.float32), g=cfg.group, axis=0)
    xq = _fwd_quantize(xh, cfg, key)
    wq = _fwd_quantize(jnp.swapaxes(wh, 0, 1), cfg, key)  # blocks along K
    # QDQ values are bf16-exact (≤2 mantissa bits × pow2 scale): bf16 GEMM
    # operands + residuals are bit-identical and halve bytes (§Perf iter.)
    xv = xq.values.astype(jnp.bfloat16)
    wv = jnp.swapaxes(wq.values, 0, 1).astype(jnp.bfloat16)
    y = _gemm(xv, wv, cfg.accum_dtype).astype(x.dtype)
    return y, (xv, wv, xq.mask, jnp.swapaxes(wq.mask, 0, 1), seed, sent_x, sent_w)


def _bwd_rotate_quantize_gemms(cfg: QuartetConfig, xq_v, wq_v, m_x, seed, dy):
    """Shared Algorithm-1 backward body for ``_quartet_bwd`` and ``_pq_bwd``:
    the two rotate→quantize→GEMM blocks.

    Returns ``(dx, dw_rot)`` — ``dx [..., K]`` with the activation mask ⊙ and
    H⁻¹ already applied, and ``dw_rot [K, N]`` left in the rotated-quantized
    weight space (the caller owns the weight mask ⊙ + H⁻¹, which for the
    pre-quantized-weight variant live in ``quest_qdq_gathered``'s VJP).
    """
    K, N = wq_v.shape
    dyf = dy.astype(jnp.float32)
    lead = dy.shape[:-1]
    Bflat = int(np.prod(lead)) if lead else 1

    # ----- dx = H⁻¹( 16/9 · (SR(¾·Ĥ_N dy) @ SR(¾·Ĥ_N Wᵀ)ᵀ) ⊙ M_x ) ----------
    # zero-pad N to a multiple of the Hadamard group (exact; see _pad32)
    dy_p = _pad32(dyf, axis=-1)
    wq_p = _pad32(wq_v.astype(jnp.float32), axis=-1)
    Np = dy_p.shape[-1]
    signs_n = fastrng.rademacher(seed, Np, salt=11)
    g_h = _maybe_rht(dy_p, signs_n, cfg, axis=-1)  # [..., Np]
    wt_h = _maybe_rht(wq_p, signs_n, cfg, axis=-1)
    if cfg.bwd_rounding == "none":
        dx_rot = _gemm(g_h, jnp.swapaxes(wt_h, 0, 1), cfg.accum_dtype)
    else:
        g_q = _bwd_quantize(g_h, cfg, seed, salt=1)
        wt_q = _bwd_quantize(wt_h, cfg, seed, salt=2)  # blocks along N ✓
        dx_rot = SR_POSTSCALE * _gemm(g_q, jnp.swapaxes(wt_q, 0, 1), cfg.accum_dtype)
    dx = hadamard_transform(dx_rot * m_x, g=cfg.group, axis=-1)  # H⁻¹ = H

    # ----- dW_rot = 16/9 · SR(¾·Ĥ_B Xᵀ)ᵀ @ SR(¾·Ĥ_B dy) ----------------------
    xf = _pad32(xq_v.astype(jnp.float32).reshape(Bflat, K), axis=0)  # exact
    gf = _pad32(dyf.reshape(Bflat, N), axis=0)
    Bp = xf.shape[0]
    signs_b = fastrng.rademacher(seed, Bp, salt=12)
    x2 = _maybe_rht(xf, signs_b, cfg, axis=0)
    g2 = _maybe_rht(gf, signs_b, cfg, axis=0)
    if cfg.bwd_rounding == "none":
        dw_rot = _gemm(jnp.swapaxes(x2, 0, 1), g2, cfg.accum_dtype)
    else:
        x2_q = _bwd_quantize(jnp.swapaxes(x2, 0, 1), cfg, seed, salt=3)  # [K, B]
        g2_q = _bwd_quantize(jnp.swapaxes(g2, 0, 1), cfg, seed, salt=4)  # [N, B]
        dw_rot = SR_POSTSCALE * _gemm(x2_q, jnp.swapaxes(g2_q, 0, 1), cfg.accum_dtype)
    return dx, dw_rot


def _quartet_bwd(cfg: QuartetConfig, res, dy):
    if cfg.fwd_quantizer == "none":
        x, w, seed = res
        dyf = dy.astype(jnp.float32)
        dx = _gemm(dyf, jnp.swapaxes(w, 0, 1).astype(jnp.float32), cfg.accum_dtype)
        xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        gf = dyf.reshape(-1, dy.shape[-1])
        dw = _gemm(jnp.swapaxes(xf, 0, 1), gf, cfg.accum_dtype)
        return dx.astype(x.dtype), dw.astype(w.dtype), _float0_like(seed)

    if cfg.use_kernels:
        return _quartet_bwd_kernels(cfg, res, dy)

    xq_v, wq_v, m_x, m_w, seed, sent_x, sent_w = res
    dx, dw_rot = _bwd_rotate_quantize_gemms(cfg, xq_v, wq_v, m_x, seed, dy)
    dw = hadamard_transform(dw_rot * m_w, g=cfg.group, axis=0)  # H⁻¹ along K
    return dx.astype(sent_x.dtype), dw.astype(sent_w.dtype), _float0_like(seed)


def _dequant_codes(codes: jnp.ndarray, scales: jnp.ndarray, group: int) -> jnp.ndarray:
    """Half-codes + per-group scales → f32 values (code · 0.5 · scale)."""
    shape = codes.shape
    c = codes.astype(jnp.float32).reshape(*shape[:-1], shape[-1] // group, group)
    return (c * (0.5 * scales)[..., None]).reshape(shape)


def _quartet_bwd_kernels(cfg: QuartetConfig, res, dy):
    """Algorithm 1 backward routed through the Pallas kernels."""
    from repro.kernels import ops as K

    (xc, xs), (wtc, wts), m_x, m_w, seed, sent_x, sent_w = res
    g = cfg.group

    wq_v = jnp.swapaxes(_dequant_codes(wtc, wts, g), 0, 1)  # [K, N]
    Kdim, N = wq_v.shape
    dyf = dy.astype(jnp.float32)
    lead = dy.shape[:-1]
    Bflat = int(np.prod(lead)) if lead else 1

    # ----- dx ---------------------------------------------------------------
    dy_p = _pad32(dyf, axis=-1)
    wq_p = _pad32(wq_v, axis=-1)
    Np = dy_p.shape[-1]
    signs_n = fastrng.rademacher(seed, Np, salt=11)
    gc, gs = K.sr_hadamard_quantize(dy_p, signs_n, seed, salt=1)  # [..., Np]
    wtc2, wts2 = K.sr_hadamard_quantize(wq_p, signs_n, seed, salt=2)  # [K, Np]
    dx_rot = SR_POSTSCALE * K.mxfp4_matmul(
        gc, gs, jnp.swapaxes(wtc2, 0, 1), jnp.swapaxes(wts2, 0, 1)
    )
    dx = hadamard_transform(dx_rot * m_x, g=g, axis=-1)

    # ----- dW ---------------------------------------------------------------
    xq_v = _pad32(_dequant_codes(xc, xs, g).reshape(Bflat, Kdim), axis=0)
    gf = _pad32(dyf.reshape(Bflat, N), axis=0)
    Bp = xq_v.shape[0]
    signs_b = fastrng.rademacher(seed, Bp, salt=12)
    x2c, x2s = K.sr_hadamard_quantize(jnp.swapaxes(xq_v, 0, 1), signs_b, seed, salt=3)
    g2c, g2s = K.sr_hadamard_quantize(jnp.swapaxes(gf, 0, 1), signs_b, seed, salt=4)
    dw_rot = SR_POSTSCALE * K.mxfp4_matmul(
        x2c, x2s, jnp.swapaxes(g2c, 0, 1), jnp.swapaxes(g2s, 0, 1)
    )
    dw = hadamard_transform(dw_rot * m_w, g=g, axis=0)

    return (
        dx.astype(sent_x.dtype).reshape(*lead, Kdim),
        dw.astype(sent_w.dtype),
        _float0_like(seed),
    )


quartet_linear.defvjp(_quartet_fwd, _quartet_bwd)


# ---------------------------------------------------------------------------
# FP4 all-gather (beyond-paper): ship FSDP weight shards as 4-bit codes
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quest_qdq_gathered(w: jnp.ndarray, cfg: QuartetConfig):
    """H₃₂ → QuEST-quantize → (codes cross the FSDP all-gather as int8 +
    per-32 scales, a 1.78× wire reduction vs bf16; 3.37× with int4 packing)
    → dequantize.  Returns (w_rot_q values [K,N], mask [K,N]).

    The grouped Hadamard and the per-32 scale blocks both live entirely
    inside a K-shard (K/n_data is a multiple of 32 for every config), so the
    quantization is shard-local and the gathered result is bit-identical to
    quantizing the full tensor — the paper's forward, with a cheaper gather.
    The STE/trust backward (g ⊙ M then H⁻¹) rides in the custom VJP.
    """
    out, _ = _qdqg_fwd(w, cfg)
    return out


def _qdqg_fwd(w, cfg: QuartetConfig):
    """w: [K, N] or [E, K, N] (stacked experts; E stays model-sharded)."""
    from repro.distributed.context import current_mesh

    wh = hadamard_transform(w.astype(jnp.float32), g=cfg.group, axis=-2)
    wq = Q.quest(jnp.swapaxes(wh, -2, -1), cfg.fwd_fmt)  # blocks along K
    codes = jnp.swapaxes(wq.codes, -2, -1)  # int8 [..., K, N]
    scales = jnp.swapaxes(wq.scales, -2, -1)  # f32 [..., K/32, N]
    mask = jnp.swapaxes(wq.mask, -2, -1)

    mesh = current_mesh()
    if mesh is not None:
        # force the all-gather to happen on the 4-bit payload (int8 codes +
        # scales), not on dequantized bf16/f32 values
        from jax.sharding import NamedSharding, PartitionSpec as P

        def fits(dim):
            return "model" if dim % mesh.shape["model"] == 0 else None

        if w.ndim == 2:
            spec = P(None, fits(w.shape[1]))
        else:  # [E, K, N]: experts keep their EP sharding, K is gathered
            spec = P(fits(w.shape[0]), None, None)
        rep = NamedSharding(mesh, spec)
        codes = jax.lax.with_sharding_constraint(codes, rep)
        scales = jax.lax.with_sharding_constraint(scales, rep)

    g = cfg.group
    *lead, K, N = codes.shape
    vals = (codes.astype(jnp.float32).reshape(*lead, K // g, g, N)
            * (0.5 * scales)[..., None, :]).reshape(*lead, K, N)
    vals = vals.astype(jnp.bfloat16)  # bf16-exact QDQ values
    return (vals, mask), (mask, jnp.zeros((0,), w.dtype))


def _qdqg_bwd(cfg: QuartetConfig, res, cts):
    mask, sent_w = res
    dvals, _ = cts  # cotangent w.r.t. the rotated-quantized values
    # Reduce-scatter the cotangent to the weight's K-shard BEFORE touching the
    # (shard-local) mask: otherwise GSPMD all-reduces the full f32 cotangent
    # and gathers the bool mask — both the mask ⊙ and H are K-block-local, so
    # they commute with the scatter.
    from repro.distributed.context import current_mesh

    mesh = current_mesh()
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        K = dvals.shape[-2]
        fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        size = 1
        for a in fsdp:
            size *= mesh.shape[a]
        if K % size == 0:
            spec = [None] * dvals.ndim
            spec[-2] = fsdp
            if dvals.ndim == 3 and dvals.shape[0] % mesh.shape["model"] == 0:
                spec[0] = "model"  # stacked experts keep EP sharding
            dvals = jax.lax.with_sharding_constraint(
                dvals, NamedSharding(mesh, P(*spec)))
    dw = hadamard_transform(dvals.astype(jnp.float32) * mask, g=cfg.group, axis=-2)
    return (dw.astype(sent_w.dtype),)


quest_qdq_gathered.defvjp(_qdqg_fwd, _qdqg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def quartet_linear_pq(x, w_vals, w_mask, seed, cfg: QuartetConfig):
    """quartet_linear with a pre-rotated/pre-quantized weight operand
    (from quest_qdq_gathered).  x: [..., K]; w_vals/w_mask: [K, N]."""
    y, _ = _pq_fwd(x, w_vals, w_mask, seed, cfg)
    return y


def _pq_fwd(x, w_vals, w_mask, seed, cfg: QuartetConfig):
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    xh = hadamard_transform(x.astype(jnp.float32), g=cfg.group, axis=-1)
    xq = _fwd_quantize(xh, cfg, key)
    xv = xq.values.astype(jnp.bfloat16)
    y = _gemm(xv, w_vals.astype(jnp.bfloat16), cfg.accum_dtype).astype(x.dtype)
    sent_x = jnp.zeros((0,), x.dtype)
    return y, (xv, w_vals.astype(jnp.bfloat16), xq.mask, seed, sent_x)


def _pq_bwd(cfg: QuartetConfig, res, dy):
    """Algorithm-1 backward via the shared body; dW is returned in the
    rotated-quantized space — the mask ⊙ and H⁻¹ happen in
    quest_qdq_gathered's VJP."""
    xq_v, wq_v, m_x, seed, sent_x = res
    dx, dw_rot = _bwd_rotate_quantize_gemms(cfg, xq_v, wq_v, m_x, seed, dy)
    mask_ct = np.zeros(wq_v.shape, dtype=jax.dtypes.float0)  # bool operand
    return dx.astype(sent_x.dtype), dw_rot, mask_ct, _float0_like(seed)


quartet_linear_pq.defvjp(_pq_fwd, _pq_bwd)


# ---------------------------------------------------------------------------
# Reference forward (pure function, no custom vjp) for oracle tests
# ---------------------------------------------------------------------------


def quartet_forward_reference(x, w, cfg: QuartetConfig = QUARTET_CONFIG):
    """The forward computation only — used by kernel ref tests and PTQ."""
    xh = hadamard_transform(jnp.asarray(x, jnp.float32), g=cfg.group, axis=-1)
    wh = hadamard_transform(jnp.asarray(w, jnp.float32), g=cfg.group, axis=0)
    xq = Q.quest(xh, cfg.fwd_fmt)
    wq = Q.quest(jnp.swapaxes(wh, 0, 1), cfg.fwd_fmt)
    return _gemm(xq.values, jnp.swapaxes(wq.values, 0, 1), cfg.accum_dtype)
