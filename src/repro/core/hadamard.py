"""Grouped (block-diagonal) Hadamard transforms.

Quartet applies the Hadamard transform at the MXFP4 scaling-group size
(g = 32): the forward pass uses the *fixed* transform ``H_g``, the backward
pass the *randomized* transform ``Ĥ_g(x, ξ) = H_g · diag(ξ)`` with Rademacher
signs ξ shared between the two operands of each backward GEMM, which keeps
the GEMM exact under rotation: (x D H)(H D w) = x w  since H·H = I and D² = I.

The normalized Hadamard matrix is symmetric and involutory (H = Hᵀ = H⁻¹),
so "inverse Hadamard" below is the transform itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def hadamard_matrix(g: int) -> np.ndarray:
    """Normalized g×g Hadamard matrix (Sylvester construction), g = 2^k."""
    if g & (g - 1) != 0 or g <= 0:
        raise ValueError(f"group size must be a power of two, got {g}")
    h = np.array([[1.0]])
    while h.shape[0] < g:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(g)).astype(np.float32)


def _hmat(g: int, dtype) -> jnp.ndarray:
    return jnp.asarray(hadamard_matrix(g), dtype=dtype)


def hadamard_transform(x: jnp.ndarray, g: int = 32, axis: int = -1) -> jnp.ndarray:
    """Apply the fixed grouped Hadamard transform along ``axis``.

    The axis length must be divisible by ``g``; each contiguous group of ``g``
    elements is rotated independently (the "Grouped Hadamard Transform" of the
    paper, matching the MXFP4 block size).
    """
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    k = x.shape[-1]
    if k % g != 0:
        raise ValueError(f"axis length {k} not divisible by hadamard group {g}")
    shape = x.shape
    xb = x.reshape(*shape[:-1], k // g, g)
    out = jnp.einsum("...g,gh->...h", xb, _hmat(g, x.dtype)).reshape(shape)
    return jnp.moveaxis(out, -1, axis)


def rademacher_signs(key: jax.Array, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """ξ ∈ {±1}ⁿ. One sign per coordinate of the transformed axis."""
    return jax.random.rademacher(key, (n,), dtype=dtype)


def randomized_hadamard_transform(
    x: jnp.ndarray, signs: jnp.ndarray, g: int = 32, axis: int = -1
) -> jnp.ndarray:
    """Ĥ_g(x, ξ): sign-flip then grouped Hadamard along ``axis``.

    ``signs`` has length equal to ``x.shape[axis]``; using the same signs on
    both GEMM operands preserves the product exactly (before quantization).
    """
    axis = axis % x.ndim
    shape = [1] * x.ndim
    shape[axis] = x.shape[axis]
    x = x * signs.reshape(shape).astype(x.dtype)
    return hadamard_transform(x, g=g, axis=axis)


def inverse_randomized_hadamard_transform(
    x: jnp.ndarray, signs: jnp.ndarray, g: int = 32, axis: int = -1
) -> jnp.ndarray:
    """Ĥ_g⁻¹ = diag(ξ) · H_g  (H is involutory, D² = I)."""
    axis = axis % x.ndim
    x = hadamard_transform(x, g=g, axis=axis)
    shape = [1] * x.ndim
    shape[axis] = x.shape[axis]
    return x * signs.reshape(shape).astype(x.dtype)


def inverse_hadamard_transform(x: jnp.ndarray, g: int = 32, axis: int = -1) -> jnp.ndarray:
    """H_g⁻¹ = H_g."""
    return hadamard_transform(x, g=g, axis=axis)
