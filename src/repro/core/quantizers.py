"""Quantizer zoo: the four forward schemes of Table 2 + backward SR variants.

Every quantizer maps a tensor to a block-scaled low-precision representation
and returns a :class:`QuantResult` carrying

  * ``values``  — dequantized values (scale · grid-point); feeding these to a
                  fp32-accumulating GEMM is *bit-exact* w.r.t. native
                  block-scaled FP4 hardware (E2M1 products fit in ≤4 mantissa
                  bits, E8M0 scales are exact powers of two),
  * ``codes``   — grid indices (int8) for storage-realistic paths,
  * ``scales``  — per-block scales (after the format's scale-dtype rounding),
  * ``mask``    — QuEST clip mask (1 where |x/s| within grid; used as the
                  straight-through "trust" gradient estimator).

Blocks are 1-D along the **last axis** (the GEMM contraction axis), matching
MX semantics; callers move the contraction axis last before quantizing.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core.formats import Format


class QuantResult(NamedTuple):
    values: jnp.ndarray  # same shape/dtype-f32 as input, on-grid × scale
    codes: jnp.ndarray  # int8 grid indices, same shape as input
    scales: jnp.ndarray  # [..., K/block] fp32 (post scale-dtype rounding)
    mask: jnp.ndarray  # bool, same shape as input (True = inside grid)


def _block_scales(x: jnp.ndarray, fmt: Format, kind: str) -> jnp.ndarray:
    """Raw (pre-rounding) per-block scales. kind: 'absmax' | 'rms'."""
    block = fmt.block if fmt.block > 0 else x.shape[-1]
    xb = F.to_blocks(x, block)
    if kind == "absmax":
        raw = jnp.max(jnp.abs(xb), axis=-1) / fmt.max_value
    elif kind == "rms":
        c = F.gaussian_optimal_clip(fmt.name)
        rms = jnp.sqrt(jnp.mean(xb.astype(jnp.float32) ** 2, axis=-1))
        raw = c * rms / fmt.max_value
    else:
        raise ValueError(kind)
    return jnp.maximum(raw, 2.0**F.E8M0_MIN_EXP)


def _codes_from_values(q: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    """"Half-codes": int8 = 2 × grid value (E2M1 → ±{0,1,2,3,4,6,8,12}).

    Dequantization is then ``code * 0.5 * scale`` — pure arithmetic, no table
    gather — which is what the Pallas GEMM kernel does per-tile in VMEM.
    Used for 4-bit grids (E2M1, INT4); wider grids fall back to grid indices.
    """
    if fmt.max_value <= 63.0:  # static per-format property
        return jnp.round(q * 2.0).astype(jnp.int8)
    return jnp.searchsorted(fmt.grid_array, q).astype(jnp.int8)


def _finish(
    x: jnp.ndarray, scales: jnp.ndarray, fmt: Format, q_scaled: jnp.ndarray
) -> QuantResult:
    block = fmt.block if fmt.block > 0 else x.shape[-1]
    values = F.from_blocks(q_scaled * scales[..., None]).astype(jnp.float32)
    codes = F.from_blocks(_codes_from_values(q_scaled, fmt))
    xb = F.to_blocks(jnp.asarray(x, jnp.float32), block)
    mask = F.from_blocks(jnp.abs(xb / scales[..., None]) <= fmt.max_value)
    return QuantResult(values, codes, scales, mask)


# ---------------------------------------------------------------------------
# Forward-pass quantizers (Table 2)
# ---------------------------------------------------------------------------


def rtn_absmax(x: jnp.ndarray, fmt: Format = F.MXFP4, scale_mode: str = "ceil") -> QuantResult:
    """Round-to-nearest with per-block AbsMax scales."""
    block = fmt.block if fmt.block > 0 else x.shape[-1]
    scales = F.quantize_scale(_block_scales(x, fmt, "absmax"), fmt, scale_mode)
    xb = F.to_blocks(jnp.asarray(x, jnp.float32), block)
    if fmt.name in ("mxfp4", "nvfp4"):
        q = F.rtn_e2m1(xb / scales[..., None])  # hardware-exact E2M1 cast
    else:
        q = F.rtn_to_grid(jnp.clip(xb / scales[..., None], -fmt.max_value, fmt.max_value), fmt.grid_array)
    return _finish(x, scales, fmt, q)


def sr_absmax(
    x: jnp.ndarray, key: jax.Array, fmt: Format = F.MXFP4, scale_mode: str = "ceil"
) -> QuantResult:
    """Stochastic rounding with per-block AbsMax scales.

    With ``scale_mode='ceil'`` (power-of-two rounded *up*) no value can exceed
    the grid max, so SR is exactly unbiased: E[Q(x)] = x.
    """
    block = fmt.block if fmt.block > 0 else x.shape[-1]
    scales = F.quantize_scale(_block_scales(x, fmt, "absmax"), fmt, scale_mode)
    xb = F.to_blocks(jnp.asarray(x, jnp.float32), block)
    u = jax.random.uniform(key, xb.shape, jnp.float32)
    q = F.stochastic_round_to_grid(xb / scales[..., None], fmt.grid_array, u)
    return _finish(x, scales, fmt, q)


def sr_absmax_fast(x: jnp.ndarray, seed: jnp.ndarray, fmt: Format = F.MXFP4,
                   scale_mode: str = "ceil", salt: int = 0) -> QuantResult:
    """SR with the fused counter-hash PRNG (no materialized random buffers).

    Used on the training hot path (Quartet backward); numerically an SR with
    a different, still element-decorrelated uniform source — unbiasedness is
    property-tested in tests/test_quantizers.py.
    """
    from repro.core import fastrng

    block = fmt.block if fmt.block > 0 else x.shape[-1]
    scales = F.quantize_scale(_block_scales(x, fmt, "absmax"), fmt, scale_mode)
    xb = F.to_blocks(jnp.asarray(x, jnp.float32), block)
    u = fastrng.uniform(seed, xb.shape, salt)
    q = F.stochastic_round_to_grid(xb / scales[..., None], fmt.grid_array, u)
    return _finish(x, scales, fmt, q)


def quest(x: jnp.ndarray, fmt: Format = F.MXFP4, scale_mode: str = "nearest") -> QuantResult:
    """QuEST [33]: RMSE-optimal (Gaussian-fit) clip scale + RTN + trust mask.

    Callers apply the Hadamard transform first (Gaussianizing each block), so
    the fixed ``c*·rms`` scale is near-MSE-optimal.  Values beyond the clip
    point saturate; the returned mask zeroes their gradient (trust estimator).
    """
    block = fmt.block if fmt.block > 0 else x.shape[-1]
    scales = F.quantize_scale(_block_scales(x, fmt, "rms"), fmt, scale_mode)
    xb = F.to_blocks(jnp.asarray(x, jnp.float32), block)
    scaled = jnp.clip(xb / scales[..., None], -fmt.max_value, fmt.max_value)
    if fmt.name in ("mxfp4", "nvfp4"):
        q = F.rtn_e2m1(scaled)
    else:
        q = F.rtn_to_grid(scaled, fmt.grid_array)
    return _finish(x, scales, fmt, q)


def rtn_absmax_pma(x: jnp.ndarray, fmt: Format = F.MXFP4) -> QuantResult:
    """RTN AbsMax PMA (paper §4.3): pseudo-unbiased RTN.

    Multiplies the dequantized output by a constant ≈ E[S] precomputed for
    Gaussian inputs, cancelling the *average* magnitude shrinkage of RTN. Not
    truly unbiased (S correlates with Q(X)) — reproduced here because Table 2
    / Fig. 2 show it degrading at large D/N exactly for that reason.
    """
    r = rtn_absmax(x, fmt, scale_mode="ceil")
    gamma = pma_gamma(fmt)
    return QuantResult(r.values * gamma, r.codes, r.scales * gamma, r.mask)


import functools


@functools.lru_cache(maxsize=None)
def pma_gamma(fmt: Format) -> float:
    """E[S] for Gaussian blocks under RTN-AbsMax with this format (host-side)."""
    import numpy as np

    rng = np.random.default_rng(0)
    block = fmt.block if fmt.block > 0 else 32
    x = rng.standard_normal((4096, block)).astype(np.float32)
    import jax.numpy as jnp_  # noqa

    r = rtn_absmax(jnp.asarray(x), fmt, scale_mode="ceil")
    q = jax.device_get(r.values)
    num = float((x * x).sum())
    den = float((x * q).sum())
    return num / max(den, 1e-30)


# ---------------------------------------------------------------------------
# Packed MXFP4 persistent-state quantization (serving KV cache)
# ---------------------------------------------------------------------------


class PackedQuant(NamedTuple):
    """Storage-realistic MXFP4 payload: 4.25 bits/element.

    ``codes``  — uint8, two E2M1 nibble codes per byte, [..., K/2]
    ``scales`` — uint8 E8M0 biased-exponent codes, [..., K/block]
    """

    codes: jnp.ndarray
    scales: jnp.ndarray


def kv_quantize(x: jnp.ndarray, fmt: Format = F.MXFP4,
                scale_mode: str = "nearest") -> PackedQuant:
    """Quantize-on-write for persistent state (KV cache pages).

    Same block-scaling rule as :func:`rtn_absmax` (per-block AbsMax → E8M0
    scale → E2M1 RTN) but returns the *packed* storage payload rather than
    dequantized values: nibble codes (2/byte) + uint8 scale exponents.
    The last axis is the block axis; ``x.shape[-1]`` must divide by
    ``fmt.block`` (or equal a smaller power-of-two block, handled by the
    caller via ``dataclasses.replace(fmt, block=...)``).
    """
    block = fmt.block if fmt.block > 0 else x.shape[-1]
    scales = F.quantize_scale(_block_scales(x, fmt, "absmax"), fmt, scale_mode)
    xb = F.to_blocks(jnp.asarray(x, jnp.float32), block)
    q = F.rtn_e2m1(jnp.clip(xb / scales[..., None], -fmt.max_value, fmt.max_value))
    codes = F.pack_nibbles(F.from_blocks(F.e2m1_to_nibble(q)))
    return PackedQuant(codes, F.scale_to_e8m0_code(scales))


def kv_dequantize(pq: PackedQuant, fmt: Format = F.MXFP4,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Dequantize-on-read: packed nibbles × E8M0 block scales → values."""
    vals = F.nibble_to_e2m1(F.unpack_nibbles(pq.codes))
    k = vals.shape[-1]
    block = fmt.block if fmt.block > 0 else k
    scales = F.e8m0_code_to_scale(pq.scales)
    vb = F.to_blocks(vals, block) * scales[..., None]
    return F.from_blocks(vb).astype(dtype)


def state_quantize(x: jnp.ndarray, fmt: Format = F.MXFP4,
                   scale_mode: str = "nearest") -> PackedQuant:
    """Quantize-on-write for FLAT per-slot state (SSM recurrent/conv rings).

    Same packed payload as :func:`kv_quantize`, but for state whose last
    axis is an arbitrary flattened feature count: the axis is zero-padded up
    to the next multiple of ``fmt.block`` first (padding lanes land in their
    own trailing blocks whenever the true extent is block-aligned, and in
    the worst case only dilute the final block's AbsMax downward — they
    never clip real values).  Callers remember the true extent and slice it
    back in :func:`state_dequantize`.
    """
    e = x.shape[-1]
    block = fmt.block if fmt.block > 0 else e
    pad = (-e) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return kv_quantize(x, fmt, scale_mode)


def state_dequantize(pq: PackedQuant, n: int, fmt: Format = F.MXFP4,
                     dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`state_quantize`: dequantize the padded payload and
    slice the last axis back to the true extent ``n``."""
    vals = kv_dequantize(pq, fmt, dtype)
    return vals[..., :n]


# ---------------------------------------------------------------------------
# LSQ (learned step size; used by the method-comparison harness)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _lsq_round(x: jnp.ndarray, step: jnp.ndarray, qmax: float):
    q = jnp.clip(jnp.round(x / step), -qmax, qmax)
    return q * step


def _lsq_fwd(x, step, qmax):
    return _lsq_round(x, step, qmax), (x, step, qmax)


def _lsq_bwd(res, g):
    x, step, qmax = res
    v = x / step
    inside = (jnp.abs(v) <= qmax).astype(g.dtype)
    # LSQ gradient w.r.t. step: (round(v)-v) inside, ±qmax at the clip points
    q = jnp.clip(jnp.round(v), -qmax, qmax)
    dstep = jnp.sum(g * jnp.where(inside > 0, q - v, jnp.sign(v) * qmax))
    grad_scale = 1.0 / jnp.sqrt(qmax * x.size)
    return g * inside, dstep * grad_scale, None


_lsq_round.defvjp(_lsq_fwd, _lsq_bwd)


def lsq(x: jnp.ndarray, step: jnp.ndarray, fmt: Format = F.INT4) -> jnp.ndarray:
    """LSQ [17] with a learnable per-tensor step (uniform grid formats)."""
    qmax = fmt.max_value
    return _lsq_round(x, step, qmax)
