"""Counter-based inline PRNG for stochastic rounding.

``jax.random.uniform`` materializes a u32 buffer per element and lowers large
threefry batches as while loops — for Quartet that meant ~0.5 GB of random
bits per backward GEMM operand held live across the layer scan.  SR needs
*decorrelated*, not cryptographic, randomness; hardware kernels draw it from
a per-element counter hash in registers.  This is the JAX analogue: iota →
murmur3-finalizer hash → 24-bit uniform, fully fused into the consumer
(no buffers, no loops), deterministic in (seed, salt, element index).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _murmur3_fmix(h: jnp.ndarray) -> jnp.ndarray:
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


import jax


def random_bits(seed: jnp.ndarray, shape, salt: int = 0) -> jnp.ndarray:
    """u32 bits, shape ``shape``; seed is a traced uint32 scalar.

    The element index is built from per-dimension ``broadcasted_iota``s (the
    linear index Σ i_d·stride_d), NOT a flat arange+reshape: GSPMD can shard
    broadcasted iotas along any partitioned dim, whereas a rank-1 iota
    reshaped to N-D falls back to full replication (an 8 GB buffer for a
    global-batch dW quantization).
    """
    shape = tuple(shape) if shape else (1,)
    lin = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for d in range(len(shape) - 1, -1, -1):
        lin = lin + jax.lax.broadcasted_iota(jnp.uint32, shape, d) * jnp.uint32(stride % (2**32))
        stride *= shape[d]
    h = lin * jnp.uint32(2654435761)
    h = h + jnp.asarray(seed, jnp.uint32) * jnp.uint32(2246822519)
    h = h + jnp.uint32(salt % (2**32)) * jnp.uint32(3266489917)
    # two fmix rounds: passes basic equidistribution; plenty for SR dither
    h = _murmur3_fmix(h)
    h = _murmur3_fmix(h + jnp.uint32(0x9E3779B9))
    return h


def uniform(seed: jnp.ndarray, shape, salt: int = 0) -> jnp.ndarray:
    """U[0, 1) float32 from the top 24 bits (exactly representable)."""
    bits = random_bits(seed, shape, salt)
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def rademacher(seed: jnp.ndarray, n: int, salt: int = 0) -> jnp.ndarray:
    """±1 f32 signs for the randomized Hadamard transform."""
    bits = random_bits(seed, (n,), salt)
    return jnp.where((bits & 1) == 1, 1.0, -1.0).astype(jnp.float32)
