"""Fully-quantized training baselines compared against Quartet (Table 3).

Each baseline is a linear layer with a custom VJP that performs all three
GEMMs in 4-bit precision, following the original method's recipe adapted to
FP4/INT4 exactly as the paper's §5 does:

* LUQ [11]      — logarithmic unbiased quantization: power-of-two (log-scale)
                  grid, stochastic *underflow* below the minimum normal, and
                  stochastic rounding of the mantissa-free log grid on the
                  backward; RTN log grid forward.
* Jetfire [52]  — per-(32×32) 2-D block AbsMax scaling, RTN everywhere,
                  INT8→FP4 port (the paper's adaptation).
* HALO [3]      — Hadamard rotations on both operands of every GEMM,
                  per-tensor scales (HALO-2), RTN, FP4.
* LSS [50]      — forward: block Hadamard + LSQ INT4; backward: leverage-score
                  sampling of gradient rows into two INT4 GEMMs.

These reproduce the *methods*, so that the benchmark harness can reproduce the
paper's ordering (Quartet < LUQ-INT4 < ... and the instability of HALO/LSS).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import quantizers as Q
from repro.core.hadamard import hadamard_transform
from repro.core.quartet import _float0_like, _gemm


# ---------------------------------------------------------------------------
# LUQ: logarithmic unbiased quantization
# ---------------------------------------------------------------------------

# 4-bit log grid: sign + 3 exponent bits -> {0, 2^-6 .. 2^0} · absmax-scale
_LUQ_EXPS = np.arange(-6, 1, dtype=np.float64)  # 7 normals + 0


def _luq_quantize(x: jnp.ndarray, key: jax.Array | None, stochastic: bool) -> jnp.ndarray:
    """Quantize to the signed log grid with per-tensor absmax scale.

    Stochastic mode (backward): unbiased — log-scale SR between adjacent
    powers of two + stochastic underflow below 2^-6·s.
    """
    x = jnp.asarray(x, jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    a = jnp.abs(x) / s
    sign = jnp.sign(x)
    vmin = 2.0**-6
    if stochastic:
        k1, k2 = jax.random.split(key)
        # stochastic underflow: keep vmin with prob a/vmin, else 0 (unbiased)
        under = a < vmin
        u = jax.random.uniform(k1, x.shape)
        under_val = jnp.where(u < a / vmin, vmin, 0.0)
        # SR between adjacent powers of two: a = 2^e·(1+f) -> up w.p. f
        e = jnp.floor(jnp.log2(jnp.maximum(a, vmin)))
        lo = jnp.exp2(e)
        frac = jnp.clip(a / lo - 1.0, 0.0, 1.0)
        u2 = jax.random.uniform(k2, x.shape)
        norm_val = jnp.where(u2 < frac, 2.0 * lo, lo)
        q = jnp.where(under, under_val, jnp.minimum(norm_val, 1.0))
    else:
        e = jnp.round(jnp.log2(jnp.maximum(a, vmin / 2)))
        q = jnp.where(a < vmin / 2, 0.0, jnp.exp2(jnp.clip(e, -6.0, 0.0)))
    return sign * q * s


# ---------------------------------------------------------------------------
# Jetfire: 2-D (32×32) block AbsMax RTN
# ---------------------------------------------------------------------------


def _block2d_rtn(x: jnp.ndarray, fmt: F.Format, block: int = 32) -> jnp.ndarray:
    """RTN with one AbsMax scale per (block × block) 2-D tile (pad-free path
    requires divisible dims; callers pad)."""
    x = jnp.asarray(x, jnp.float32)
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn)))
    t = xp.reshape((m + pm) // block, block, (n + pn) // block, block)
    s = jnp.maximum(jnp.max(jnp.abs(t), axis=(1, 3), keepdims=True), 1e-30) / fmt.max_value
    q = F.rtn_e2m1(t / s) if fmt.name == "mxfp4" else F.rtn_to_grid(
        jnp.clip(t / s, -fmt.max_value, fmt.max_value), fmt.grid_array)
    return (q * s).reshape(m + pm, n + pn)[:m, :n]


# ---------------------------------------------------------------------------
# HALO-2: per-tensor scale + Hadamard on both operands of every GEMM
# ---------------------------------------------------------------------------


def _halo_quantize(x: jnp.ndarray, fmt: F.Format) -> jnp.ndarray:
    x = jnp.asarray(x, jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / fmt.max_value
    return F.rtn_e2m1(x / s) * s


# ---------------------------------------------------------------------------
# LSS: leverage-score sampled INT4 backward
# ---------------------------------------------------------------------------


def _lss_sample(g: jnp.ndarray, other: jnp.ndarray, key: jax.Array, keep: float = 0.5):
    """Leverage-score row sampling: keep rows of the contraction dim with
    probability ∝ row norm, rescale kept rows by 1/p (unbiased estimator)."""
    norms = jnp.linalg.norm(g, axis=-1) * jnp.linalg.norm(other, axis=-1)
    b = norms.shape[0]
    p = jnp.clip(norms / jnp.maximum(jnp.sum(norms), 1e-30) * (keep * b), 1e-4, 1.0)
    u = jax.random.uniform(key, (b,))
    sel = (u < p).astype(jnp.float32) / p
    return sel


def _int4_rtn(x: jnp.ndarray, block: int = 32) -> jnp.ndarray:
    fmt = F.INT4
    xb = F.to_blocks(jnp.asarray(x, jnp.float32), block)
    s = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True), 1e-30) / fmt.max_value
    return F.from_blocks(jnp.round(jnp.clip(xb / s, -7, 7)) * s)


# ---------------------------------------------------------------------------
# The baseline linear layers (custom VJPs)
# ---------------------------------------------------------------------------


def _flatten_batch(x):
    return x.reshape(-1, x.shape[-1])


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def baseline_linear(x, w, seed, method: str):
    y, _ = _bl_fwd(x, w, seed, method)
    return y


def _bl_fwd(x, w, seed, method: str):
    xf = jnp.asarray(x, jnp.float32)
    wf = jnp.asarray(w, jnp.float32)
    if method == "luq_int4" or method == "luq_fp4":
        fmt = F.INT4 if method.endswith("int4") else F.MXFP4
        if method.endswith("int4"):
            xq, wq = _int4_rtn(xf), _int4_rtn(jnp.swapaxes(wf, 0, 1)).swapaxes(0, 1)
        else:
            xq, wq = _luq_quantize(xf, None, False), _luq_quantize(wf, None, False)
        y = _gemm(xq, wq, jnp.float32)
        return y.astype(x.dtype), (xq, wq, seed)
    if method == "jetfire_fp4":
        xq = _block2d_rtn(_flatten_batch(xf), F.MXFP4).reshape(xf.shape)
        wq = _block2d_rtn(wf, F.MXFP4)
        y = _gemm(xq, wq, jnp.float32)
        return y.astype(x.dtype), (xq, wq, seed)
    if method == "halo_fp4":
        xh = hadamard_transform(xf, g=_halo_group(xf.shape[-1]), axis=-1)
        wh = hadamard_transform(wf, g=_halo_group(wf.shape[0]), axis=0)
        xq, wq = _halo_quantize(xh, F.MXFP4), _halo_quantize(wh, F.MXFP4)
        y = _gemm(xq, wq, jnp.float32)
        return y.astype(x.dtype), (xq, wq, seed)
    if method == "lss_int4":
        xh = hadamard_transform(xf, g=_halo_group(xf.shape[-1]), axis=-1)
        wh = hadamard_transform(wf, g=_halo_group(wf.shape[0]), axis=0)
        xq, wq = _int4_rtn(xh), _int4_rtn(jnp.swapaxes(wh, 0, 1)).swapaxes(0, 1)
        y = _gemm(xq, wq, jnp.float32)
        return y.astype(x.dtype), (xq, wq, seed)
    raise ValueError(f"unknown baseline method {method!r}")


def _halo_group(k: int) -> int:
    g = 1
    while k % (g * 2) == 0 and g < 128:
        g *= 2
    return g


def _bl_bwd(method: str, res, dy):
    xq, wq, seed = res
    dyf = jnp.asarray(dy, jnp.float32)
    key = jax.random.fold_in(jax.random.PRNGKey(0xB5), seed)
    k1, k2, k3 = jax.random.split(key, 3)

    gf = _flatten_batch(dyf)
    xf = _flatten_batch(xq)

    if method in ("luq_int4", "luq_fp4"):
        gq1 = _luq_quantize(dyf, k1, True)
        dx = _gemm(gq1, jnp.swapaxes(wq, 0, 1), jnp.float32)
        gq2 = _luq_quantize(gf, k2, True)
        dw = _gemm(jnp.swapaxes(xf, 0, 1), gq2, jnp.float32)
    elif method == "jetfire_fp4":
        gq = _block2d_rtn(gf, F.MXFP4).reshape(dyf.shape)
        dx = _gemm(gq, jnp.swapaxes(wq, 0, 1), jnp.float32)
        dw = _gemm(jnp.swapaxes(xf, 0, 1), _block2d_rtn(gf, F.MXFP4), jnp.float32)
    elif method == "halo_fp4":
        gN = _halo_group(dyf.shape[-1])
        gh = hadamard_transform(dyf, g=gN, axis=-1)
        wth = hadamard_transform(wq, g=gN, axis=-1)
        dx = _gemm(_halo_quantize(gh, F.MXFP4), jnp.swapaxes(_halo_quantize(wth, F.MXFP4), 0, 1), jnp.float32)
        gB = _halo_group(xf.shape[0])
        g2 = hadamard_transform(gf, g=gB, axis=0)
        x2 = hadamard_transform(xf, g=gB, axis=0)
        dw = _gemm(jnp.swapaxes(_halo_quantize(x2, F.MXFP4), 0, 1), _halo_quantize(g2, F.MXFP4), jnp.float32)
    elif method == "lss_int4":
        gq = _int4_rtn(dyf)
        dx = _gemm(gq, jnp.swapaxes(wq, 0, 1), jnp.float32)
        sel = _lss_sample(gf, xf, k3)  # leverage-score row sampling over B
        dw = _gemm(jnp.swapaxes(_int4_rtn(xf * sel[:, None]), 0, 1), _int4_rtn(gf), jnp.float32)
    else:
        raise ValueError(method)

    return dx.astype(dy.dtype), dw.astype(wq.dtype), _float0_like(seed)


baseline_linear.defvjp(_bl_fwd, _bl_bwd)

BASELINE_METHODS = ("luq_int4", "luq_fp4", "jetfire_fp4", "halo_fp4", "lss_int4")
