"""Gradient-quality metrics: MSE, cosine similarity, and the paper's novel
projection magnitude alignment (PMA, §4.3).

    S(X, ξ) = ⟨X, X⟩ / ⟨Ĥ(X, ξ), RTN(Ĥ(X, ξ))⟩
    PMA misalignment = 1 − E_ξ[1/S]

E[1/S] = 1 means the quantizer preserves magnitudes in expectation (perfectly
"aligned"); SR achieves 0 misalignment, RTN ≈ 9.3e−3, QuEST ≈ 1.3e−2
(Table 2).  We estimate the expectation by Monte-Carlo over ξ.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import quantizers as Q
from repro.core.hadamard import randomized_hadamard_transform


def mse(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((a - b) ** 2)


def relative_mse(x: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((x - q) ** 2) / jnp.maximum(jnp.mean(x**2), 1e-30)


def cosine_similarity(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    num = jnp.vdot(a.ravel(), b.ravel())
    return num / jnp.maximum(jnp.linalg.norm(a) * jnp.linalg.norm(b), 1e-30)


def _quantize_by_name(name: str, x: jnp.ndarray, key: jax.Array, fmt: F.Format) -> jnp.ndarray:
    if name == "rtn_absmax":
        return Q.rtn_absmax(x, fmt).values
    if name == "sr_absmax":
        return Q.sr_absmax(x, key, fmt).values
    if name == "quest":
        return Q.quest(x, fmt).values
    if name == "rtn_absmax_pma":
        return Q.rtn_absmax_pma(x, fmt).values
    raise ValueError(name)


def pma(
    x: jnp.ndarray,
    quantizer: str,
    key: jax.Array,
    fmt: F.Format = F.MXFP4,
    num_samples: int = 64,
    group: int = 32,
) -> jnp.ndarray:
    """Monte-Carlo estimate of E_ξ[1/S] for a quantizer (pre-rotated by Ĥ).

    1/S = ⟨X, X̂⟩ / ⟨X, X⟩ with X̂ = Ĥ⁻¹(Q(Ĥ(X, ξ))) — the magnitude of the
    de-rotated reconstruction projected back onto X.
    """
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    n = x.shape[0]
    xx = jnp.vdot(x, x)

    def one(k):
        k_sign, k_q = jax.random.split(k)
        signs = jax.random.rademacher(k_sign, (n,), dtype=jnp.float32)
        xh = randomized_hadamard_transform(x, signs, g=group, axis=0)
        qh = _quantize_by_name(quantizer, xh, k_q, fmt)
        # ⟨Ĥ(X), Q(Ĥ(X))⟩ == ⟨X, Ĥ⁻¹ Q(Ĥ X)⟩ (orthogonality)
        return jnp.vdot(xh, qh) / xx

    inv_s = jax.vmap(one)(jax.random.split(key, num_samples))
    return jnp.mean(inv_s)


def pma_misalignment(x, quantizer, key, fmt=F.MXFP4, num_samples=64, group=32):
    """1 − E[1/S]; 0 = perfectly magnitude-aligned (unbiased in magnitude)."""
    return 1.0 - pma(x, quantizer, key, fmt, num_samples, group)


def gradient_alignment_by_depth(
    grads_q: list[jnp.ndarray], grads_ref: list[jnp.ndarray]
) -> dict[str, list[float]]:
    """Fig. 2(a,b): per-layer cosine similarity + magnitude ratio of
    inter-layer activation gradients vs the unquantized reference."""
    cos, mag = [], []
    for gq, gr in zip(grads_q, grads_ref):
        cos.append(float(cosine_similarity(gq, gr)))
        mag.append(float(jnp.vdot(gq.ravel(), gr.ravel()) / jnp.maximum(jnp.vdot(gr.ravel(), gr.ravel()), 1e-30)))
    return {"cosine": cos, "magnitude": mag}
