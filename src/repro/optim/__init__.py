"""Optimizers and distributed-optimization tricks.

AdamW with fp32 master weights (the paper's setup, App. A.1), an 8-bit
block-scaled Adam variant (beyond-paper; makes the 235B/480B MoE optimizer
state fit a v5e pod), cosine schedule with warmup, global-norm clipping, and
SR-quantized gradient all-reduce with error feedback.
"""

from repro.optim.adamw import adamw, adamw8bit  # noqa: F401
from repro.optim.schedule import cosine_warmup  # noqa: F401
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
from repro.optim.grad_compress import compress_decompress_gradient  # noqa: F401
