"""Gradient compression for the data-parallel all-reduce (beyond-paper).

Reuses the paper's unbiased-SR machinery on the *communication* axis: each DP
shard stochastically rounds its local gradient to int8 (per-block scales)
before the all-reduce, with local error feedback accumulating the residual.
SR keeps the compressed all-reduce unbiased (QSGD [1], the same citation the
paper uses for its backward-pass argument); error feedback bounds the
variance contribution over steps.

Under GSPMD the all-reduce is implicit (psum of sharded grads), so this is
exposed as a quantize→dequantize transform applied to gradients *inside* the
step function before they cross the DP axis — XLA then moves 1 byte/element
instead of 4 across ICI/DCI.  Enable per-config via ``grad_compress=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_BLOCK = 256


def _sr_int8(x: jnp.ndarray, key: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    b = flat.reshape(-1, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(b), axis=-1, keepdims=True), 1e-30) / 127.0
    v = b / scale
    lo = jnp.floor(v)
    u = jax.random.uniform(key, v.shape)
    q = jnp.clip(jnp.where(u < v - lo, lo + 1.0, lo), -127, 127)
    return q.astype(jnp.int8), scale


def compress_decompress_gradient(g: jnp.ndarray, err: jnp.ndarray, key: jax.Array):
    """One error-feedback SR-int8 round trip.

    Returns (g_hat, new_err): g_hat is the value the DP all-reduce actually
    averages (int8-representable), new_err the residual carried locally.
    """
    gf = g.astype(jnp.float32) + err
    q, scale = _sr_int8(gf, key)
    ghat = (q.astype(jnp.float32) * scale).reshape(-1)[: g.size].reshape(g.shape)
    new_err = gf - ghat
    return ghat.astype(g.dtype), new_err
