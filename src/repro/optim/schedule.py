"""LR schedules: cosine decay with linear warmup (paper: 10% warmup)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(peak_lr: float, total_steps: int, warmup_frac: float = 0.1,
                  final_frac: float = 0.0):
    warmup = max(int(total_steps * warmup_frac), 1)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / warmup
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr
