"""AdamW (fp32 state, the paper's optimizer) and an 8-bit block-scaled
variant (beyond-paper; reuses the repo's block-quantization machinery).

Optax-style interface without the dependency:

    opt = adamw(lr_fn, b1, b2, eps, weight_decay)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / bc1
            nhat = nu / bc2
            u = -lr_t * (mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, mu, nu

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# 8-bit Adam (block-scaled int8 moments; beyond-paper)
# ---------------------------------------------------------------------------

_BLOCK = 256


def _q8(x: jnp.ndarray):
    """Symmetric int8 quantization, blocked along the LAST axis.

    Shape [..., D] → q [..., ceil(D/256), 256] + scales [..., ceil(D/256)].
    Blocking the last axis (instead of a flat reshape) keeps the leading-dim
    shardings intact — a flat reshape of a sharded tensor forces GSPMD into
    full rematerialization (a replicated f32 copy of the whole gradient).
    """
    if x.ndim == 0:
        x = x.reshape(1)
    d = x.shape[-1]
    pad = (-d) % _BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(*x.shape[:-1], (d + pad) // _BLOCK, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1), 1e-30) / 127.0
    q = jnp.round(blocks / scale[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape, size):
    full = (q.astype(jnp.float32) * scale[..., None])
    full = full.reshape(*full.shape[:-2], full.shape[-2] * full.shape[-1])
    d = shape[-1] if shape else 1
    if full.shape[-1] != d:
        full = full[..., :d]
    return full.reshape(shape)


def adamw8bit(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
              weight_decay: float = 0.1) -> Optimizer:
    """AdamW with int8 block-scaled first/second moments (bitsandbytes-style).

    Cuts optimizer-state HBM from 8 to ~2 bytes/param: with bf16 master
    weights this is what lets arctic-480b's state fit 256 v5e chips
    (480e9 × 4 B / 256 ≈ 7.5 GB/chip) — see EXPERIMENTS.md §Dry-run.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def z8(p):
            q, s = _q8(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return {
            "mu": jax.tree.map(z8, params),
            "nu": jax.tree.map(z8, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, mu8, nu8, p):
            g = g.astype(jnp.float32)
            mu = b1 * _dq8(mu8["q"], mu8["s"], g.shape, g.size) + (1 - b1) * g
            nu = b2 * _dq8(nu8["q"], nu8["s"], g.shape, g.size) + (1 - b2) * g * g
            nu = jnp.maximum(nu, 0.0)  # quantization can ring slightly negative
            u = -lr_t * ((mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
                         + weight_decay * p.astype(jnp.float32))
            mq, ms = _q8(mu)
            nq, ns = _q8(nu)
            return u, {"q": mq, "s": ms}, {"q": nq, "s": ns}

        leaf = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params,
                           is_leaf=lambda x: False)
        # out leaves are 3-tuples at param positions
        istup = lambda x: isinstance(x, tuple)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=istup)
        mu = jax.tree.map(lambda o: o[1], out, is_leaf=istup)
        nu = jax.tree.map(lambda o: o[2], out, is_leaf=istup)
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init, update)
