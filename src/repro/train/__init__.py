"""Training & serving runtime: losses, TrainState, step builders, the
training loop with fault tolerance, and the batched serving engine."""

from repro.train.losses import cross_entropy_loss  # noqa: F401
from repro.train.state import TrainState, make_train_state  # noqa: F401
from repro.train.steps import make_eval_step, make_train_step  # noqa: F401
