"""Batched serving engine: prefill + incremental decode with per-family
caches (KV for attention, conv+state for SSM, cross-KV for enc-dec/VLM).

``make_prefill_step`` / ``make_decode_step`` produce jit-able functions used
both by the serving example and by the dry-run's ``prefill_*`` / ``decode_*``
shape cells.  Decode processes ONE new token against a length-``max_len``
cache, exactly as the assigned ``decode_32k`` / ``long_500k`` shapes specify.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.registry import Model


def init_cache(model: Model, batch: int, max_len: int, zeros: bool = True):
    """Materialize (or spec, zeros=False) the decode cache."""
    spec = model.cache_spec(batch, max_len)
    if not zeros:
        return spec
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def _cast_params(params, compute_dtype):
    return jax.tree.map(
        lambda p: p.astype(compute_dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def make_chunk_prefill_step(model: Model, *, method: str = "quartet",
                            build_cross: bool = True) -> Callable:
    """Chunked prefill: process ``tokens [B, C]`` starting at absolute position
    ``start [B]``, writing KV at ``start .. start+C`` — the building block both
    the whole-prompt :func:`make_prefill_step` and the continuous-batching
    engine's per-slot prefill share.  With ``build_cross=True`` (default)
    cross caches (enc-dec) are (re)built on every chunk — idempotent, since
    the source memory is fixed; ``build_cross=False`` skips the encoder and
    attends over an already-populated cross cache instead (the state-pool
    engine writes cross-KV ONCE at admission, so every chunk reads the pool
    rather than re-running the encoder)."""
    cfg = model.cfg
    compute_dtype = jnp.dtype(cfg.dtype)

    def prefill_chunk(params, tokens, start, caches, extra=None, token_valid=None):
        """tokens [B, C], start [B] → (last_logits [B, V], caches, start+C)."""
        cparams = _cast_params(params, compute_dtype)
        B, C = tokens.shape
        positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        logits, caches, _ = model.forward(
            cparams, tokens, jnp.uint32(0), positions=positions, caches=caches,
            cache_index=start, extra=extra, build_cross=build_cross, method=method,
            token_valid=token_valid)
        return logits[:, -1, :], caches, start + C

    return prefill_chunk


def make_prefill_step(model: Model, *, method: str = "quartet") -> Callable:
    chunk = make_chunk_prefill_step(model, method=method)

    def prefill(params, tokens, caches, extra=None):
        """tokens [B, S] → (next_token_logits [B, V], caches, next_pos [B])."""
        B, _ = tokens.shape
        return chunk(params, tokens, jnp.zeros((B,), jnp.int32), caches, extra)

    return prefill


def make_verify_step(model: Model, *, method: str = "quartet") -> Callable:
    """Speculative-decoding verify: score ``tokens [B, S]`` (per slot: the
    last accepted token followed by S-1 drafted tokens) at absolute positions
    ``start .. start+S`` in one call, returning the logits of **every**
    position — ``logits[:, i]`` is the target distribution for the token
    after ``tokens[:, i]``, which the verifier compares against draft i+1
    (and ``logits[:, -1]`` yields the bonus token).  Same contract as
    :func:`make_chunk_prefill_step` except the full ``[B, S, V]`` logits are
    kept instead of only the last column; with a ``PagedKV`` cache the paged
    backend scores all S tokens directly over the packed pool.

    ``positions`` overrides the default ``start + arange(S)`` per-token
    positions — the batched paged prefill (``serve.steps.prefill_all``)
    passes positions where ragged-tail padding tokens are redirected to the
    page table's scratch sentinel column, reusing this step as "verify a
    whole prompt chunk per slot"."""
    import dataclasses

    from repro.models.registry import build_model

    # verify / batched-prefill rows sit at per-slot offsets: causal masks and
    # rope angles must be computed per row, so this step runs on a model built
    # with attn_rows_shared=False (train/prefill keep the row-shared fast path)
    vmodel = build_model(dataclasses.replace(model.cfg, attn_rows_shared=False))
    compute_dtype = jnp.dtype(vmodel.cfg.dtype)

    def verify(params, tokens, start, caches, extra=None, positions=None,
               token_valid=None):
        """tokens [B, S], start [B] → (logits [B, S, V] f32, caches)."""
        cparams = _cast_params(params, compute_dtype)
        B, S = tokens.shape
        if positions is None:
            positions = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        logits, caches, _ = vmodel.forward(
            cparams, tokens, jnp.uint32(0), positions=positions, caches=caches,
            cache_index=start, extra=extra, method=method,
            token_valid=token_valid)
        return logits, caches

    return verify


def make_decode_step(model: Model, *, method: str = "quartet") -> Callable:
    cfg = model.cfg
    compute_dtype = jnp.dtype(cfg.dtype)

    def decode(params, token, position, caches, extra=None, token_valid=None):
        """token [B, 1], position [B] → (logits [B, V], caches, position+1)."""
        cparams = _cast_params(params, compute_dtype)
        positions = position[:, None]
        logits, caches, _ = model.forward(
            cparams, token, jnp.uint32(0), positions=positions, caches=caches,
            cache_index=position, extra=extra, method=method,
            token_valid=token_valid)
        return logits[:, -1, :], caches, position + 1

    return decode


def greedy_generate(model: Model, params, prompt: jnp.ndarray, max_new: int,
                    max_len: int, extra=None, method: str = "quartet",
                    sampling=None):
    """Reference generation loop (prefill → lax.scan of decode steps).

    ``sampling`` is an optional :class:`repro.serve.sampling.SamplingParams`;
    ``None`` (or ``temperature == 0``) keeps the historical greedy-argmax
    path bit-for-bit.  Sampled draws use the stateless per-token keys
    ``sampling.row_key(seed, row, t)`` — the same discipline the serving
    engine uses, so a single-row sampled generate is token-exact against an
    engine request with the same SamplingParams."""
    if max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {max_new}")
    if sampling is not None and not sampling.greedy:
        from repro.serve.sampling import sample_row

        B = prompt.shape[0]

        def pick(logits, t):  # [B, V] → [B, 1] int32, token index t
            rows = jnp.arange(B, dtype=jnp.int32)
            return jax.vmap(
                lambda l, r: sample_row(l, sampling, r, t))(logits, rows)[:, None]
    else:
        def pick(logits, t):
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)

    prefill = make_prefill_step(model, method=method)
    decode = make_decode_step(model, method=method)
    caches = init_cache(model, prompt.shape[0], max_len)
    logits, caches, pos = prefill(params, prompt, caches, extra=extra)
    tok = pick(logits, jnp.int32(0))
    if max_new == 1:
        # the scan below would run 0 steps and return an empty [0, B] ys —
        # the prefill-produced token IS the whole answer
        return tok

    def body(carry, t):
        tok, pos, caches = carry
        logits, caches, pos = decode(params, tok, pos, caches, extra=extra)
        tok = pick(logits, t)
        return (tok, pos, caches), tok[:, 0]

    (_, _, _), toks = jax.lax.scan(
        body, (tok, pos, caches), jnp.arange(1, max_new, dtype=jnp.int32))
    return jnp.concatenate([tok, jnp.moveaxis(toks, 0, 1)], axis=1)
