"""Straggler / health monitoring for long multi-pod runs.

On real fleets the failure modes are: a host slows down (thermals, ECC
retries), a step hangs (network), or throughput decays (input pipeline).
This monitor tracks a step-time EWMA + variance, flags outlier steps, and
exposes hooks the launcher uses to act (log, checkpoint-now, or abort-and-
restart, which with our atomic checkpointing is always safe).

On CPU CI this is exercised by the unit tests with synthetic timings.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class StragglerMonitor:
    ewma_alpha: float = 0.05
    outlier_factor: float = 3.0  # step > factor × ewma → straggler event
    hang_factor: float = 10.0  # step > factor × ewma → treat as hang
    on_straggler: Callable[[int, float, float], None] | None = None
    on_hang: Callable[[int, float, float], None] | None = None

    _ewma: float | None = None
    _last_start: float | None = None
    straggler_steps: int = 0
    hang_steps: int = 0

    def step_start(self):
        self._last_start = time.monotonic()

    def step_end(self, step: int) -> dict:
        dt = time.monotonic() - self._last_start
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> dict:
        """Feed one step duration; returns the current verdict."""
        verdict = {"step": step, "dt": dt, "ewma": self._ewma, "status": "ok"}
        if self._ewma is not None:
            if dt > self.hang_factor * self._ewma:
                self.hang_steps += 1
                verdict["status"] = "hang"
                if self.on_hang:
                    self.on_hang(step, dt, self._ewma)
            elif dt > self.outlier_factor * self._ewma:
                self.straggler_steps += 1
                verdict["status"] = "straggler"
                if self.on_straggler:
                    self.on_straggler(step, dt, self._ewma)
        # outliers don't poison the baseline
        if verdict["status"] == "ok" or self._ewma is None:
            self._ewma = dt if self._ewma is None else (
                (1 - self.ewma_alpha) * self._ewma + self.ewma_alpha * dt)
        verdict["ewma"] = self._ewma
        return verdict
