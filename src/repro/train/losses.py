"""Losses: next-token cross entropy (paper's C4 objective) + z-loss.

``chunked_lm_loss`` applies the LM head + CE per sequence chunk under
``jax.checkpoint``: the full [B, S, V] f32 logits tensor (2.5 GB/device for a
150k vocab at 64k tokens) never materializes — only one [B, c, V] chunk is
live, and the backward recomputes each chunk's logits.  This is the standard
memory-vs-recompute trade for big-vocab training (the recompute is one extra
head GEMM, ~3% of step FLOPs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None, z_loss: float = 0.0):
    """logits [B, S, V] (f32), labels [B, S] int32.  Returns (loss, metrics)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    metrics = {"nll": loss, "tokens": denom}
    if z_loss:
        zl = jnp.sum(lse**2 * mask) / denom
        loss = loss + z_loss * zl
        metrics["z_loss"] = zl
    return loss, metrics


def chunked_lm_loss(head_fn, params, features, labels, seed,
                    mask: jnp.ndarray | None = None, z_loss: float = 0.0,
                    chunk: int = 512, method: str = "quartet"):
    """head_fn(params, x_chunk, seed, method) → logits; features [B, S, D]."""
    B, S, D = features.shape
    c = min(chunk, S)
    while S % c != 0:
        c //= 2
    n = S // c
    xs = jnp.moveaxis(features.reshape(B, n, c, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)
    ms = None if mask is None else jnp.moveaxis(mask.reshape(B, n, c), 1, 0)

    @jax.checkpoint
    def one(xc, lc, mc):
        logits = head_fn(params, xc, seed, method)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        m = jnp.ones_like(lse) if mc is None else mc.astype(jnp.float32)
        nll = jnp.sum((lse - ll) * m)
        zl = jnp.sum(lse**2 * m) if z_loss else jnp.float32(0.0)
        return nll, zl, jnp.sum(m)

    def body(carry, inp):
        xc, lc, mc = inp if ms is not None else (*inp, None)
        nll, zl, cnt = one(xc, lc, mc)
        return (carry[0] + nll, carry[1] + zl, carry[2] + cnt), None

    init = (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0))
    args = (xs, ls, ms) if ms is not None else (xs, ls)
    (nll, zl, cnt), _ = jax.lax.scan(body, init, args)
    denom = jnp.maximum(cnt, 1.0)
    loss = nll / denom
    metrics = {"nll": loss, "tokens": denom}
    if z_loss:
        loss = loss + z_loss * zl / denom
        metrics["z_loss"] = zl / denom
    return loss, metrics
