"""The training loop: checkpoint/restart, straggler monitoring, eval.

``train(...)`` is the single entry point used by the launcher and the
examples.  It is restart-safe by construction: state (params, optimizer,
step) and the data-pipeline position are both recoverable from the latest
checkpoint, so a killed process rerun with the same arguments continues
bit-identically (the per-step RNG seed is the step counter).
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data.pipeline import TokenBatcher
from repro.models.registry import Model
from repro.optim.adamw import Optimizer
from repro.train.state import TrainState, make_train_state
from repro.train.steps import make_eval_step, make_train_step
from repro.train.straggler import StragglerMonitor


def train(
    model: Model,
    optimizer: Optimizer,
    batcher: TokenBatcher,
    total_steps: int,
    *,
    method: str = "quartet",
    master_dtype: str = "float32",
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 500,
    eval_batcher: TokenBatcher | None = None,
    eval_every: int = 0,
    eval_batches: int = 8,
    log_every: int = 10,
    log_fn: Callable = print,
    grad_compress: bool = False,
    microbatch: int = 1,
    extra_batch: dict | None = None,
    seed: int = 0,
) -> tuple[TrainState, list[dict]]:
    params = model.init(jax.random.PRNGKey(seed))
    state = make_train_state(params, optimizer, master_dtype, grad_compress)
    del params

    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        start_step = int(meta["step"])
        log_fn(f"[resume] restored step {start_step} from {checkpoint_dir}")

    step_fn = jax.jit(make_train_step(
        model, optimizer, method=method, grad_compress=grad_compress,
        microbatch=microbatch), donate_argnums=(0,))
    eval_fn = jax.jit(make_eval_step(model, method=method)) if eval_batcher else None

    monitor = StragglerMonitor(
        on_straggler=lambda s, dt, mu: log_fn(
            f"[straggler] step {s}: {dt:.2f}s vs ewma {mu:.2f}s"))
    history = []
    for step in range(start_step, total_steps):
        batch = {k: jnp.asarray(v) for k, v in batcher.batch(step).items()}
        if extra_batch:
            batch.update(extra_batch)
        monitor.step_start()
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        verdict = monitor.step_end(step)
        metrics.update(step=step, dt=verdict["dt"])
        history.append(metrics)
        if log_every and step % log_every == 0:
            log_fn(f"step {step:6d} loss {metrics['loss']:.4f} "
                   f"gnorm {metrics['grad_norm']:.3f} ({verdict['dt']:.2f}s)")
        if eval_fn and eval_every and step and step % eval_every == 0:
            log_fn(f"step {step:6d} eval_loss {evaluate(model, state, eval_batcher, eval_batches, method):.4f}")
        if ckpt and checkpoint_every and (step + 1) % checkpoint_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(total_steps, state, blocking=True)
    return state, history


def evaluate(model: Model, state: TrainState, batcher: TokenBatcher,
             n_batches: int, method: str = "quartet") -> float:
    eval_fn = jax.jit(make_eval_step(model, method=method))
    tot, cnt = 0.0, 0.0
    for i in range(n_batches):
        batch = {k: jnp.asarray(v) for k, v in batcher.batch(10_000_000 + i).items()}
        m = eval_fn(state.params, batch)
        tot += float(m["nll"]) * float(m["tokens"])
        cnt += float(m["tokens"])
    return tot / max(cnt, 1.0)
