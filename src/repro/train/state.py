"""TrainState: master params + optimizer state + step, with the paper's
mixed-precision policy (fp32/bf16 master outside the quantized graph;
MXFP4 only inside the linear layers via quartet_linear)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


class TrainState(NamedTuple):
    params: Any  # master weights (fp32 or bf16 per config)
    opt_state: Any
    step: jnp.ndarray
    err: Any = None  # gradient-compression error feedback (optional)


def make_train_state(params, optimizer: Optimizer, master_dtype: str = "float32",
                     grad_compress: bool = False) -> TrainState:
    master = jax.tree.map(lambda p: p.astype(jnp.dtype(master_dtype))
                          if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
    err = None
    if grad_compress:
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), master)
    return TrainState(master, optimizer.init(master), jnp.zeros((), jnp.int32), err)
