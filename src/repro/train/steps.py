"""Step builders: the jit-able train / eval / serve step functions.

``make_train_step`` wires the full paper pipeline: bf16 compute params cast
from the master, Quartet (or baseline) quantized forward/backward, global-norm
clip, AdamW, optional SR-int8 gradient compression with error feedback.  The
per-step ``seed`` (derived from the step counter) drives every stochastic
quantizer so steps are bit-reproducible given the state.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import Model
from repro.optim.adamw import Optimizer, apply_updates
from repro.optim.clip import clip_by_global_norm
from repro.optim.grad_compress import compress_decompress_gradient
from repro.train.losses import chunked_lm_loss, cross_entropy_loss
from repro.train.state import TrainState


def make_train_step(model: Model, optimizer: Optimizer, *,
                    method: str = "quartet", clip_norm: float = 1.0,
                    aux_weight: float = 0.01, z_loss: float = 0.0,
                    grad_compress: bool = False, loss_chunk: int = 512,
                    microbatch: int = 1) -> Callable:
    """``microbatch`` > 1 splits the global batch into that many sequential
    accumulation steps — activation memory scales down proportionally (the
    standard fit knob for the large train_4k cells)."""
    cfg = model.cfg
    compute_dtype = jnp.dtype(cfg.dtype)

    def loss_fn(params, batch, seed):
        cparams = jax.tree.map(
            lambda p: p.astype(compute_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        extra = {k: v for k, v in batch.items()
                 if k in ("source_embeds", "image_embeds")}
        feats, _, aux = model.forward(cparams, batch["tokens"], seed,
                                      extra=extra or None, method=method,
                                      features_only=True)
        mask = batch.get("loss_mask")
        loss, metrics = chunked_lm_loss(model.head, cparams, feats,
                                        batch["labels"], seed, mask, z_loss,
                                        chunk=loss_chunk, method=method)
        metrics["aux"] = aux
        return loss + aux_weight * aux, metrics

    def grads_of(params, batch, seed):
        if microbatch <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, seed)
        mb = jax.tree.map(
            lambda x: x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:]),
            batch)

        from repro.distributed.context import constrain_params

        def body(carry, mbatch_i):
            acc, loss_acc, i = carry
            (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbatch_i, seed + i)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            # keep the accumulator on the parameter sharding (else GSPMD
            # replicates a full f32 copy of the model per device)
            acc = constrain_params(acc)
            return (acc, loss_acc + loss, i + jnp.uint32(1)), metrics

        zeros = constrain_params(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (gsum, loss_sum, _), ms = jax.lax.scan(
            body, (zeros, jnp.float32(0.0), jnp.uint32(0)), mb)
        grads = jax.tree.map(lambda g: g / microbatch, gsum)
        metrics = jax.tree.map(lambda m: m.mean(), ms)
        return (loss_sum / microbatch, metrics), grads

    def train_step(state: TrainState, batch):
        seed = (state.step.astype(jnp.uint32) + jnp.uint32(1)) * jnp.uint32(microbatch)
        (loss, metrics), grads = grads_of(state.params, batch, seed)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_err = state.err
        if grad_compress and state.err is not None:
            key = jax.random.fold_in(jax.random.PRNGKey(0xC0), seed)
            pairs = jax.tree.map(
                lambda g, e: compress_decompress_gradient(g, e, key),
                grads, state.err)
            istup = lambda x: isinstance(x, tuple)
            grads = jax.tree.map(lambda o: o[0], pairs, is_leaf=istup)
            new_err = jax.tree.map(lambda o: o[1], pairs, is_leaf=istup)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics.update(loss=loss, grad_norm=gnorm)
        return TrainState(params, opt_state, state.step + 1, new_err), metrics

    return train_step


def make_eval_step(model: Model, *, method: str = "quartet") -> Callable:
    cfg = model.cfg
    compute_dtype = jnp.dtype(cfg.dtype)

    def eval_step(params, batch):
        cparams = jax.tree.map(
            lambda p: p.astype(compute_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        extra = {k: v for k, v in batch.items()
                 if k in ("source_embeds", "image_embeds")}
        logits, _, _ = model.forward(cparams, batch["tokens"], jnp.uint32(0),
                                     extra=extra or None, method=method)
        loss, metrics = cross_entropy_loss(logits, batch["labels"],
                                           batch.get("loss_mask"))
        return metrics

    return eval_step
