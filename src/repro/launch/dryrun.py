"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × its shape set) cell this lowers + compiles the real
step function (train_step for train shapes; serve prefill/decode otherwise)
under the production meshes — 16×16 single-pod and 2×16×16 multi-pod — with
512 placeholder host devices, printing memory_analysis() (fits) and feeding
cost_analysis() + the HLO text into the roofline analyzer (§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out reports/dryrun.json
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs, shapes_for  # noqa: E402
from repro.distributed import sharding as SH  # noqa: E402
from repro.distributed.context import activate_mesh  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import adamw, adamw8bit, cosine_warmup  # noqa: E402
from repro.train.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.state import TrainState  # noqa: E402
from repro.train.steps import make_train_step  # noqa: E402

# archs whose fp32 Adam state cannot fit a single v5e pod: bf16 master +
# int8 block-scaled moments (see DESIGN.md / optim.adamw8bit)
BIG_MOE = {"qwen3-moe-235b-a22b", "arctic-480b"}


def pick_microbatch(cfg, shape, n_dp: int) -> int:
    """Gradient-accumulation factor for train shapes: targets ≈6 GB of
    per-device saved-activation stacks (L·T·D·6 B, bf16+f32 copies).

    mb may exceed global_batch/n_dp: when the per-microbatch batch no longer
    shards over DP, activation sharding falls back to sequence parallelism
    (distributed.context.constrain_tokens), so tokens/device keeps shrinking.
    """
    if shape.kind != "train":
        return 1
    import numpy as np

    tokens_per_dev = shape.global_batch * shape.seq_len // n_dp
    per_tok = max(cfg.num_layers * cfg.d_model * 6, 1)
    t_target = max(6e9 / per_tok, 1024)
    want = max(1, int(np.ceil(tokens_per_dev / t_target)))
    mb = 1
    while mb * 2 <= min(want, shape.global_batch) \
            and shape.global_batch % (mb * 2) == 0:
        mb *= 2
    return mb


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_state(model, optimizer, master_dtype):
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(master_dtype))
        if jnp.issubdtype(s.dtype, jnp.floating) else s, params)
    opt = jax.eval_shape(optimizer.init, params)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(params, opt, step, None)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, compile_: bool = True,
               fp4_allgather: bool = False, remat_policy: str = "none",
               mb_override: int = 0):
    """Lower + compile one (arch × shape × mesh) cell; return the report.

    ``fp4_allgather`` / ``remat_policy`` are the §Perf hillclimb knobs (see
    EXPERIMENTS.md §Perf) — defaults are the paper-faithful baseline."""
    import dataclasses
    cfg = get_config(arch)
    if fp4_allgather:
        cfg = dataclasses.replace(
            cfg, quartet=dataclasses.replace(cfg.quartet, fp4_allgather=True))
    if remat_policy != "none":
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    t0 = time.time()

    big = arch in BIG_MOE
    optimizer = (adamw8bit if big else adamw)(cosine_warmup(3e-4, 10000))
    master_dtype = "bfloat16" if big else "float32"

    specs = input_specs(cfg, shape)
    B = shape.global_batch
    # decode inputs are [B, 1] — never sequence-shard them (SP applies to the
    # KV/SSM cache, which cache_partition handles separately)
    bspec = SH.batch_partition(
        mesh, B, shape.seq_len if shape.kind != "decode" else None)
    in_shard = {}
    for k, s in specs.items():
        if k in ("tokens", "labels"):
            in_shard[k] = NamedSharding(mesh, bspec)
        elif k == "position":
            in_shard[k] = NamedSharding(mesh, P(bspec[0]))
        else:  # stub embeddings [B, T, D]
            in_shard[k] = NamedSharding(mesh, P(bspec[0], None, None))

    n_dp = 512 // 16 if multi_pod else 16
    mb = mb_override or pick_microbatch(cfg, shape, n_dp)
    with activate_mesh(mesh):
        if shape.kind == "train":
            state = abstract_state(model, optimizer, master_dtype)
            pspecs = SH.param_partition(state.params, mesh)
            sspecs = SH.partition_state(state, pspecs, mesh)
            step_fn = make_train_step(model, optimizer, microbatch=mb)
            jitted = jax.jit(
                step_fn,
                in_shardings=(_named(mesh, sspecs), in_shard),
                out_shardings=(_named(mesh, sspecs), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, specs)
        else:
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            pspecs = SH.param_partition(params, mesh)
            cache = model.cache_spec(B, shape.seq_len)
            cspecs = SH.cache_partition(cache, mesh, B)
            if shape.kind == "prefill":
                fn = make_prefill_step(model)
                extra_keys = [k for k in specs if k not in ("tokens",)]
                def run(params, tokens, caches, extra):
                    return fn(params, tokens, caches, extra=extra or None)
                extra = {k: specs[k] for k in extra_keys} or None
                extra_shard = {k: in_shard[k] for k in extra_keys} or None
                jitted = jax.jit(run, in_shardings=(
                    _named(mesh, pspecs), in_shard["tokens"],
                    _named(mesh, cspecs), extra_shard))
                lowered = jitted.lower(params, specs["tokens"], cache, extra)
            else:  # decode
                fn = make_decode_step(model)
                def run(params, token, position, caches):
                    return fn(params, token, position, caches)
                jitted = jax.jit(run, in_shardings=(
                    _named(mesh, pspecs), in_shard["tokens"],
                    in_shard["position"], _named(mesh, cspecs)),
                    out_shardings=(None, _named(mesh, cspecs), None),
                    donate_argnums=(3,))
                lowered = jitted.lower(params, specs["tokens"],
                                       specs["position"], cache)

    t_lower = time.time() - t0
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "lower_s": round(t_lower, 2),
        "microbatch": mb,
    }
    if not compile_:
        return report, lowered, None
    t0 = time.time()
    compiled = lowered.compile()
    report["compile_s"] = round(time.time() - t0, 2)

    n_dev = 512 if multi_pod else 256
    mf = RL.model_flops(cfg, shape, include_backward=(shape.kind == "train"))
    analysis = RL.analyze_compiled(compiled, model_flops_per_step=mf,
                                   n_devices=n_dev)
    report.update(analysis)
    ma = compiled.memory_analysis()
    print(f"[{arch} × {shape_name} × {report['mesh']}] "
          f"args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB/device "
          f"| dominant={report['dominant']} "
          f"compute={report['compute_s']*1e3:.2f}ms "
          f"memory={report['memory_s']*1e3:.2f}ms "
          f"collective={report['collective_s']*1e3:.2f}ms")
    return report, lowered, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--fp4-allgather", action="store_true")
    ap.add_argument("--out", default="reports/dryrun.json")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shape_list = shapes_for(cfg) if (args.all or not args.shape) \
            else [SHAPES[args.shape]]
        for sh in shape_list:
            for mp in meshes:
                cells.append((arch, sh.name, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    failures = 0
    for arch, shape_name, mp in cells:
        try:
            report, _, _ = lower_cell(arch, shape_name, mp,
                                      fp4_allgather=args.fp4_allgather)
            report["status"] = "ok"
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            report = {"arch": arch, "shape": shape_name,
                      "mesh": "2x16x16" if mp else "16x16",
                      "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        results.append(report)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, default=str)
    print(f"\n{len(results) - failures}/{len(results)} cells OK -> {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
