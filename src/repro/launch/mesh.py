"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod = 16×16 = 256 chips (v5e pod);
multi-pod = 2×16×16 = 512 chips with a leading "pod" axis (DCI-connected).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType

    def _mk(shape, axes):
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # jax 0.4.x: no AxisType / axis_types kwarg — plain mesh
    def _mk(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(model: int = 1):
    """Smoke-test mesh over whatever devices exist (usually 1 CPU device).

    ``model`` must divide the device count: ``data`` is the cofactor, and a
    non-divisor would build a ``data * model != n`` mesh that ``make_mesh``
    rejects with an opaque reshape error (or, worse, silently drop devices).
    """
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if model > n or n % model != 0:
        raise ValueError(
            f"model={model} does not divide the local device count {n} "
            f"(valid: {[d for d in range(1, n + 1) if n % d == 0]}); "
            f"force more host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return _mk((n // model, model), ("data", "model"))


def make_serve_meshes(tp: int = 1, dp: int = 1):
    """``dp`` single-axis ``('model',)`` meshes of ``tp`` devices each, over
    disjoint contiguous device groups — one mesh per data-parallel engine
    replica.  Serving replicas never communicate across ``data`` (each owns
    its pool, page tables, and scheduler inventory), so they get independent
    meshes rather than one global ``(data, model)`` mesh: a replica's jitted
    steps shard_map over its own ``model`` axis only."""
    if tp < 1 or dp < 1:
        raise ValueError(f"tp and dp must be >= 1, got tp={tp} dp={dp}")
    devices = jax.devices()
    need = tp * dp
    if need > len(devices):
        raise ValueError(
            f"tp={tp} x dp={dp} needs {need} devices, have {len(devices)}; "
            f"force more host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    import numpy as np

    return [Mesh(np.asarray(devices[r * tp:(r + 1) * tp]), ("model",))
            for r in range(dp)]
