"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod = 16×16 = 256 chips (v5e pod);
multi-pod = 2×16×16 = 512 chips with a leading "pod" axis (DCI-connected).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType

    def _mk(shape, axes):
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # jax 0.4.x: no AxisType / axis_types kwarg — plain mesh
    def _mk(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(model: int = 1):
    """Smoke-test mesh over whatever devices exist (usually 1 CPU device)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return _mk((data, model), ("data", "model"))
