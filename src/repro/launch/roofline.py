"""Roofline analysis from compiled HLO (§Roofline of EXPERIMENTS.md).

``compiled.cost_analysis()`` does NOT multiply ``lax.scan``/while bodies by
their trip count (verified empirically — a 94-layer scan reports 1 layer of
FLOPs), so this module parses ``compiled.as_text()`` directly:

  1. split the module into computations, building a per-computation symbol
     table (op name → output shape) including parameters,
  2. cost each op: dot/convolution FLOPs from operand/output shapes,
     collective bytes by kind (all-gather/all-reduce/reduce-scatter/
     all-to-all/collective-permute), HBM-traffic proxy = Σ output bytes of
     non-trivial ops,
  3. walk the call graph from ENTRY multiplying by each while op's
     ``known_trip_count`` (fusions/calls ×1, conditional branches ×1),
  4. emit the three roofline terms with the v5e constants.

The SPMD-partitioned module is already per-device, so all numbers are
per-chip.  The memory term is a *proxy* (fusion-boundary traffic on the CPU
backend differs from TPU); it is used for relative §Perf iteration deltas
alongside the analytic weights+activations estimate.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

# v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (3D-torus links not aggregated: conservative)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e8m0fnu": 1,
    "s8": 1, "u8": 1, "pred": 1,
    "f4e2m1fn": 0.5, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_TRIVIAL_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "after-all", "partition-id", "replica-id", "iota", "bitcast-convert",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type string (sums tuple components)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    calls: list = dataclasses.field(default_factory=list)  # (comp_name, count)


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\]))")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def parse_hlo(text: str) -> tuple[dict[str, CompCost], str]:
    """Returns ({computation: CompCost}, entry_name)."""
    comps: dict[str, CompCost] = {}
    entry = None
    cur: CompCost | None = None
    cur_name = None
    symbols: dict[str, str] = {}

    for line in text.splitlines():
        # computation headers are column-0 lines ending with "{"
        is_hdr_line = line and not line[0].isspace() and line.rstrip().endswith("{") \
            and not line.startswith("HloModule")
        if is_hdr_line:
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr is None:  # fallback: extract the name only
                m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line.strip())
                if m is None:
                    continue
                groups = (m.group(1), m.group(2), "", "")
            else:
                groups = hdr.groups()
            cur_name = groups[1]
            cur = CompCost()
            comps[cur_name] = cur
            symbols = {}
            if groups[0]:
                entry = cur_name
            for pname, ptype in _PARAM_RE.findall(line):
                symbols[pname] = ptype
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, out_type, op, rest = m.groups()
        symbols[name] = out_type

        if op in _TRIVIAL_OPS:
            continue

        out_bytes = _shape_bytes(out_type)

        if op == "while":
            trip = 1
            tm = _TRIP_RE.search(s)
            if tm:
                trip = int(tm.group(1))
            bm, cm = _BODY_RE.search(s), _COND_RE.search(s)
            if bm:
                cur.calls.append((bm.group(1), trip))
            if cm:
                cur.calls.append((cm.group(1), trip + 1))
            continue
        if op == "conditional":
            br = _BRANCHES_RE.search(s)
            if br:
                for b in _OPERAND_RE.findall(br.group(1)):
                    cur.calls.append((b, 1))
            continue
        if op in ("fusion", "call", "async-start", "map", "reduce", "sort",
                  "reduce-window", "scatter", "select-and-scatter", "custom-call"):
            cm2 = _CALLS_RE.search(s)
            if cm2:
                cur.calls.append((cm2.group(1), 1))
            cur.mem_bytes += out_bytes
            # fall through: reduces etc. count their output traffic

        if op in _COLLECTIVES:
            # bytes moved ≈ max(input, output) payload of the collective
            opnds = _OPERAND_RE.findall(rest.split(",  ")[0])
            in_bytes = sum(_shape_bytes(symbols.get(o, "")) for o in opnds
                           if o in symbols)
            cur.coll_bytes[op] += max(out_bytes, in_bytes)
            continue

        if op in ("dot", "convolution"):
            opnds = _OPERAND_RE.findall(rest)
            lhs = symbols.get(opnds[0], "") if opnds else ""
            lhs_dims = _shape_dims(lhs)
            out_dims = _shape_dims(out_type)
            contract = 1
            cmatch = _CONTRACT_RE.search(s)
            if cmatch and lhs_dims:
                for ci in (cmatch.group(1).split(",") if cmatch.group(1) else []):
                    contract *= lhs_dims[int(ci)]
            n_out = 1
            for d in out_dims:
                n_out *= d
            cur.flops += 2.0 * n_out * max(contract, 1)
            cur.mem_bytes += out_bytes
            continue

        if op not in ("fusion", "call"):
            cur.mem_bytes += out_bytes

    return comps, entry or "main"


def aggregate(comps: dict[str, CompCost], entry: str) -> dict:
    """Walk the call graph multiplying by call counts."""
    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        for callee, count in comps[name].calls:
            visit(callee, m * count, depth + 1)

    visit(entry, 1.0)

    flops = sum(comps[c].flops * m for c, m in mult.items() if c in comps)
    mem = sum(comps[c].mem_bytes * m for c, m in mult.items() if c in comps)
    coll = defaultdict(float)
    for c, m in mult.items():
        if c in comps:
            for kind, b in comps[c].coll_bytes.items():
                coll[kind] += b * m
    return {"flops": flops, "mem_bytes": mem, "collective_bytes": dict(coll),
            "total_collective_bytes": sum(coll.values())}


def roofline_terms(agg: dict) -> dict:
    """The three §Roofline terms, in seconds (per device, per step)."""
    compute = agg["flops"] / PEAK_FLOPS_BF16
    memory = agg["mem_bytes"] / HBM_BW
    collective = agg["total_collective_bytes"] / ICI_BW
    dominant = max(
        (("compute", compute), ("memory", memory), ("collective", collective)),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
    }


def analyze_compiled(compiled, model_flops_per_step: float | None = None,
                     n_devices: int = 256) -> dict:
    """Full analysis of a compiled executable."""
    comps, entry = parse_hlo(compiled.as_text())
    agg = aggregate(comps, entry)
    out = {**agg, **roofline_terms(agg)}
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    out["xla_cost_flops_unscaled"] = ca.get("flops", 0.0)
    ma = compiled.memory_analysis()
    out["bytes_per_device"] = {
        "arguments": getattr(ma, "argument_size_in_bytes", 0),
        "outputs": getattr(ma, "output_size_in_bytes", 0),
        "temp": getattr(ma, "temp_size_in_bytes", 0),
        "alias": getattr(ma, "alias_size_in_bytes", 0),
    }
    if model_flops_per_step:
        total_hlo = agg["flops"] * n_devices
        out["model_flops"] = model_flops_per_step
        out["useful_fraction"] = model_flops_per_step / max(total_hlo, 1.0)
    return out


def model_flops(cfg, shape, include_backward: bool) -> float:
    """Analytic MODEL_FLOPS: 6·N·D (train) or 2·N·D (forward), N = active."""
    n = cfg.n_active_params() if cfg.family == "moe" else cfg.n_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    per_tok = 6 * n if include_backward else 2 * n
    return float(per_tok) * tokens


def save_report(path: str, report: dict):
    with open(path, "w") as f:
        json.dump(report, f, indent=2, default=str)
