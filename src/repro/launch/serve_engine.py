"""Continuous-batching serving launcher: Poisson arrival workload.

``python -m repro.launch.serve_engine --arch qwen3-1.7b --reduced --requests 12
--rate 4 --kv mxfp4`` samples request arrival times from a Poisson process
(exponential inter-arrival gaps), prompt lengths uniformly from
``[--min-prompt, --max-prompt]``, and drives the engine on a virtual clock:
each ``Engine.step`` advances time by its measured wall duration, and
requests are submitted the moment the clock passes their arrival time —
so queueing behaviour is faithful even though steps are synchronous.

``--spec {self,ngram,draft} --spec-k 4`` turns on speculative decoding
(paged families; ``--draft-arch`` selects the draft model for the ``draft``
proposer) and reports tokens-per-verify-call and draft acceptance;
``--temperature/--top-k/--top-p/--sample-seed`` enable per-request sampling.

Observability: ``--metrics-out m.jsonl`` streams registry snapshots as
JSON-lines, ``--trace-out t.jsonl`` writes one line per retired request
(spans + derived TTFT/TPOT), ``--quant-stride N`` samples the MXFP4 pool's
clip/scale health every N ticks, and the run ends with the telemetry
summary table (see ``serve/README.md#observability``).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.distributed.context import activate_mesh
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, SamplingParams, SpecConfig,
                         TelemetryConfig)
from repro.serve.spec import aggregate_stats


def make_extra(cfg, key, batch: int = 1):
    if cfg.family == "encdec":
        return {"source_embeds": jax.random.normal(
            key, (batch, cfg.max_source_len, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"image_embeds": jax.random.normal(
            key, (batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)}
    return None


def poisson_workload(rng: np.random.Generator, n: int, rate: float,
                     min_prompt: int, max_prompt: int, max_new: int, vocab: int):
    """[(arrival_time, prompt, max_new)] with exponential inter-arrival gaps."""
    t, out = 0.0, []
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        out.append((t, rng.integers(0, vocab, plen).astype(np.int32), max_new))
    return out


def run_workload(engine: Engine, workload, extra=None, verbose: bool = True,
                 sampling=None):
    """Drive the engine on a virtual clock; returns (requests, elapsed)."""
    pending = list(workload)
    clock, t0 = 0.0, time.perf_counter()
    while pending or engine.sched.pending:
        while pending and pending[0][0] <= clock:
            at, prompt, max_new = pending.pop(0)
            engine.submit(prompt, max_new, extra=extra, arrival_time=at,
                          sampling=sampling)
        if not engine.sched.pending:  # idle gap: jump to the next arrival
            clock = pending[0][0]
            continue
        s0 = time.perf_counter()
        info = engine.step(now=clock)
        clock += time.perf_counter() - s0
        if verbose and info["step"] % 20 == 0:
            print(f"  step {info['step']:4d} t={clock:7.2f}s queued={info['queued']} "
                  f"prefill={info['prefilling']} decode={info['decoding']}")
    return engine.completed, time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0, help="arrivals per second")
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--kv", default="mxfp4", choices=["mxfp4", "dense"])
    ap.add_argument("--decode-backend", default=None,
                    choices=["paged", "gather", "statepool", "dense_slots"],
                    help="paged families: fused paged-attention kernel "
                         "(default) vs gather-dequantize oracle; state "
                         "families (ssm/hybrid/encdec/vlm): unified packed "
                         "state pool (default) vs dense-slot oracle")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged families: radix prefix cache — admissions "
                         "alias pages of previously-served shared prefixes "
                         "(copy-on-write, LRU-evicted) and prefill only the "
                         "unshared tail; token-exact vs the non-sharing "
                         "engine")
    ap.add_argument("--debug-cache", action="store_true",
                    help="run the PagedCache invariant checker after every "
                         "pool mutation (slow; refcount/conservation audit)")
    ap.add_argument("--method", default="quartet")
    ap.add_argument("--seed", type=int, default=0)
    # speculative decoding (paged families)
    ap.add_argument("--spec", default=None,
                    choices=["self", "ngram", "draft"],
                    help="enable speculative decoding with this proposer")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify call")
    ap.add_argument("--ngram", type=int, default=2,
                    help="ngram proposer: suffix length to match")
    ap.add_argument("--draft-arch", default=None,
                    help="draft proposer: registry arch of the draft model")
    # per-request sampling (greedy argmax when temperature is 0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--sample-seed", type=int, default=0)
    # observability (repro.serve.telemetry)
    ap.add_argument("--metrics-out", default=None,
                    help="stream registry snapshots as JSON-lines here")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request span traces as JSON-lines here")
    ap.add_argument("--quant-stride", type=int, default=0,
                    help="sample MXFP4 pool clip/scale health every N ticks "
                         "(0 = off)")
    ap.add_argument("--profile-out", default=None,
                    help="profile the run: per-phase roofline/bandwidth "
                         "gauges + a Chrome trace-event JSON (tick-phase "
                         "spans, request lifecycles, jit-compile events) "
                         "written here — open in Perfetto/chrome://tracing")
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced else get_config(args.arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    rng = np.random.default_rng(args.seed)
    workload = poisson_workload(rng, args.requests, args.rate, args.min_prompt,
                                args.max_prompt, args.max_new, cfg.vocab_size)

    spec = None
    if args.spec is not None:
        spec = SpecConfig(k=args.spec_k, proposer=args.spec, ngram=args.ngram,
                          draft_arch=args.draft_arch)
    sampling = None
    if args.temperature > 0:
        sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                                  top_p=args.top_p, seed=args.sample_seed)
    elif args.top_k or args.top_p < 1.0 or args.sample_seed:
        ap.error("--top-k/--top-p/--sample-seed require --temperature > 0 "
                 "(temperature 0 is greedy argmax and ignores them)")

    telemetry = TelemetryConfig(metrics_path=args.metrics_out,
                                trace_path=args.trace_out,
                                quant_stride=args.quant_stride,
                                profile_trace_path=args.profile_out)
    with activate_mesh(make_local_mesh()):
        engine = Engine(model, params, EngineConfig(
            n_slots=args.slots, max_len=args.max_len, page_size=args.page_size,
            kv_dtype=args.kv, prefill_chunk=args.prefill_chunk, method=args.method,
            decode_backend=args.decode_backend, prefix_cache=args.prefix_cache,
            debug_cache=args.debug_cache, spec=spec, telemetry=telemetry))
        done, elapsed = run_workload(engine, workload, extra=make_extra(cfg, key),
                                     sampling=sampling)

    # final telemetry summary table (the registry + tracer collected every
    # number the old hand-rolled prints derived from request objects)
    total_tokens = sum(len(r.tokens) for r in done)
    engine.telemetry.finalize()
    print(f"\n{cfg.name} [{cfg.family}] "
          f"kv={'dense-slots' if engine.backend == 'dense_slots' else args.kv}"
          f" decode={engine.decode_backend} slots={args.slots}"
          + (f" spec={args.spec}(k={args.spec_k})" if spec else ""))
    print(f"  {len(done)} requests, {total_tokens} tokens in {elapsed:.2f}s wall "
          f"→ {total_tokens / elapsed:.1f} tok/s")
    print(engine.telemetry.summary())
    if spec is not None:
        agg = aggregate_stats(done)
        print(f"  spec: {agg['tokens_per_decode_call']} tok/verify-call, "
              f"acceptance {agg['acceptance_rate']} "
              f"({agg['drafts_accepted']}/{agg['drafts_proposed']} drafts)")
    for label, path in (("metrics", args.metrics_out), ("traces", args.trace_out),
                        ("profile trace", args.profile_out)):
        if path:
            print(f"  {label} → {path}")


if __name__ == "__main__":
    main()
