"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real multi-host TPU fleet this process runs per host (jax.distributed
initializes from the cluster env); in this container it runs single-process
on CPU with reduced configs.  Restart the same command after a failure and it
resumes from the latest atomic checkpoint.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_reduced_config
from repro.configs.llama_paper import LEARNING_RATES
from repro.data.pipeline import TokenBatcher, make_dataset
from repro.distributed.context import activate_mesh
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model
from repro.optim import adamw, adamw8bit, cosine_warmup
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--method", default="quartet",
                    help="quartet | bf16 | luq_int4 | jetfire_fp4 | halo_fp4 | lss_int4")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--opt8bit", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 production mesh (requires devices)")
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = build_model(cfg)
    lr = args.lr or LEARNING_RATES.get(args.arch, 3e-4)
    opt = (adamw8bit if args.opt8bit else adamw)(
        cosine_warmup(lr, args.steps))
    ds = make_dataset(args.data, cfg.vocab_size)
    batcher = TokenBatcher(ds, args.batch, args.seq,
                           host_index=jax.process_index(),
                           host_count=jax.process_count())

    mesh = (make_production_mesh() if args.production_mesh else make_local_mesh())
    with activate_mesh(mesh):
        state, history = train(
            model, opt, batcher, args.steps, method=args.method,
            master_dtype="bfloat16" if args.opt8bit else "float32",
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
            grad_compress=args.grad_compress, microbatch=args.microbatch)
    print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
