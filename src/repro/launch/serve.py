"""Serving launcher: batched prefill + decode with per-family caches.

``python -m repro.launch.serve --arch <id> --reduced --batch 4 --prompt-len 32``
runs a greedy generation round-trip (the dry-run exercises the production
shapes; this entry point proves the engine end-to-end on real arrays).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced_config
from repro.distributed.context import activate_mesh
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.train.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--method", default="quartet")
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    extra = None
    if cfg.family == "encdec":
        extra = {"source_embeds": jax.random.normal(
            key, (args.batch, cfg.max_source_len, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        extra = {"image_embeds": jax.random.normal(
            key, (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)}

    with activate_mesh(make_local_mesh()):
        t0 = time.time()
        out = greedy_generate(model, params, prompt,
                              max_new=args.max_new,
                              max_len=args.prompt_len + args.max_new,
                              extra=extra, method=args.method)
        out.block_until_ready()
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(out[:2])


if __name__ == "__main__":
    main()
