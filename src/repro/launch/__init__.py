"""Launchers: production mesh construction, the multi-pod dry-run, the
roofline analyzer, and the train/serve entry points."""
