"""Pallas kernels for the serving engine's FP4 KV-cache pages.

Quantize-on-write / dequantize-on-read for persistent decode state: one
VMEM-resident pass per KV page fuses

  1. per-32-group AbsMax scale computation,
  2. E8M0 (nearest power-of-two) scale rounding → biased-exponent uint8,
  3. E2M1 round-to-nearest downcast (arithmetic ties-to-even — lowers inside
     the kernel body with no gathers),
  4. nibble packing: two 4-bit codes per byte (S EE M bit layout),

writing the *real* 4.25-bit payload (codes + scale exponents) back to HBM.
The unpack kernel inverts arithmetically: magnitude = 2^((i-2)>>1)·(1+m/2)
for normal codes, i/2 for the subnormal region — no table gathers, so both
bodies map onto the VPU.  Semantics are verified against the jnp reference
pair ``core.quantizers.kv_quantize`` / ``kv_dequantize`` in
tests/test_serve_engine.py (bit-identical payloads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import formats as F

GROUP = 32
_E2M1_MAX = 6.0
# magnitude index of ±6.0 — a quantized element sitting on this code was at
# (or clipped to) the top of the E2M1 grid; the fraction of such codes in the
# pool is the FP4 saturation / clip-rate gauge (telemetry.quant_health)
E2M1_SAT_IDX = 7


def _exp2i(e: jnp.ndarray) -> jnp.ndarray:
    bits = (e.astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def split_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    """Packed bytes [..., K/2] u8 → nibble codes [..., K] u8 (high nibble
    first — the pack order of ``_kv_quant_pack_kernel``).  Shared by
    :func:`unpack_dequant` and the pool-health reductions
    (``serve.telemetry.quant_health``), which inspect codes without
    dequantizing."""
    *lead, kh = packed.shape
    return jnp.stack([(packed >> 4) & 0xF, packed & 0xF],
                     axis=-1).reshape(*lead, kh * 2)


def unpack_dequant(packed: jnp.ndarray, scale_codes: jnp.ndarray,
                   block: int = GROUP) -> jnp.ndarray:
    """Packed nibble codes [..., K/2] u8 + E8M0 scale codes [..., K/block] u8
    → f32 values [..., K].  Pure arithmetic (no table gathers) so it lowers
    on the VPU — shared by the unpack kernel below and the fused paged-
    attention kernel (``kernels/paged_attention.py``), which calls it per
    VMEM-resident KV tile."""
    *lead, kh = packed.shape
    k = kh * 2
    nib = split_nibbles(packed)
    idx = (nib & 7).astype(jnp.float32)
    mag_norm = _exp2i(jnp.floor((idx - 2.0) / 2.0)) * (1.0 + 0.5 * (idx % 2.0))
    mag = jnp.where(idx >= 2.0, mag_norm, idx * 0.5)
    val = jnp.where((nib & 8) > 0, -mag, mag)
    scale = _exp2i(scale_codes.astype(jnp.float32) - 127.0)
    return (val.reshape(*lead, k // block, block)
            * scale[..., None]).reshape(*lead, k)


def _kv_quant_pack_kernel(x_ref, codes_ref, scales_ref):
    """One [bm, bk] tile → packed nibbles [bm, bk/2] + E8M0 codes [bm, bk/32]."""
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    ng = bk // GROUP

    # (1) AbsMax per 32-group, (2) nearest power-of-two exponent
    amax = jnp.max(jnp.abs(x.reshape(bm, ng, GROUP)), axis=-1)
    e = jnp.round(jnp.log2(jnp.maximum(amax / _E2M1_MAX, 2.0**-126)))
    e = jnp.clip(e, -126.0, 127.0)
    scale = _exp2i(e)

    # (3) E2M1 RTN (saturating, ties-to-even)
    v = x.reshape(bm, ng, GROUP) / scale[..., None]
    q = F.rtn_e2m1(jnp.clip(v, -_E2M1_MAX, _E2M1_MAX))

    # (4) arithmetic nibble encode + pack pairs into bytes
    nib = F.e2m1_to_nibble(q).reshape(bm, bk // 2, 2)
    codes_ref[...] = (nib[..., 0] << 4) | (nib[..., 1] & 0xF)
    scales_ref[...] = (e + 127.0).astype(jnp.uint8)


def _kv_dequant_unpack_kernel(codes_ref, scales_ref, o_ref):
    """Packed [bm, bk/2] + scale codes [bm, bk/32] → f32 values [bm, bk]."""
    o_ref[...] = unpack_dequant(codes_ref[...], scales_ref[...])


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def kv_quant_pack(
    x: jnp.ndarray,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool = True,
):
    """x: [M, K] → (packed codes uint8 [M, K/2], E8M0 scale codes uint8 [M, K/32])."""
    m, k = x.shape
    if k % GROUP != 0:
        raise ValueError(f"K={k} not divisible by group {GROUP}")
    bk = min(block_k, k)
    while k % bk != 0:
        bk -= GROUP
    bm = min(block_m, m)
    grid_m = pl.cdiv(m, bm)
    pad_m = grid_m * bm - m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))

    codes, scales = pl.pallas_call(
        _kv_quant_pack_kernel,
        grid=(grid_m, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk // 2), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_m * bm, k // 2), jnp.uint8),
            jax.ShapeDtypeStruct((grid_m * bm, k // GROUP), jnp.uint8),
        ],
        interpret=interpret,
    )(x)
    if pad_m:
        codes, scales = codes[:m], scales[:m]
    return codes, scales


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def kv_dequant_unpack(
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """(packed codes [M, K/2], scale codes [M, K/32]) → f32 values [M, K]."""
    m, kh = codes.shape
    k = kh * 2
    assert scales.shape == (m, k // GROUP), (codes.shape, scales.shape)
    bk = min(block_k, k)
    while k % bk != 0:
        bk -= GROUP
    bm = min(block_m, m)
    grid_m = pl.cdiv(m, bm)
    pad_m = grid_m * bm - m
    if pad_m:
        codes = jnp.pad(codes, ((0, pad_m), (0, 0)))
        scales = jnp.pad(scales, ((0, pad_m), (0, 0)))

    out = pl.pallas_call(
        _kv_dequant_unpack_kernel,
        grid=(grid_m, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk // 2), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid_m * bm, k), jnp.float32),
        interpret=interpret,
    )(codes, scales)
    return out[:m] if pad_m else out
