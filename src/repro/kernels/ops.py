"""Jit'd public wrappers around the Pallas kernels.

These present a shape-flexible API (leading batch dims, transposed weights)
over the 2-D tiled kernels and centralize the interpret-mode switch:
``repro.kernels.ops.INTERPRET`` is True on CPU (kernel bodies execute in the
Pallas interpreter for correctness validation) and False on real TPUs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hadamard_quant import hadamard_quest_quantize as _hq_fn
from repro.kernels.kv_pack import kv_dequant_unpack as _kvd_fn
from repro.kernels.kv_pack import kv_quant_pack as _kvq_fn
from repro.kernels.mxfp4_matmul import mxfp4_matmul as _mm_fn
from repro.kernels.sr_hadamard_quant import sr_hadamard_quantize as _sr_fn

INTERPRET = jax.default_backend() != "tpu"

GROUP = 32


def _as2d(x: jnp.ndarray):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def hadamard_quest_quantize(x: jnp.ndarray, group: int = GROUP):
    """[..., K] → (codes [...,K] int8, scales [...,K/32] f32, mask [...,K] bool)."""
    assert group == GROUP, "kernels are specialized to the MXFP4 group of 32"
    x2, lead = _as2d(x)
    codes, scales, mask = _hq_fn(x2, interpret=INTERPRET)
    return (
        codes.reshape(*lead, -1),
        scales.reshape(*lead, -1),
        mask.reshape(*lead, -1),
    )


def sr_hadamard_quantize(
    x: jnp.ndarray, signs: jnp.ndarray, seed: jnp.ndarray,
    prescale: float = 0.75, salt: int = 0,
):
    """[..., K] → (codes, scales); randomness from the fused counter-hash
    PRNG (core/fastrng.py) — no materialized random buffers.  On real TPU
    hardware the same hash runs in-kernel from ``pltpu`` iota."""
    from repro.core import fastrng

    x2, lead = _as2d(x)
    u = fastrng.uniform(seed, x2.shape, salt)
    codes, scales = _sr_fn(x2, signs, u, prescale=prescale, interpret=INTERPRET)
    return codes.reshape(*lead, -1), scales.reshape(*lead, -1)


def kv_quant_pack(x: jnp.ndarray):
    """[..., K] → (packed codes uint8 [..., K/2], E8M0 scale codes [..., K/32]).

    The serving PagedCache's quantize-on-write primitive (4.25 bits/element);
    bit-identical to ``core.quantizers.kv_quantize``."""
    x2, lead = _as2d(x)
    codes, scales = _kvq_fn(x2, interpret=INTERPRET)
    return codes.reshape(*lead, -1), scales.reshape(*lead, -1)


def kv_dequant_unpack(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """(packed codes [..., K/2], scale codes [..., K/32]) → f32 values [..., K]."""
    c2, lead = _as2d(codes)
    s2, _ = _as2d(scales)
    out = _kvd_fn(c2, s2, interpret=INTERPRET)
    return out.reshape(*lead, -1)


def mxfp4_matmul(a_codes, a_scales, b_codes, b_scales) -> jnp.ndarray:
    """[..., K] codes × [K, N] codes → f32 [..., N] (scales along K)."""
    a2, lead = _as2d(a_codes)
    s2, _ = _as2d(a_scales)
    out = _mm_fn(a2, s2, b_codes, b_scales, interpret=INTERPRET)
    return out.reshape(*lead, -1)
