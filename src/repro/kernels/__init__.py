"""Pallas TPU kernels for Quartet's two compute hot-spots (§4.4):

  Stage 1 — fused quantization:  hadamard_quant (forward: fixed H + QuEST),
            sr_hadamard_quant (backward: randomized H + stochastic rounding).
  Stage 2 — block-scaled GEMM:   mxfp4_matmul (int8 half-codes + E8M0 scales,
            per-tile VMEM dequant, fp32-accumulating MXU dot).

Plus the serving-path attention hot-spots: flash_attention for the
32k-prefill / long-decode shapes (online-softmax KV streaming, causal block
skipping, GQA KV heads read in place via the block index map) and
paged_attention — batched decode directly over the engine's packed MXFP4 KV
pages (scalar-prefetched page tables drive the KV fetch, per-tile VMEM
dequantization, per-slot length masking) — both oracle-tested like the rest.

``ops.py`` holds the jit'd shape-flexible wrappers; ``ref.py`` the pure-jnp
oracles each kernel is verified against (bit-exact) in interpret mode.
"""

from repro.kernels.flash_attention import flash_attention, mha_flash  # noqa: F401
from repro.kernels.hadamard_quant import hadamard_quest_quantize  # noqa: F401
from repro.kernels.mxfp4_matmul import mxfp4_matmul  # noqa: F401
# NOTE: re-export PagedKV only — binding the `paged_attention` function here
# would shadow the submodule of the same name on the package object
from repro.kernels.paged_attention import PagedKV  # noqa: F401
from repro.kernels.sr_hadamard_quant import sr_hadamard_quantize  # noqa: F401
