"""Fused MXFP4 paged-attention decode kernel (Pallas).

Batched decode attends *directly over the packed KV pool*: the per-slot page
table (scalar-prefetched, so it is available before the kernel body runs)
drives the KV block fetch via the ``BlockSpec`` index map — page ``p`` of
slot ``b`` pulls pool page ``tables[b, p]`` into VMEM.  E2M1 nibble codes and
E8M0 scale bytes are unpacked/dequantized per tile *inside* the kernel
(``kv_pack.unpack_dequant`` — pure arithmetic, VPU-friendly), so decode HBM
traffic is O(packed KV) = 4.25 bits/element instead of the O(dense KV)
gather-dequantize round-trip the engine previously paid.

Blocking is GQA-native: the grid is ``(B, Hkv, pages_per_slot)`` with pages
innermost; each (slot, KV-head) program streams that head's pages once and
computes all ``Hq/Hkv`` query heads of the group against it — KV heads are
read in place, never materialized ``group×`` (no ``jnp.repeat``).  Online
softmax state (m, l, acc) lives in VMEM scratch across the page loop;
per-slot valid-length masking (``lengths[b]``, i.e. decode position + 1)
handles ragged batches, and fully-invalid pages are skipped with ``pl.when``
(their DMA fetches the scratch page the allocator parks unmapped table
entries on).

Queries may carry a token axis (``q [B, S, Hq, hd]``): the speculative
verify scores the last accepted token plus S-1 drafted tokens per slot in
the same single pass — the query block grows to ``S·group`` rows and each
row's causal bound is offset by its token index (row ``r`` sees positions
≤ ``lengths[b] - 1 + r // group``), so drafts never attend past themselves.
Plain decode is the S == 1 special case of the same kernel.  **Batched paged
prefill** is the S == prefill_chunk case: every prefilling slot's chunk is
scored in one grid pass over the packed pool, with ragged tails handled by
:func:`prefill_chunk_layout` — padding tokens are positioned on a sentinel
scratch column appended to the page table, so their quantize-on-write lands
on page 0 and their (garbage) output rows carry per-row causal bounds past
every valid row's, never contaminating real tokens.

``PagedKV`` is the pytree that threads this state through the model's
layer scan: pool leaves carry a leading ``[L]`` axis and are consumed one
layer-slice per scan step; ``tables`` is broadcast to ``[L, B, P]`` so each
slice sees the same page mapping.  ``models.attention`` dispatches to this
kernel whenever the decode cache is a ``PagedKV`` (see its backend matrix).

Validated in interpret mode against ``models.attention.blocked_attention``
over page-size / GQA / ragged-length / dense-vs-mxfp4 sweeps in
tests/test_paged_attention.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F
from repro.core import quantizers as Q
from repro.kernels.kv_pack import unpack_dequant

GROUP = 32
NEG_INF = -1e30


class PagedKV(NamedTuple):
    """Paged decode-attention state (a pytree, scannable over layers).

    ``pool``   — dict of pool leaves.  Packed mode: ``k_codes``/``v_codes``
                 u8 [..., n_pages, ps, Hkv, hd/2] + ``k_scales``/``v_scales``
                 u8 [..., n_pages, ps, Hkv, hd/block]; dense mode: ``k``/``v``
                 in the compute dtype [..., n_pages, ps, Hkv, hd].
    ``tables`` — int32 [..., B, pages_per_slot] page table (masked lanes'
                 rows zeroed by the engine so their writes land on the
                 reserved scratch page 0).

    Leaves carry a leading ``[L]`` axis when used as a layer-scan xs.
    """

    pool: dict
    tables: jnp.ndarray


def quant_block(hd: int) -> int:
    """MXFP4 scale-block size clamped to the head dim (blocks never straddle
    heads; reduced configs use hd=32, full configs 128 — both divide)."""
    return GROUP if hd % GROUP == 0 else hd


def quant_fmt(hd: int) -> F.Format:
    return dataclasses.replace(F.MXFP4, block=quant_block(hd))


def scatter_token(pool: dict, page_ids: jnp.ndarray, offsets: jnp.ndarray,
                  k_new: jnp.ndarray, v_new: jnp.ndarray) -> dict:
    """Write tokens into a single layer's pool slice.

    page_ids/offsets share any leading shape ``[...]`` (``[B]`` for decode,
    ``[B, S]`` for a speculative verify burst); k_new/v_new are
    ``[..., Hkv, hd]``.  Quantize-on-write in packed mode.  Duplicate
    (page, offset) pairs (masked lanes redirected to the scratch page)
    resolve arbitrarily — scratch contents are never read.
    """
    if "k" in pool:
        return {
            "k": pool["k"].at[page_ids, offsets].set(k_new.astype(pool["k"].dtype)),
            "v": pool["v"].at[page_ids, offsets].set(v_new.astype(pool["v"].dtype)),
        }
    fmt = quant_fmt(k_new.shape[-1])
    kq, vq = Q.kv_quantize(k_new, fmt), Q.kv_quantize(v_new, fmt)
    return {
        "k_codes": pool["k_codes"].at[page_ids, offsets].set(kq.codes),
        "k_scales": pool["k_scales"].at[page_ids, offsets].set(kq.scales),
        "v_codes": pool["v_codes"].at[page_ids, offsets].set(vq.codes),
        "v_scales": pool["v_scales"].at[page_ids, offsets].set(vq.scales),
    }


def prefill_chunk_layout(
    tables: jnp.ndarray,  # [B, P] int32 (masked lanes' rows already zeroed)
    start: jnp.ndarray,  # [B] int32 — absolute position of each chunk's row 0
    n_valid: jnp.ndarray,  # [B] int32 — real tokens in each row (1..C)
    chunk: int,  # C, the (static) padded chunk width
    page_size: int,
    mask: jnp.ndarray,  # [B] bool — slot actively prefilling this tick
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row write masking for a ragged batched-prefill chunk.

    Returns ``(tables_ext [B, P+1], positions [B, C])``:

    * ``tables_ext`` appends one all-zero **sentinel column** to the page
      tables.  Page-table reads clamp out-of-range columns, so without the
      sentinel an overlong position could clamp onto the *last mapped* page
      and clobber live KV; with it, every out-of-range column lands on the
      reserved scratch page 0.
    * ``positions[b, s]`` is ``start[b] + s`` for valid tokens.  Padding
      tokens of active rows are positioned at ``P * page_size`` — exactly the
      sentinel column — so their quantize-on-write goes to scratch; inactive
      lanes sit at position 0 of their zeroed table row (also scratch) and
      keep the page loop's per-slot trip count at one.

    The kernel needs no other change: per-row causal bounds come from
    ``positions[:, 0] + r // group``, and a valid token at ``start + s``
    never sees a padding position (``start + s' > start + s`` for every
    padding ``s'``), so garbage flows only into garbage rows.
    """
    B, P = tables.shape
    tables_ext = jnp.concatenate(
        [tables, jnp.zeros((B, 1), tables.dtype)], axis=1)
    s = jnp.arange(chunk, dtype=jnp.int32)[None, :]
    valid = mask[:, None] & (s < n_valid[:, None])
    start_safe = jnp.where(mask, start, 0).astype(jnp.int32)
    sentinel = jnp.int32(P * page_size)
    positions = jnp.where(
        valid, start_safe[:, None] + s,
        jnp.where(mask[:, None], sentinel, 0))
    return tables_ext, positions


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------


def _online_softmax_tile(q, k, v, kv_pos, q_pos, m_ref, l_ref, acc_ref):
    """One [rows, ps] score tile folded into the running (m, l, acc);
    ``q_pos`` is [rows, 1] — each query row carries its own causal bound."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [rows, ps]
    s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _paged_kernel(tbl_ref, len_ref, q_ref, *rest,
                  load_kv, ps: int, n_pp: int, group: int, n_q: int,
                  scale: float):
    """One (slot, KV-head, page) step; ``load_kv(kv_refs)`` materializes the
    page's [ps, hd] f32 K/V tiles (pool-dtype-specific — the only part that
    differs between the packed and dense pools).

    The query block is [n_q·group, hd]: ``n_q`` consecutive decode/verify
    tokens × the KV head's GQA group.  Row ``r`` belongs to query token
    ``r // group`` sitting at absolute position ``len_ref[b] - 1 + r//group``
    — speculative verify scores all drafted tokens in one pass with per-row
    causal bounds; plain decode is the n_q == 1 special case."""
    *kv_refs, o_ref, m_ref, l_ref, acc_ref = rest
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]  # tokens visible to the FIRST query row

    @pl.when(p * ps < length + n_q - 1)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # [n_q*group, hd]
        k, v = load_kv(kv_refs)
        kv_pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, (n_q * group, 1), 0)
        q_pos = length - 1 + rows // group
        _online_softmax_tile(q, k, v, kv_pos, q_pos, m_ref, l_ref, acc_ref)

    @pl.when(p == n_pp - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _load_kv_mxfp4(block: int):
    def load(kv_refs):
        kc, ks, vc, vs = kv_refs
        return (unpack_dequant(kc[0, :, 0, :], ks[0, :, 0, :], block),
                unpack_dequant(vc[0, :, 0, :], vs[0, :, 0, :], block))
    return load


def _load_kv_dense(kv_refs):
    k_ref, v_ref = kv_refs
    return (k_ref[0, :, 0, :].astype(jnp.float32),
            v_ref[0, :, 0, :].astype(jnp.float32))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jnp.ndarray,  # [B, Hq, hd] (one decode query per slot) or [B, S, Hq, hd]
    pool: dict,  # one layer's pool slice (packed or dense leaves)
    tables: jnp.ndarray,  # [B, pages_per_slot] int32
    lengths: jnp.ndarray,  # [B] int32 — tokens visible to the FIRST query
    #                        (its position + 1); query s sees lengths + s
    interpret: bool = True,
) -> jnp.ndarray:
    """Decode/verify attention directly over the paged pool.

    ``q`` may carry a token axis S > 1 (speculative verify: the last accepted
    token plus the drafted suffix) — all S tokens of a slot are scored in one
    grid pass with per-row causal bounds.  Returns [B, Hq, hd] for 3-d ``q``,
    [B, S, Hq, hd] for 4-d."""
    multi = q.ndim == 4
    if not multi:
        q = q[:, None]
    B, S, Hq, hd = q.shape
    quantized = "k_codes" in pool
    kleaf = pool["k_codes"] if quantized else pool["k"]
    ps, Hkv = kleaf.shape[1], kleaf.shape[2]
    group = Hq // Hkv
    n_pp = tables.shape[1]
    scale = 1.0 / np.sqrt(hd)
    # [B, S, Hkv, group, hd] → [B, Hkv, S·group, hd]: row r = token r//group,
    # query head (r%group) of the program's KV head
    qg = (q.reshape(B, S, Hkv, group, hd)
          .transpose(0, 2, 1, 3, 4)
          .reshape(B, Hkv, S * group, hd))

    def kv_idx(b, h, p, tbl, ln):
        del ln
        return (tbl[b, p], 0, h, 0)

    def q_idx(b, h, p, tbl, ln):
        del p, tbl, ln
        return (b, h, 0, 0)

    if quantized:
        block = quant_block(hd)
        load_kv = _load_kv_mxfp4(block)
        kv_specs = [
            pl.BlockSpec((1, ps, 1, hd // 2), kv_idx),
            pl.BlockSpec((1, ps, 1, hd // block), kv_idx),
            pl.BlockSpec((1, ps, 1, hd // 2), kv_idx),
            pl.BlockSpec((1, ps, 1, hd // block), kv_idx),
        ]
        operands = (pool["k_codes"], pool["k_scales"],
                    pool["v_codes"], pool["v_scales"])
    else:
        load_kv = _load_kv_dense
        kv_specs = [
            pl.BlockSpec((1, ps, 1, hd), kv_idx),
            pl.BlockSpec((1, ps, 1, hd), kv_idx),
        ]
        operands = (pool["k"], pool["v"])
    rows = S * group
    kern = functools.partial(_paged_kernel, load_kv=load_kv, ps=ps, n_pp=n_pp,
                             group=group, n_q=S, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pp),
        in_specs=[pl.BlockSpec((1, 1, rows, hd), q_idx), *kv_specs],
        out_specs=pl.BlockSpec((1, 1, rows, hd), q_idx),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),  # running max
            pltpu.VMEM((rows, 1), jnp.float32),  # running denom
            pltpu.VMEM((rows, hd), jnp.float32),  # running numerator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, hd), q.dtype),
        interpret=interpret,
    )(tables, lengths, qg, *operands)
    out = (out.reshape(B, Hkv, S, group, hd)
           .transpose(0, 2, 1, 3, 4)
           .reshape(B, S, Hq, hd))
    return out if multi else out[:, 0]
