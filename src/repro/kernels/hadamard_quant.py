"""Fused forward Stage-1 Pallas kernel: grouped Hadamard + QuEST → MXFP4.

TPU adaptation of Quartet's Stage-1 CUDA kernel (§4.4): one VMEM-resident
pass fuses

  1. the block-32 Hadamard transform, executed as a [bm·bk/32, 32] × [32, 32]
     MXU matmul against the constant normalized Hadamard matrix,
  2. per-32-group RMSE-optimal (QuEST) scale computation,
  3. E8M0 (power-of-two) scale rounding,
  4. E2M1 round-to-nearest downcast (the Blackwell PTX cvt → a native
     float4_e2m1fn cast on TPU/interpret),
  5. clip-mask generation for the backward trust estimator,

writing half-codes (int8 = 2×grid value), scales, and masks back to HBM.
Where Blackwell stages through GMEM→SMEM→RF, we stage HBM→VMEM→VREG; the
CUTLASS epilogue becomes the tail of the kernel body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import formats as F
from repro.core.hadamard import hadamard_matrix

GROUP = 32
_E2M1_MAX = 6.0


def _exp2i(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^e via bit manipulation (XLA exp2 is inexact / flushes at -126)."""
    bits = (e.astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _round_scale_e8m0_nearest(s: jnp.ndarray) -> jnp.ndarray:
    e = jnp.round(jnp.log2(jnp.maximum(s, 2.0**-126)))
    return _exp2i(jnp.clip(e, -126.0, 127.0))


def _hadamard_quest_kernel(x_ref, h_ref, codes_ref, scales_ref, mask_ref, *, clip_c: float):
    """One [bm, bk] tile: Hadamard → QuEST scale → E2M1 RTN → mask."""
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    ng = bk // GROUP

    # (1) grouped Hadamard as an MXU matmul against the constant 32×32 H
    xg = x.reshape(bm * ng, GROUP)
    xh = jnp.dot(xg, h_ref[...], preferred_element_type=jnp.float32)

    # (2) QuEST scale: c* · rms per 32-group, mapped so clip point = grid max
    rms = jnp.sqrt(jnp.mean(xh * xh, axis=-1, keepdims=True))
    raw = jnp.maximum(clip_c * rms / _E2M1_MAX, 2.0**-126)

    # (3) E8M0 rounding (nearest power of two)
    scale = _round_scale_e8m0_nearest(raw)

    # (4) E2M1 RTN downcast (hardware-exact, saturating) + mask (5)
    v = xh / scale
    mask = jnp.abs(v) <= _E2M1_MAX
    q = F.rtn_e2m1(jnp.clip(v, -_E2M1_MAX, _E2M1_MAX))

    codes_ref[...] = jnp.round(q * 2.0).astype(jnp.int8).reshape(bm, bk)
    scales_ref[...] = scale.reshape(bm, ng)
    mask_ref[...] = mask.reshape(bm, bk).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def hadamard_quest_quantize(
    x: jnp.ndarray,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool = True,
):
    """x: [M, K] → (codes int8 [M,K], scales f32 [M,K/32], mask bool [M,K])."""
    m, k = x.shape
    if k % GROUP != 0:
        raise ValueError(f"K={k} not divisible by group {GROUP}")
    bk = min(block_k, k)
    while k % bk != 0:  # largest divisor of K ≤ block_k that is a multiple of 32
        bk -= GROUP
    bm = min(block_m, m)
    grid_m = pl.cdiv(m, bm)
    pad_m = grid_m * bm - m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))

    clip_c = F.gaussian_optimal_clip("mxfp4")
    hmat = jnp.asarray(hadamard_matrix(GROUP), jnp.float32)
    kern = functools.partial(_hadamard_quest_kernel, clip_c=clip_c)
    codes, scales, mask = pl.pallas_call(
        kern,
        grid=(grid_m, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((GROUP, GROUP), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_m * bm, k), jnp.int8),
            jax.ShapeDtypeStruct((grid_m * bm, k // GROUP), jnp.float32),
            jax.ShapeDtypeStruct((grid_m * bm, k), jnp.int8),
        ],
        interpret=interpret,
    )(x, hmat)
    if pad_m:
        codes, scales, mask = codes[:m], scales[:m], mask[:m]
    return codes, scales, mask.astype(jnp.bool_)
