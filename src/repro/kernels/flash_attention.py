"""Pallas flash-attention (forward) for the 32k-prefill / long-decode shapes.

The jnp ``blocked_attention`` (models/attention.py) is the differentiable
reference used in training; this kernel is its serving-path hot-spot twin:
one VMEM-resident pass per (batch·head, q-block), streaming KV blocks with
online softmax — no [S, T] score matrix ever leaves VMEM.

Blocking: grid (BH, S/bq, T/bk) with the KV dimension innermost; the running
(m, l, acc) state lives in VMEM scratch across the innermost loop, flushed to
HBM at the last KV block.  Causal masking compares absolute q/kv indices, so
fully-masked future blocks are skipped via ``pl.when`` (the classic flash
triangular schedule).

Validated bit-consistently (≤1e-5) against a naive-softmax oracle in
``ref.py`` over shape sweeps in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, bq: int, bk: int, nk: int,
                  t_valid: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q_start = i * bq
    k_start = j * bk

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale  # [bq, hd]
        k = k_ref[0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < t_valid  # KV padding (non-multiple T)
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip KV blocks fully in the future of this q block (flash schedule)
        pl.when(k_start <= q_start + bq - 1)(_body)
    else:
        _body()

    @pl.when(j == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "q_heads", "kv_heads", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [B·Hq, S, hd]
    k: jnp.ndarray,  # [B·Hkv, T, hd]
    v: jnp.ndarray,  # [B·Hkv, T, hd]
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    q_heads: int = 1,
    kv_heads: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    """GQA-native: the KV row for query row ``bh`` is resolved in the block
    index map (``(bh // Hq)·Hkv + (bh % Hq) // group``), so KV heads are read
    in place — never materialized ``group×`` larger via ``jnp.repeat``."""
    bh, s, hd = q.shape
    t = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    group = q_heads // kv_heads
    assert bh % q_heads == 0 and k.shape[0] == (bh // q_heads) * kv_heads

    bq = min(block_q, s)
    bk = min(block_k, t)
    pad_q = (-s) % bq
    pad_k = (-t) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq, nk = (s + pad_q) // bq, (t + pad_k) // bk

    def kv_row(b):
        return (b // q_heads) * kv_heads + (b % q_heads) // group

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk, nk=nk,
        t_valid=t)
    out = pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (kv_row(b), j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (kv_row(b), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running denom
            pltpu.VMEM((bq, hd), jnp.float32),  # running numerator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s, :]


def mha_flash(q, k, v, causal: bool = True, interpret: bool = True,
              block_q: int = 256, block_k: int = 256):
    """[B, S, Hq, hd] × [B, T, Hkv, hd] (GQA) → [B, S, Hq, hd]."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, hd)
    o = flash_attention(qf, kf, vf, causal=causal, block_q=block_q,
                        block_k=block_k, q_heads=Hq, kv_heads=Hkv,
                        interpret=interpret)
    return o.reshape(B, Hq, S, hd).transpose(0, 2, 1, 3)
