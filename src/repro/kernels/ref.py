"""Pure-jnp oracles for every Pallas kernel in this package.

These are *independent* implementations (built on repro.core's searchsorted-
grid semantics) against which the arithmetic-trick kernel implementations are
verified with assert_allclose over shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import quantizers as Q
from repro.core.hadamard import hadamard_transform


def hadamard_quest_quantize_ref(x: jnp.ndarray, group: int = 32):
    """Oracle for the fused forward Stage-1 kernel.

    x: [M, K] → (codes int8 [M, K], scales f32 [M, K/group], mask bool [M, K])
    codes are half-codes (2× the E2M1 grid value).
    """
    xh = hadamard_transform(jnp.asarray(x, jnp.float32), g=group, axis=-1)
    r = Q.quest(xh, F.MXFP4)
    return r.codes, r.scales, r.mask


def sr_hadamard_quantize_ref(
    x: jnp.ndarray, signs: jnp.ndarray, u: jnp.ndarray,
    prescale: float = 0.75, group: int = 32,
):
    """Oracle for the fused backward Stage-1 kernel (randomized H + SR).

    x: [M, K]; signs: [K] ±1; u: [M, K] uniforms.
    Returns (codes int8 [M, K], scales f32 [M, K/group]).
    """
    xf = jnp.asarray(x, jnp.float32) * signs[None, :]
    xh = hadamard_transform(xf, g=group, axis=-1) * prescale
    fmt = F.MXFP4
    xb = F.to_blocks(xh, group)
    raw = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1), 2.0**F.E8M0_MIN_EXP) / fmt.max_value
    scales = F.round_scale_e8m0(raw, "ceil")
    q = F.stochastic_round_to_grid(
        xb / scales[..., None], fmt.grid_array, F.to_blocks(u, group)
    )
    codes = F.from_blocks(jnp.round(q * 2.0).astype(jnp.int8))
    return codes, scales


def mxfp4_matmul_ref(a_codes, a_scales, b_codes, b_scales, group: int = 32):
    """Oracle for the block-scaled GEMM kernel.

    a: codes [M, K], scales [M, K/group]  (blocks along K)
    b: codes [K, N], scales [K/group, N]  (blocks along K)
    Returns f32 [M, N] with fp32 accumulation.
    """
    av = a_codes.astype(jnp.float32) * 0.5
    av = av.reshape(av.shape[0], -1, group) * a_scales[..., None]
    av = av.reshape(a_codes.shape)
    bv = b_codes.astype(jnp.float32) * 0.5
    bv = bv.reshape(-1, group, bv.shape[-1]) * b_scales[:, None, :]
    bv = bv.reshape(b_codes.shape)
    return jax.lax.dot_general(
        av, bv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def flash_attention_ref(q, k, v, causal: bool = True):
    """Naive-softmax oracle for the flash kernel.  q/k/v: [BH, S|T, hd]."""
    import numpy as np

    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
