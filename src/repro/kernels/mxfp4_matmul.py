"""Stage-2 Pallas kernel: MXFP4 block-scaled GEMM.

TPU analogue of Quartet's dedicated CUTLASS ``tcgen05.mma`` kernel:

    D = (A ⊗ SFA) · (B ⊗ SFB),   scales along the K dim, one per 32 elements.

Blackwell applies the E8M0 rescale inside the tensor core; the TPU MXU has no
block-scaled input path, so the kernel dequantizes each [bm, bk] / [bk, bn]
code tile to f32 *in VMEM* (int8 half-code × 0.5 × scale — two vector ops,
no gather) and feeds the MXU with an fp32-accumulating ``jnp.dot``.  Because
E2M1×E2M1 products need ≤ 4 mantissa bits and E8M0 scales are exact powers of
two, this is bit-exact w.r.t. native FP4 hardware with fp32 accumulation
(DESIGN.md §2).  HBM traffic, however, is the *real* 4-bit payload: codes and
scales only.

Layout: A codes [M, K] + scales [M, K/32]; B codes [K, N] + scales [K/32, N].
Grid (m, n, k) with a VMEM f32 accumulator flushed at the last k step — the
standard Pallas TPU matmul schedule, K innermost so the accumulator stays
resident while code tiles stream through.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

GROUP = 32


def _mxfp4_matmul_kernel(a_ref, as_ref, b_ref, bs_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)  # [bm, bk] half-codes
    b = b_ref[...].astype(jnp.float32)  # [bk, bn]
    bm, bk = a.shape
    bn = b.shape[1]
    ng = bk // GROUP

    # dequant: value = code · 0.5 · scale  (scale broadcast per 32-group)
    a = a.reshape(bm, ng, GROUP) * (0.5 * as_ref[...])[..., None]
    b = b.reshape(ng, GROUP, bn) * (0.5 * bs_ref[...])[:, None, :]

    acc_ref[...] += jnp.dot(
        a.reshape(bm, bk), b.reshape(bk, bn), preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def mxfp4_matmul(
    a_codes: jnp.ndarray,
    a_scales: jnp.ndarray,
    b_codes: jnp.ndarray,
    b_scales: jnp.ndarray,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """(A codes [M,K], scales [M,K/32]) × (B codes [K,N], scales [K/32,N]) → f32 [M,N]."""
    m, k = a_codes.shape
    k2, n = b_codes.shape
    assert k == k2, (a_codes.shape, b_codes.shape)
    assert a_scales.shape == (m, k // GROUP)
    assert b_scales.shape == (k // GROUP, n)

    bk = min(block_k, k)
    while k % bk != 0:
        bk -= GROUP
    bm, bn = min(block_m, m), min(block_n, n)
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), k // bk
    pm, pn = gm * bm - m, gn * bn - n
    if pm:
        a_codes = jnp.pad(a_codes, ((0, pm), (0, 0)))
        a_scales = jnp.pad(a_scales, ((0, pm), (0, 0)), constant_values=1.0)
    if pn:
        b_codes = jnp.pad(b_codes, ((0, 0), (0, pn)))
        b_scales = jnp.pad(b_scales, ((0, 0), (0, pn)), constant_values=1.0)

    kern = functools.partial(_mxfp4_matmul_kernel, n_k=gk)
    out = pl.pallas_call(
        kern,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_codes, a_scales, b_codes, b_scales)
    return out[:m, :n]
