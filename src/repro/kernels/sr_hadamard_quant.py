"""Fused backward Stage-1 Pallas kernel: randomized Hadamard + SR → MXFP4.

Implements the backward operand preparation of Algorithm 1:

    SR( ¾ · Ĥ_32(x, ξ) )   with E8M0 ceil scales (no clipping → unbiased)

The sign flip ξ, grouped Hadamard (MXU matmul), AbsMax scale, power-of-two
ceil rounding, and stochastic E2M1 rounding are fused in one VMEM pass.

Stochastic rounding is arithmetic (no grid search): for E2M1 the spacing at
|v| is   step(v) = 2^(floor(log2 |v|) − 1)  for |v| ≥ 1, and 0.5 below 1;
round down to the grid then move up with probability (v − lo)/step.  Uniform
randomness arrives as an explicit operand so the kernel is reproducible and
CPU-interpretable; on real TPU hardware the same kernel can draw bits from
``pltpu.prng_random_bits`` instead (switchable, see ``use_hw_rng``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import hadamard_matrix

GROUP = 32
_E2M1_MAX = 6.0


def _e2m1_stochastic_round(v: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Unbiased SR onto the E2M1 grid for |v| ≤ 6 (arithmetic formulation)."""
    a = jnp.abs(v)
    sign = jnp.sign(v)
    e = jnp.floor(jnp.log2(jnp.maximum(a, 1.0)))  # 0 for a<1 → step 0.5
    step = jnp.exp2(e - 1.0)  # 0.5, 0.5, 1, 2 for a in [0,1),[1,2),[2,4),[4,6]
    lo = jnp.floor(a / step) * step
    p_up = (a - lo) / step
    q = jnp.where(u < p_up, lo + step, lo)
    return sign * jnp.minimum(q, _E2M1_MAX)


def _sr_hadamard_kernel(x_ref, signs_ref, u_ref, h_ref, codes_ref, scales_ref, *, prescale: float):
    x = x_ref[...].astype(jnp.float32) * signs_ref[...].astype(jnp.float32)[None, :]
    bm, bk = x.shape
    ng = bk // GROUP

    xh = jnp.dot(x.reshape(bm * ng, GROUP), h_ref[...], preferred_element_type=jnp.float32)
    xh = xh * prescale

    absmax = jnp.max(jnp.abs(xh), axis=-1, keepdims=True)
    raw = jnp.maximum(absmax / _E2M1_MAX, 2.0**-126)
    # E8M0 ceil: guarantees |v| ≤ 6 ⇒ SR never clips ⇒ unbiased.
    # exact 2^e via bit manipulation (XLA exp2 is inexact / flushes at -126)
    e = jnp.clip(jnp.ceil(jnp.log2(raw) - 1e-6), -126.0, 127.0)
    scale = jax.lax.bitcast_convert_type((e.astype(jnp.int32) + 127) << 23, jnp.float32)

    v = xh / scale
    q = _e2m1_stochastic_round(v, u_ref[...].astype(jnp.float32).reshape(bm * ng, GROUP))

    codes_ref[...] = jnp.round(q * 2.0).astype(jnp.int8).reshape(bm, bk)
    scales_ref[...] = scale.reshape(bm, ng)


@functools.partial(
    jax.jit, static_argnames=("prescale", "block_m", "block_k", "interpret")
)
def sr_hadamard_quantize(
    x: jnp.ndarray,
    signs: jnp.ndarray,
    u: jnp.ndarray,
    prescale: float = 0.75,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool = True,
):
    """x: [M, K], signs: [K] ±1, u: [M, K] uniforms →
    (codes int8 [M, K], scales f32 [M, K/32])."""
    m, k = x.shape
    if k % GROUP != 0:
        raise ValueError(f"K={k} not divisible by group {GROUP}")
    bk = min(block_k, k)
    while k % bk != 0:
        bk -= GROUP
    bm = min(block_m, m)
    grid_m = pl.cdiv(m, bm)
    pad_m = grid_m * bm - m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
        u = jnp.pad(u, ((0, pad_m), (0, 0)), constant_values=0.5)

    kern = functools.partial(_sr_hadamard_kernel, prescale=prescale)
    codes, scales = pl.pallas_call(
        kern,
        grid=(grid_m, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((GROUP, GROUP), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_m * bm, k), jnp.int8),
            jax.ShapeDtypeStruct((grid_m * bm, k // GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(x, signs, u, jnp.asarray(hadamard_matrix(GROUP), jnp.float32))
    if pad_m:
        codes, scales = codes[:m], scales[:m]
    return codes, scales
