"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544.  [arXiv:2403.17297]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-reduced",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
