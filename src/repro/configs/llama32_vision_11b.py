"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, gated cross-attention image layers every 5.  Vision frontend is
a STUB (precomputed patch embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1600,
    rope_theta=5e5,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-reduced",
        family="vlm",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        cross_attn_every=2,
        num_image_tokens=64,
    )
