"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
mamba1, ssm_state=16.  [arXiv:2410.05355]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_variant="mamba1",
    ssm_expand=2,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-reduced",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=1,
        num_kv_heads=1,
        head_dim=32,
        d_ff=0,
        vocab_size=512,
        ssm_state=8,
        ssm_variant="mamba1",
        ssm_expand=2,
        tie_embeddings=True,
    )
