"""deepseek-7b [dense] — 30L d_model=4096 32H (kv=32, i.e. MHA) d_ff=11008
vocab=102400, llama-arch.  [arXiv:2401.02954]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-reduced",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
