"""The paper's own Llama-2-style pre-training family (Appendix A.1, Table 4):
30M / 50M / 100M / 200M non-embedding parameters + the 7B stability run.
Sequence length 512, batch 512, AdamW, cosine schedule with 10% warmup."""

from repro.configs.base import ModelConfig


def _llama(name, layers, d, heads, vocab=32000) -> ModelConfig:
    # SwiGLU ffn: 8/3·d rounded up to a multiple of 64 (Llama-2 convention)
    f = ((int(d * 8 / 3) + 63) // 64) * 64
    return ModelConfig(
        name=name,
        family="dense",
        num_layers=layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=heads,
        head_dim=d // heads,
        d_ff=f,
        vocab_size=vocab,
    )


LLAMA_30M = _llama("llama-paper-30m", 6, 640, 5)
LLAMA_50M = _llama("llama-paper-50m", 7, 768, 6)
LLAMA_100M = _llama("llama-paper-100m", 8, 1024, 8)
LLAMA_200M = _llama("llama-paper-200m", 10, 1280, 10)
LLAMA_7B = _llama("llama-paper-7b", 32, 4096, 32)

# Paper learning rates (Table 4), scaled inverse-proportionally to N.
LEARNING_RATES = {
    "llama-paper-30m": 1.2e-3,
    "llama-paper-50m": 1.2e-3,
    "llama-paper-100m": 6e-4,
    "llama-paper-200m": 3e-4,
    "llama-paper-7b": 9.375e-6,
}

PAPER_FAMILY = {c.name: c for c in
                (LLAMA_30M, LLAMA_50M, LLAMA_100M, LLAMA_200M, LLAMA_7B)}


def tiny_llama(d: int = 128, layers: int = 3, vocab: int = 2048) -> ModelConfig:
    """~0.5-2M-param models for the CPU-scale Table-3 method comparison."""
    heads = max(d // 64, 2)
    return _llama(f"llama-tiny-{d}x{layers}", layers, d, heads, vocab)
