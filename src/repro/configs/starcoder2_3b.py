"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, GeLU MLP, biases, RoPE.  [arXiv:2402.19173]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp="gelu",
    use_bias=True,
    rope_theta=1e5,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-reduced",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp="gelu",
        use_bias=True,
    )
