"""Architecture configs: the 10 assigned architectures (exact public-literature
dimensions) + the paper's own Llama 30M..7B family.  ``get_config(name)``
resolves ids like "qwen3-moe-235b-a22b"; each module also exports ``reduced()``
— a small same-family variant for CPU smoke tests."""

from repro.configs.base import (  # noqa: F401
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    DECODE_32K,
    ModelConfig,
    ShapeConfig,
    input_specs,
    shapes_for,
)
from repro.configs.registry import ARCH_IDS, get_config, get_reduced_config  # noqa: F401
