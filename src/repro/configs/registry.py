"""Config registry: ``--arch <id>`` resolution for launchers and tests."""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str, **overrides) -> ModelConfig:
    if name in _MODULES:
        cfg = importlib.import_module(_MODULES[name]).CONFIG
    else:
        from repro.configs.llama_paper import PAPER_FAMILY
        if name not in PAPER_FAMILY:
            raise ValueError(f"unknown arch {name!r}; have {ARCH_IDS} + paper family")
        cfg = PAPER_FAMILY[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_reduced_config(name: str, **overrides) -> ModelConfig:
    cfg = importlib.import_module(_MODULES[name]).reduced()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
