"""whisper-tiny [audio/enc-dec] — 4+4L d_model=384 6H d_ff=1536 vocab=51865,
conv frontend is a STUB (precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp="gelu",
    use_bias=True,
    norm="layernorm",
    pos_embed="absolute",
    tie_embeddings=True,
    max_source_len=1500,
    # §Perf: d=384 makes attention-score transients ([B,S,H,ck] f32) the
    # memory driver, not weights — halving the KV block halves them
    attn_kv_chunk=512,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-reduced",
        family="encdec",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        mlp="gelu",
        use_bias=True,
        norm="layernorm",
        pos_embed="absolute",
        tie_embeddings=True,
        max_source_len=64,
    )
