"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm, tied embeddings.  [hf:Qwen/Qwen3-8B family]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b-reduced",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        qk_norm=True,
        tie_embeddings=True,
    )
