"""Model / shape configuration system.

Every assigned architecture is one ``ModelConfig`` in ``repro/configs/<id>.py``
(exact public-literature dimensions) plus a ``reduced()`` variant for CPU smoke
tests.  Shapes are the four assigned input-shape cells; ``input_specs`` builds
ShapeDtypeStruct stand-ins so full configs never allocate memory.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.quartet import QUARTET_CONFIG, QuartetConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    use_bias: bool = False
    qk_norm: bool = False
    pos_embed: Literal["rope", "absolute", "none"] = "rope"
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3

    # SSM (mamba1 / mamba2)
    ssm_state: int = 0
    ssm_variant: Literal["", "mamba1", "mamba2"] = ""
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # mamba2
    ssm_chunk: int = 128

    # hybrid (zamba2): one shared attention block applied every N mamba blocks
    attn_every: int = 0

    # enc-dec (whisper)
    encoder_layers: int = 0
    max_source_len: int = 1500  # whisper frame count (30 s)

    # vlm: cross-attention to image tokens every N layers
    cross_attn_every: int = 0
    num_image_tokens: int = 0

    # numerics / technique
    quartet: QuartetConfig = QUARTET_CONFIG
    quantize_lm_head: bool = False  # paper quantizes transformer linears
    dtype: str = "bfloat16"

    # execution
    # attention backend (models.attention.dispatch_attention):
    #   "blocked" — jnp online-softmax reference (differentiable; train default)
    #   "flash"   — Pallas flash kernel for from-scratch self-attention
    #   "paged"   — serving decode attends directly over packed MXFP4 pages
    #               (dense call sites fall back to "blocked"); this is what
    #               makes the engine's batched decode O(packed KV) HBM traffic
    attn_backend: Literal["blocked", "flash", "paged"] = "paged"
    attn_q_chunk: int = 1024  # flash-style blocking for long sequences
    attn_kv_chunk: int = 1024
    # True (default): S > 1 rows share row 0's positions for causal masks and
    # rope angles — train/whole-batch-prefill rows are an identical arange,
    # and per-row [B, S, …] masks/angles would hoist out of the layer scan as
    # multi-GB loop invariants.  The serving engine's multi-row steps build
    # their model with False: both the speculative verify and the batched
    # paged prefill (train.serve.make_verify_step, which serve.steps reuses
    # for prefill_all) put every slot's rows at genuinely different per-slot
    # offsets, so masks and rope angles must be per row.
    attn_rows_shared: bool = True
    # Tensor-parallel serving (set by serve.placement via dataclasses.replace,
    # never by hand): when ``tp_axis`` names a shard_map mesh axis of (static)
    # size ``tp_size``, the paged/gather decode paths treat their KV cache
    # operands as head-sharded — each shard computes its local Hkv/tp KV heads
    # (and E/tp experts for MoE), runs attention on its pool slice, and
    # all_gathers outputs over the head axis.  Exactness-preserving by
    # construction: per-head attention is independent and the tiled
    # all_gather is a pure concat, so no cross-shard reduction ever reorders
    # floating-point sums.  None → single-device behaviour, bit-identical.
    tp_axis: str | None = None
    tp_size: int = 1
    remat: bool = True
    # "full": recompute everything (paper-faithful baseline);
    # "dots": save no-batch-dim dot outputs (skips fwd GEMM recompute — §Perf)
    remat_policy: str = "full"
    scan_layers: bool = True

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_causal_lm(self) -> bool:
        return self.family in ("dense", "moe", "ssm", "hybrid", "vlm")

    def n_params(self, non_embedding: bool = True) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and roofline)."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim_
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * hd * (nq + 2 * nkv) + nq * hd * d
        if self.mlp == "swiglu":
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        per_layer = 0
        if self.family in ("dense", "vlm", "encdec"):
            per_layer = attn + ffn
        elif self.family == "moe":
            per_layer = attn + self.num_experts * ffn + d * self.num_experts
            if self.moe_dense_residual:
                per_layer += ffn
        elif self.family == "ssm":
            per_layer = _mamba_params(self)
        elif self.family == "hybrid":
            mamba = _mamba_params(self)
            n_attn = L // max(self.attn_every, 1)
            per_layer = mamba  # per mamba block; attn added below
            extra = n_attn * (attn + ffn)
            total = L * per_layer + extra
            if not non_embedding:
                total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
            return total
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            per_layer_total = L * per_layer + n_cross * attn
        else:
            per_layer_total = L * per_layer
        total = per_layer_total
        if not non_embedding:
            total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters — MoE uses top-k experts only."""
        if self.family != "moe":
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim_
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        ffn = (3 if self.mlp == "swiglu" else 2) * d * f
        act = attn + self.experts_per_token * ffn + d * self.num_experts
        if self.moe_dense_residual:
            act += ffn
        return L * act


def _mamba_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    if cfg.ssm_variant == "mamba2":
        nh = di // cfg.ssm_head_dim
        return d * (2 * di + 2 * n * 1 + nh) + di * cfg.ssm_conv + di * d + 3 * nh
    # mamba1
    dt_rank = max(d // 16, 1)
    return (d * 2 * di + di * cfg.ssm_conv + di * (dt_rank + 2 * n)
            + dt_rank * di + di * n + di * d)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

# long_500k needs sub-quadratic sequence handling: run only for SSM/hybrid.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.family in LONG_CONTEXT_FAMILIES:
        out.append(LONG_500K)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:    tokens + labels [B, S]
    prefill:  tokens [B, S]
    decode:   tokens [B, 1] + position + the KV/SSM cache (built separately
              by the serving engine; see repro.train.serve.cache_specs)
    Modality frontends are stubs per spec: audio/vision arrive as precomputed
    frame/patch embeddings.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token against a length-S cache
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "position": jax.ShapeDtypeStruct((B,), i32),
        }
    if cfg.family == "encdec":
        # audio stub: precomputed conv-frontend frame embeddings
        specs["source_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.max_source_len, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return specs
