"""zamba2-7b [hybrid] — 81 Mamba2 layers, d_model=3584, shared attention
block (32H, kv=32, d_ff=14336) applied every 6 layers, vocab=32000,
ssm_state=64.  [arXiv:2411.15242]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_variant="mamba2",
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-reduced",
        family="hybrid",
        num_layers=5,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        ssm_state=16,
        ssm_variant="mamba2",
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=32,
        attn_every=2,
    )
