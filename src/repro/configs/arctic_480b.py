"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP.  [hf:Snowflake/snowflake-arctic-base]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-reduced",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=64,
        vocab_size=512,
        num_experts=8,
        experts_per_token=2,
        moe_dense_residual=True,
    )
