"""Active-mesh context: launchers wrap lowering in ``activate_mesh(mesh)``;
models anchor activations through ``constrain_*`` helpers that no-op when no
mesh is active (CPU smoke tests), keeping model code mesh-agnostic."""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STACK: list[Mesh] = []


@contextlib.contextmanager
def activate_mesh(mesh: Mesh):
    _STACK.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _STACK.pop()


def current_mesh() -> Mesh | None:
    return _STACK[-1] if _STACK else None


def _dp(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# §Perf knob: additionally shard the layer-scan carry's SEQUENCE dim over the
# model axis (Megatron-style sequence parallelism).  Activations then regather
# per layer (~MBs) instead of FSDP weights regathering per microbatch (~GBs) —
# lets the microbatch count drop for gather-bound MoE training.
SEQ_SHARD_CARRY = [False]


def constrain_tokens(x):
    """Anchor [B, S, ...] activations: batch → DP axes, falling back to
    sequence → data for batch-1 long-context shapes (SP)."""
    mesh = current_mesh()
    if mesh is None or x.ndim < 2:
        return x
    B, S = x.shape[0], x.shape[1]
    dp = _dp(mesh)
    while dp and B % _size(mesh, dp) != 0:
        dp = dp[:-1]
    s_ax = None
    if SEQ_SHARD_CARRY[0] and S > 1 and S % mesh.shape["model"] == 0:
        s_ax = "model"
    if dp and _size(mesh, dp) > 1:
        spec = P(dp, s_ax, *([None] * (x.ndim - 2)))
    elif S % mesh.shape["data"] == 0 and S > 1:
        spec = P(None, "data", *([None] * (x.ndim - 2)))
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_params(tree):
    """Anchor a param-structured pytree (e.g. the microbatch gradient
    accumulator) to the parameter sharding rules — without this, GSPMD
    replicates the f32 accumulator (≈1 TB/device for a 235B MoE)."""
    mesh = current_mesh()
    if mesh is None:
        return tree
    from repro.distributed.sharding import param_partition

    specs = param_partition(tree, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: hasattr(x, "shape"))


def constrain_layer_params(tree):
    """Anchor a *per-layer* (unstacked) param slice inside the layer scan.

    Forward this is a no-op (the slice already carries the right sharding);
    the payoff is the transpose: with_sharding_constraint constrains its own
    cotangent, so per-layer dW leaves the backward scan correctly sharded
    instead of triggering SPMD's full-rematerialization reshard (a 141 GiB
    replicated copy per expert tensor for the 235B MoE).
    """
    mesh = current_mesh()
    if mesh is None:
        return tree
    from repro.distributed.sharding import param_partition

    specs = param_partition(tree, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: hasattr(x, "shape"))


def constrain_logits(x):
    """[B, S, V]: batch → DP, vocab → model (anchors the LM-head GEMM)."""
    mesh = current_mesh()
    if mesh is None or x.ndim != 3:
        return x
    B, S, V = x.shape
    dp = _dp(mesh)
    while dp and B % _size(mesh, dp) != 0:
        dp = dp[:-1]
    b_ax = dp if (dp and _size(mesh, dp) > 1) else None
    v_ax = "model" if V % mesh.shape["model"] == 0 else None
    s_ax = None
    if b_ax is None and S % mesh.shape["data"] == 0 and S > 1:
        s_ax = "data"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, s_ax, v_ax)))
