"""Sharding rules: param-path → PartitionSpec, MaxText-style.

Mesh axes: ``(pod, data, model)`` multi-pod or ``(data, model)`` single-pod.

  * batch/tokens      → (pod, data)            [DP]
  * weights, K dim    → data (+pod)            [FSDP / ZeRO-3]
  * weights, N dim    → model                  [TP: heads / d_ff / vocab]
  * MoE expert dim    → model                  [EP: 128 experts / 16 shards]
  * long-context seq  → data                   [SP / context parallelism]
  * mamba inner dim   → model                  [SSM TP]

Every mapping is divisibility-guarded: a dim is only sharded if the axis size
divides it (e.g. starcoder2's 24 heads are sharded via the fused 3072-wide
projection, not the head count).  Rules are *name-based* over the param-tree
paths so they cover all six families uniformly.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh: Mesh, include_pod: bool):
    if include_pod and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes):
    """Return ``axes`` if it divides ``dim``, else progressively shrink.

    Preserves the caller's form — a string stays a string, a tuple stays a
    tuple: jax 0.4.x PartitionSpec equality is structural (``('data',)`` !=
    ``'data'``), and the rule tests pin the tuple form for FSDP axes."""
    if axes is None:
        return None
    was_str = isinstance(axes, str)
    if was_str:
        axes = (axes,)
    while axes and dim % _axis_size(mesh, axes) != 0:
        axes = axes[:-1]
    if not axes:
        return None
    return axes[0] if was_str else tuple(axes)


# --- rule table: (path regex, spec builder over trailing dims) ---------------
# Specs are given for the *unstacked* parameter; a leading scan/stack dim
# (layers, super-blocks, experts-in-name) is auto-padded with None.


def _spec_for(path: str, shape: tuple[int, ...], mesh: Mesh, fsdp) -> P:
    tp = "model"

    def fit(dim, axes):
        return _fit(mesh, dim, axes)

    # ---- embeddings / lm head ----
    if re.search(r"embed/table$", path):
        v, d = shape[-2:]
        return P(fit(v, tp), fit(d, fsdp))
    if re.search(r"lm_head/w$", path):
        d, v = shape[-2:]
        return P(*_pad(shape, (fit(d, fsdp), fit(v, tp))))

    # ---- MoE experts: [.., E, K, N] ----
    if re.search(r"moe/(gate|up)$", path):
        e, d, f = shape[-3:]
        return P(*_pad(shape, (fit(e, tp), fit(d, fsdp), None)))
    if re.search(r"moe/down$", path):
        e, f, d = shape[-3:]
        return P(*_pad(shape, (fit(e, tp), None, fit(d, fsdp))))
    if re.search(r"moe/router/w$", path):
        d, e = shape[-2:]
        return P(*_pad(shape, (fit(d, fsdp), None)))

    # ---- column-parallel linears: K → fsdp, N → tp ----
    if re.search(r"(wq|wk|wv|gate|up|in_proj|dt_proj)/w$", path):
        k, n = shape[-2:]
        return P(*_pad(shape, (fit(k, fsdp), fit(n, tp))))
    # ---- row-parallel linears: K → tp, N → fsdp ----
    if re.search(r"(wo|down|out_proj|x_proj)/w$", path):
        k, n = shape[-2:]
        return P(*_pad(shape, (fit(k, tp), fit(n, fsdp))))

    # ---- biases of column-parallel layers ----
    if re.search(r"(wq|wk|wv|gate|up|in_proj|dt_proj)/b$", path):
        return P(*_pad(shape, (fit(shape[-1], tp),)))

    # ---- SSM internals: inner dim → tp ----
    if re.search(r"conv_w$", path):
        return P(*_pad(shape, (None, fit(shape[-1], tp))))
    if re.search(r"conv_b$", path):
        return P(*_pad(shape, (fit(shape[-1], tp),)))
    if re.search(r"A_log$", path) and len(shape) >= 2:
        return P(*_pad(shape, (fit(shape[-2], tp), None)))

    # ---- everything else (norms, scalars, small vectors): replicated ----
    return P(*([None] * len(shape)))


def _pad(shape, trailing) -> tuple:
    """Left-pad a trailing-dims spec with None for stacked leading dims."""
    lead = len(shape) - len(trailing)
    return tuple([None] * lead) + tuple(trailing)


def param_partition(params: Any, mesh: Mesh, include_pod_fsdp: bool = True):
    """PartitionSpec pytree for a param tree (works on ShapeDtypeStructs)."""
    fsdp = fsdp_axes(mesh, include_pod_fsdp)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key_name(k) for k in path)
        specs.append(_spec_for(pstr, tuple(leaf.shape), mesh, fsdp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def opt_partition(opt_state: Any, param_specs: Any, mesh: Mesh):
    """Adam moments inherit their parameter's spec; int8 block-state stays
    replicated-by-structure (flat blocks don't align with param dims)."""

    def like(spec, leaf):
        if hasattr(leaf, "shape") and len(leaf.shape) == len(spec):
            return spec
        return P(*([None] * len(getattr(leaf, "shape", ()))))

    out = {}
    for key in opt_state:
        if key == "step":
            out[key] = P()
        elif _is_q8_tree(opt_state[key]):
            # int8 moments are blocked along the param's last axis:
            # q [..., n, 256] and s [..., n] inherit the param's leading-dim
            # sharding; the last-dim axis moves to the block-count dim.
            def q8spec(spec, m):
                parts = list(spec) if len(spec) else []
                if isinstance(m, dict):
                    lead = parts[:-1] if parts else []
                    # the param's last-dim axis moves to the block-count dim
                    # — only if the (much smaller) count stays divisible
                    n_blocks = m["q"].shape[-2]
                    last = _fit(mesh, n_blocks, parts[-1]) if parts else None
                    qdims = m["q"].ndim
                    qspec = (lead + [last, None])[:qdims]
                    qspec = [None] * (qdims - len(qspec)) + qspec if len(qspec) < qdims else qspec
                    sdims = m["s"].ndim
                    sspec = (lead + [last])[:sdims]
                    sspec = [None] * (sdims - len(sspec)) + sspec if len(sspec) < sdims else sspec
                    return {"q": P(*qspec), "s": P(*sspec)}
                return P(*([None] * m.ndim))
            out[key] = jax.tree.map(
                q8spec, param_specs, opt_state[key],
                is_leaf=lambda x: isinstance(x, P))
        else:
            out[key] = jax.tree.map(
                lambda spec, m: like(spec, m), param_specs, opt_state[key],
                is_leaf=lambda x: isinstance(x, P))
    return out


def _is_q8_tree(tree) -> bool:
    leaves = jax.tree.leaves(tree)
    return any(getattr(l, "dtype", None) == jnp.int8 for l in leaves)


def partition_state(state, param_specs, mesh: Mesh):
    """Specs for a full TrainState."""
    from repro.train.state import TrainState

    opt = opt_partition(state.opt_state, param_specs, mesh)
    err = None
    if state.err is not None:
        err = param_specs
    return TrainState(param_specs, opt, P(), err)


# ---------------------------------------------------------------------------
# Inputs / activations / caches
# ---------------------------------------------------------------------------


def batch_partition(mesh: Mesh, batch: int, seq: int | None = None) -> P:
    """Token batches: batch over DP axes; context-parallel fallback when the
    batch is too small (long_500k: B=1 → shard the sequence over data)."""
    dp = _fit(mesh, batch, dp_axes(mesh))
    if dp is not None and _axis_size(mesh, dp) > 1:
        return P(dp, None)
    if seq is not None and seq % mesh.shape["data"] == 0:
        return P(None, "data")  # SP / context parallelism
    return P(None, None)


def serve_pool_partition(pool: Any, mesh: Mesh) -> Any:
    """PartitionSpecs for the serving engine's packed page pool.

    Every pool leaf is ``[L, n_pages, page, H, payload]`` (packed codes,
    scales, or a dense-dtype payload) — the KV-head dim (axis 3) is the
    natural shard axis for GQA serving: the paged-attention grid is already
    ``(B, Hkv, pages)``, so each shard runs the identical kernel over its
    local ``Hkv/tp`` heads and local pool slice.  Divisibility-guarded like
    every rule here: a non-divisible head count falls back to replicated,
    which the models' shape-based tp detection treats as "not sharded"
    (consistent by construction)."""

    def spec(leaf):
        ax = _fit(mesh, leaf.shape[3], "model")
        return P(None, None, None, ax, None)

    return jax.tree.map(spec, pool)


def cache_partition(cache_specs: Any, mesh: Mesh, batch: int) -> Any:
    """KV/SSM cache sharding: batch dim → DP axes if divisible; kv-head or
    inner dims → model if divisible; long sequences → data."""

    def spec(leaf):
        shape = leaf.shape
        # stacked caches: [L, B, T, H, hd] / [L, B, T', Di] / [L, B, Di, N]...
        out = [None] * len(shape)
        try:  # batch dim: first dim equal to `batch` after the stack dim
            bdim = next(i for i, s in enumerate(shape) if s == batch and i > 0)
        except StopIteration:
            bdim = None
        dp = _fit(mesh, batch, dp_axes(mesh))
        batch_sharded = bdim is not None and dp is not None and _axis_size(mesh, dp) > 1
        if batch_sharded:
            out[bdim] = dp
        start = (bdim + 1) if bdim is not None else (1 if len(shape) > 1 else 0)
        free = [i for i in range(start, len(shape)) if out[i] is None]

        def fits(i, axis):
            return shape[i] % mesh.shape[axis] == 0 and shape[i] >= mesh.shape[axis]

        # model axis: prefer the head-like dim (second-to-last, ≤512), else
        # the largest remaining divisible dim
        mi = None
        if len(shape) >= 2 and (len(shape) - 2) in free and shape[-2] <= 512 \
                and fits(len(shape) - 2, "model"):
            mi = len(shape) - 2
        else:
            for i in sorted(free, key=lambda i: -shape[i]):
                if fits(i, "model"):
                    mi = i
                    break
        if mi is not None:
            out[mi] = "model"
            free.remove(mi)
        # data axis (when the batch couldn't use it): the seq-like dim
        if not batch_sharded:
            for i in sorted(free, key=lambda i: -shape[i]):
                if shape[i] > 1024 and fits(i, "data"):
                    out[i] = "data"
                    break
        return P(*out)

    return jax.tree.map(spec, cache_specs)
