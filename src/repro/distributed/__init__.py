"""Distribution: logical-axis sharding rules (DP/FSDP/TP/EP/SP) over the
production mesh, built for GSPMD (jax.jit + NamedSharding)."""

from repro.distributed.sharding import (  # noqa: F401
    batch_partition,
    cache_partition,
    param_partition,
    partition_state,
)
