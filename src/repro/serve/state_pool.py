"""One packed pool for EVERY family's per-slot decode state.

:class:`StatePool` decomposes a family's cache tree into pooled *planes*,
each quantize-on-write MXFP4 (or dense, for parity testing):

* **attn-KV plane** — positional self-attention KV ([L, B, T, Hkv, hd]
  subtrees: ``dense``-shaped stacks in enc-dec/VLM ``"self"`` and the hybrid
  ``"attn"`` super-block caches) lives in a :class:`~repro.serve.paged_cache.
  PagedCache` built with an explicit geometry — same pages, free list,
  refcounts, and COW machinery as the dense/MoE engine pool.
* **cross-KV plane** — enc-dec / VLM cross-attention KV is *static after
  encode*: a second ``PagedCache`` holds it, written exactly once per source
  (at admission, via ``models.{encdec,vlm}.encode_cross_kv``) and only ever
  read afterwards.  Because pages are refcounted, two requests carrying the
  same audio/image source can ALIAS one set of cross pages — the
  :class:`CrossIndex` is the radix-prefix-cache analogue for conditioning
  tensors (exact-match on the embedding bytes; eviction drops the pin, the
  pages free once no slot maps them).
* **state rings** — SSM recurrent state and conv buffers have no positional
  axis to page over; each flattened leaf gets a :class:`RingPlane`: one page
  holds a slot's ENTIRE leaf state, and each slot owns a depth-2 ring of
  pages it alternates between (read page ``r``, write page ``w``, swap after
  the step).  Page 0 is the shared zero-sentinel/scratch: a fresh slot READS
  id 0 (gather substitutes exact zeros — the oracle's ``reset_slot``), and
  masked lanes WRITE to id 0 (never observable).  The double-buffer is what
  makes one batched jitted step safe: a lane's functional update lands in
  its write page while every other lane's read page is untouched, without
  any merge-masked dense update.

Quantization note: packed state is NOT idempotent under re-quantization
(``quantize(dequantize(x)) != x`` bitwise for values between grid points),
which is exactly why masked lanes redirect writes to the sentinel instead of
writing back what they read.
"""

from __future__ import annotations

import hashlib
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import quantizers as Q
from repro.models.registry import Model
from repro.serve.paged_cache import PagedCache

STATE_FAMILIES = ("ssm", "hybrid", "encdec", "vlm")
RING_DEPTH = 2  # read page + write page per slot
_STATE_FMT = F.MXFP4  # block-32 E2M1 + E8M0, same payload as the KV pool


# ---------------------------------------------------------------------------
# RingPlane — one flattened recurrent-state leaf
# ---------------------------------------------------------------------------


class RingPlane:
    """A pool of whole-state pages for ONE cache-tree leaf ([L, B, *rest]).

    Page assignment is static — slot ``s`` owns pages ``1 + s*RING_DEPTH ..
    1 + s*RING_DEPTH + RING_DEPTH - 1`` — so there is no allocator; the host
    ring cursor (owned by :class:`StatePool`, shared across planes) decides
    which page is read and which is written each step.  ``gather``/
    ``scatter`` are pure jit-traceable functions of the pool dict.
    """

    def __init__(self, name: str, leaf_shape: tuple[int, ...], leaf_dtype,
                 n_slots: int, kv_dtype: str):
        # leaf_shape is the PER-SLOT state shape: [L, *rest] (batch removed)
        self.name = name
        self.leaf_shape = tuple(int(d) for d in leaf_shape)
        self.dtype = jnp.dtype(leaf_dtype)
        self.kv_dtype = kv_dtype
        self.elems = int(np.prod(self.leaf_shape))
        block = _STATE_FMT.block
        self.padded = -(-self.elems // block) * block
        self.n_slots = n_slots
        self.n_pages = 1 + n_slots * RING_DEPTH
        if kv_dtype == "dense":
            self.pool = {"raw": jnp.zeros((self.n_pages, self.padded), self.dtype)}
        else:
            self.pool = {
                "codes": jnp.zeros((self.n_pages, self.padded // 2), jnp.uint8),
                "scales": jnp.zeros((self.n_pages, self.padded // block), jnp.uint8),
            }

    # -- pure device ops ----------------------------------------------------

    def gather(self, pool: dict, ids: jnp.ndarray) -> jnp.ndarray:
        """ids [B] int32 page ids → leaf values [L, B, *rest]; id 0 reads
        exact zeros (fresh state), whatever the sentinel page holds."""
        B = ids.shape[0]
        if "raw" in pool:
            flat = pool["raw"][ids].astype(self.dtype)  # [B, padded]
        else:
            pq = Q.PackedQuant(pool["codes"][ids], pool["scales"][ids])
            flat = Q.kv_dequantize(pq, _STATE_FMT, self.dtype)
        flat = jnp.where(ids[:, None] != 0, flat, jnp.zeros_like(flat))
        leaf = flat[:, :self.elems].reshape(B, *self.leaf_shape)
        return jnp.moveaxis(leaf, 0, 1)  # [L, B, *rest]

    def scatter(self, pool: dict, ids: jnp.ndarray, leaf: jnp.ndarray) -> dict:
        """Write each lane's whole new state into its page (quantize-on-write
        in packed mode).  Masked lanes carry id 0 — their writes collide on
        the sentinel, whose contents are never read."""
        B = ids.shape[0]
        flat = jnp.moveaxis(leaf, 1, 0).reshape(B, self.elems)
        if "raw" in pool:
            pad = self.padded - self.elems
            if pad:
                flat = jnp.pad(flat, ((0, 0), (0, pad)))
            return {"raw": pool["raw"].at[ids].set(flat.astype(self.dtype))}
        pq = Q.state_quantize(flat.astype(jnp.float32), _STATE_FMT)
        return {"codes": pool["codes"].at[ids].set(pq.codes),
                "scales": pool["scales"].at[ids].set(pq.scales)}

    # -- accounting ---------------------------------------------------------

    def page_bytes(self) -> int:
        """Bytes one slot's state occupies in THIS plane's storage."""
        return sum(int(a.nbytes) for a in self.pool.values()) // self.n_pages

    def dense_bytes(self) -> int:
        """Bytes the same state occupies in the DenseSlotCache oracle."""
        return self.elems * self.dtype.itemsize

    def cache_bytes(self) -> int:
        return sum(int(a.nbytes) for a in self.pool.values())

    def slot_pages(self, slot: int) -> tuple[int, ...]:
        base = 1 + slot * RING_DEPTH
        return tuple(range(base, base + RING_DEPTH))


# ---------------------------------------------------------------------------
# CrossIndex — exact-match sharing of encoded cross-KV pages
# ---------------------------------------------------------------------------


def cross_key(extra: Any) -> str | None:
    """Content key for a request's conditioning tensors (source/image
    embeddings): two requests with byte-identical embeddings share one
    encoded cross-KV page set.  None when the request carries none."""
    if not extra:
        return None
    h = hashlib.sha1()
    found = False
    for name in sorted(extra):
        val = extra[name]
        if val is None:
            continue
        arr = np.asarray(jax.device_get(val))
        h.update(name.encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
        found = True
    return h.hexdigest() if found else None


class CrossIndex:
    """Pins encoded cross-KV page sets under their source-content key.

    The cross plane's analogue of the radix prefix index: a cached entry
    holds one external reference per page (``PagedCache.ref_page``), so the
    pages survive the encoding slot's retirement; a warm admission aliases
    them via ``alloc(shared=...)``; eviction (LRU, under pool pressure)
    drops the pins and the pages free once no slot still maps them.
    """

    def __init__(self):
        self._entries: dict[str, tuple[tuple[int, ...], float]] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def match(self, key: str | None, stamp: float) -> list[int]:
        if key is None or key not in self._entries:
            return []
        pages, _ = self._entries[key]
        self._entries[key] = (pages, stamp)  # LRU touch
        return list(pages)

    def publish(self, cache: PagedCache, key: str | None,
                pages: np.ndarray, stamp: float) -> int:
        if key is None or key in self._entries:
            return 0
        pages = tuple(int(p) for p in pages if int(p) != 0)
        for p in pages:
            cache.ref_page(p)
        self._entries[key] = (pages, stamp)
        return len(pages)

    def evictable_pages(self, cache: PagedCache, exclude: set[str] | None = None) -> int:
        """Pages that would return to the free list if every evictable entry
        (external pin is the last reference) were dropped."""
        exclude = exclude or set()
        return sum(len(pages) for key, (pages, _) in self._entries.items()
                   if key not in exclude
                   and all(int(cache.refcounts[p]) == 1 for p in pages))

    def evict(self, cache: PagedCache, n_pages: int,
              exclude: set[str] | None = None) -> int:
        """Drop least-recently-used fully-unaliased entries until ``n_pages``
        pages have been freed (or nothing evictable remains)."""
        exclude = exclude or set()
        freed = 0
        order = sorted(self._entries.items(), key=lambda kv: kv[1][1])
        for key, (pages, _) in order:
            if freed >= n_pages or key in exclude:
                continue
            if not all(int(cache.refcounts[p]) == 1 for p in pages):
                continue  # still aliased by a live slot
            for p in pages:
                cache.unref_page(p)
            freed += len(pages)
            del self._entries[key]
        return freed

    def cached_pages(self) -> int:
        return sum(len(pages) for pages, _ in self._entries.values())


# ---------------------------------------------------------------------------
# StatePool — the unified allocator
# ---------------------------------------------------------------------------


class StatePool:
    """Every per-slot decode byte of one non-paged family, in pooled planes.

    Plane decomposition by family (from ``model.cache_spec``):

    ===========  ==================  ==================  ====================
    family       attn-KV plane       cross-KV plane      state rings
    ===========  ==================  ==================  ====================
    ``ssm``      —                   —                   conv + h
    ``hybrid``   ``"attn"`` stacks   —                   conv + h (mamba2)
    ``encdec``   ``"self"``          ``"cross"``         —
    ``vlm``      ``"self"``          ``"cross"``         —
    ===========  ==================  ==================  ====================

    The engine talks ONLY to this class (admission/release/occupancy/
    invariants); the jitted steps get the raw plane pools and control arrays
    as operands and return updated pools the engine writes back.
    """

    def __init__(self, model: Model, *, n_slots: int, max_len: int,
                 page_size: int, kv_dtype: str = "mxfp4", debug: bool = False,
                 cross_headroom: int = 2):
        cfg = model.cfg
        if cfg.family not in STATE_FAMILIES:
            raise ValueError(
                f"StatePool covers {STATE_FAMILIES}, got {cfg.family!r} "
                f"(dense/moe use PagedCache directly)")
        if kv_dtype not in ("mxfp4", "dense"):
            raise ValueError(f"kv_dtype must be 'mxfp4' or 'dense', got {kv_dtype!r}")
        self.family = cfg.family
        self.n_slots, self.max_len, self.page_size = n_slots, max_len, page_size
        self.kv_dtype, self.debug = kv_dtype, debug
        self._dtype = jnp.dtype(cfg.dtype)

        spec = model.cache_spec(1, max_len)  # batch-1 shapes

        # -- attn-KV plane ---------------------------------------------------
        kv_key = {"hybrid": "attn", "encdec": "self", "vlm": "self"}.get(self.family)
        self.kv: PagedCache | None = None
        if kv_key is not None:
            k_spec = spec[kv_key][0]  # [L_kv, 1, max_len, Hkv, hd]
            L_kv, _, _, H, hd = k_spec.shape
            pps = -(-max_len // page_size)
            self.kv = PagedCache(
                None, n_slots=n_slots, pages_per_slot=pps, page_size=page_size,
                kv_dtype=kv_dtype, debug=debug,
                geometry=(L_kv, H, hd), dtype=k_spec.dtype)

        # -- cross-KV plane --------------------------------------------------
        self.cross: PagedCache | None = None
        self.cross_tokens = 0
        if self.family in ("encdec", "vlm"):
            c_spec = spec["cross"][0]  # [L_c, 1, T_src, Hkv, hd]
            L_c, _, T_src, Hc, hdc = c_spec.shape
            cpp = -(-T_src // page_size)
            # headroom beyond one set per slot keeps retired-but-cached
            # sources alive (CrossIndex pins) without wedging admission
            self.cross = PagedCache(
                None, n_slots=n_slots, pages_per_slot=cpp, page_size=page_size,
                n_pages=1 + (n_slots + cross_headroom) * cpp,
                kv_dtype=kv_dtype, debug=debug,
                geometry=(L_c, Hc, hdc), dtype=c_spec.dtype)
            self.cross_tokens = int(T_src)
        self.cross_index = CrossIndex()

        # -- state rings -----------------------------------------------------
        ring_sub = {"ssm": spec, "hybrid": spec.get("mamba") if isinstance(spec, dict) else None}.get(self.family)
        self.rings: tuple[RingPlane, ...] = ()
        self._ring_treedef = None
        if ring_sub is not None:
            leaves, self._ring_treedef = jax.tree.flatten(ring_sub)
            self.rings = tuple(
                RingPlane(f"ring{i}", (lf.shape[0], *lf.shape[2:]), lf.dtype,
                          n_slots, kv_dtype)
                for i, lf in enumerate(leaves))
        # host ring cursor, shared by every plane: read page id (0 = fresh/
        # zero) and which of the slot's RING_DEPTH pages is written next
        self.ring_read = np.zeros((n_slots,), np.int32)
        self.ring_cur = np.zeros((n_slots,), np.int32)
        self.ring_active = np.zeros((n_slots,), bool)

    # -- plane traversal -----------------------------------------------------

    def planes(self):
        """(kind, plane) pairs for telemetry sweeps."""
        if self.kv is not None:
            yield "attn_kv", self.kv
        if self.cross is not None:
            yield "cross_kv", self.cross
        for r in self.rings:
            yield "state_ring", r

    def pools(self) -> dict:
        """The jitted steps' device-state operand."""
        return {"kv": self.kv.pool if self.kv else None,
                "cross": self.cross.pool if self.cross else None,
                "rings": tuple(r.pool for r in self.rings)}

    def set_pools(self, state: dict) -> None:
        if self.kv is not None:
            self.kv.pool = state["kv"]
        if self.cross is not None:
            self.cross.pool = state["cross"]
        for r, p in zip(self.rings, state["rings"]):
            r.pool = p

    def unflatten_rings(self, leaves):
        return jax.tree.unflatten(self._ring_treedef, list(leaves))

    # -- admission / release -------------------------------------------------

    def can_admit(self, n_tokens: int, cross_shared: bool = False) -> bool:
        ok = True
        if self.kv is not None:
            ok &= self.kv.can_alloc(min(n_tokens, self.max_len))
        if self.cross is not None and not cross_shared:
            cpp = self.cross.pages_needed(self.cross_tokens)
            ok &= cpp <= (self.cross.free_pages
                          + self.cross_index.evictable_pages(self.cross))
        return ok

    def alloc(self, slot: int, n_tokens: int, cross_shared=()) -> None:
        """Map one admission: KV reservation pages, one cross page set
        (aliased from ``cross_shared`` when warm), and a reset ring cursor.
        Runs inline in the scheduler's transactional ``on_admit``."""
        if self.kv is not None:
            self.kv.alloc(slot, min(n_tokens, self.max_len))
        if self.cross is not None:
            need = self.cross.pages_needed(self.cross_tokens)
            shortfall = (need - len(cross_shared)) - self.cross.free_pages
            if shortfall > 0:
                self.cross_index.evict(self.cross, shortfall)
            self.cross.alloc(slot, self.cross_tokens, shared=cross_shared)
        self.ring_read[slot] = 0  # fresh state reads the zero sentinel
        self.ring_cur[slot] = 0
        self.ring_active[slot] = bool(self.rings)
        self._check()

    def free(self, slot: int) -> None:
        if self.kv is not None:
            self.kv.free(slot)
        if self.cross is not None:
            self.cross.free(slot)
        self.ring_read[slot] = 0
        self.ring_cur[slot] = 0
        self.ring_active[slot] = False
        self._check()

    # -- ring cursor ---------------------------------------------------------

    def ring_write_id(self, slot: int) -> int:
        return 1 + slot * RING_DEPTH + int(self.ring_cur[slot])

    def ring_ids(self, mask: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(read_ids, write_ids) [n_slots] for one batched step: masked-off
        lanes read the zero sentinel and write the scratch sentinel."""
        read = np.where(mask, self.ring_read, 0).astype(np.int32)
        write = np.array(
            [self.ring_write_id(s) if mask[s] else 0
             for s in range(self.n_slots)], np.int32)
        return read, write

    def ring_advance(self, mask: np.ndarray) -> None:
        """Commit one successful step for the masked slots: the page just
        written becomes the read page; the other ring page is written next."""
        if not self.rings:
            return
        for s in np.nonzero(mask)[0]:
            self.ring_read[s] = self.ring_write_id(int(s))
            self.ring_cur[s] ^= 1
        self._check()

    # -- cross sharing -------------------------------------------------------

    def cross_match(self, key: str | None, stamp: float) -> list[int]:
        return self.cross_index.match(key, stamp) if self.cross is not None else []

    def cross_publish(self, key: str | None, slot: int, stamp: float) -> int:
        if self.cross is None:
            return 0
        return self.cross_index.publish(self.cross, key,
                                        self.cross.tables[slot], stamp)

    # -- invariants ----------------------------------------------------------

    def _check(self) -> None:
        if self.debug:
            self.check_invariants()

    def check_invariants(self) -> None:
        """Every plane's allocator invariants plus the ring-cursor contract:
        the read page is either the zero sentinel or one of the slot's own
        ring pages (specifically the one the cursor wrote last), cursors are
        in range, and inactive slots hold the reset cursor."""
        if self.kv is not None:
            self.kv.check_invariants()
        if self.cross is not None:
            self.cross.check_invariants()
        for s in range(self.n_slots):
            cur, read = int(self.ring_cur[s]), int(self.ring_read[s])
            if cur not in range(RING_DEPTH):
                raise AssertionError(f"slot {s} ring cursor {cur} out of range")
            if not self.ring_active[s]:
                if read != 0 or cur != 0:
                    raise AssertionError(
                        f"inactive slot {s} has ring state read={read} cur={cur}")
                continue
            base = 1 + s * RING_DEPTH
            expect_read = 0 if read == 0 else base + ((cur - 1) % RING_DEPTH)
            if read not in (0, expect_read):
                raise AssertionError(
                    f"slot {s} ring read page {read} is not the sentinel or "
                    f"its own last-written page {expect_read}")

    # -- accounting / telemetry ----------------------------------------------

    def cache_bytes(self) -> int:
        return sum(p.cache_bytes() for _, p in self.planes())

    def bits_per_element(self) -> float:
        """Storage bits per logical state element across every plane."""
        elems = 0
        if self.kv is not None:
            elems += (self.kv.layers * self.kv.n_pages * self.kv.page_size
                      * self.kv.kv_heads * self.kv.head_dim * 2)
        if self.cross is not None:
            elems += (self.cross.layers * self.cross.n_pages * self.cross.page_size
                      * self.cross.kv_heads * self.cross.head_dim * 2)
        for r in self.rings:
            elems += r.padded * r.n_pages
        return self.cache_bytes() * 8 / elems if elems else 0.0

    def occupancy(self) -> float:
        """Aggregate live fraction over the paged planes (rings are statically
        mapped, so they count by active slots)."""
        live = free_like = 0
        for kind, p in self.planes():
            if kind == "state_ring":
                live += int(self.ring_active.sum()) * RING_DEPTH
                free_like += p.n_pages - 1
            else:
                live += p.live_pages()
                free_like += p.n_pages - 1
        return live / free_like if free_like else 0.0

    def plane_stats(self) -> dict[str, dict[str, float]]:
        """Per-tenant-kind page accounting for the telemetry gauges."""
        stats: dict[str, dict[str, float]] = {}
        if self.kv is not None:
            stats["attn_kv"] = {
                "pages_total": self.kv.n_pages - 1,
                "pages_free": self.kv.free_pages,
                "occupancy": self.kv.occupancy(),
            }
        if self.cross is not None:
            stats["cross_kv"] = {
                "pages_total": self.cross.n_pages - 1,
                "pages_free": self.cross.free_pages,
                "occupancy": self.cross.occupancy(),
            }
        if self.rings:
            active = int(self.ring_active.sum())
            total = sum(r.n_pages - 1 for r in self.rings)
            used = active * RING_DEPTH * len(self.rings)
            stats["state_ring"] = {
                "pages_total": total,
                "pages_free": total - used,
                "occupancy": used / total if total else 0.0,
            }
        return stats

    def ring_page_mask(self) -> np.ndarray:
        """[n_pages] bool over any single ring plane's pages (all planes share
        the static layout): True where the page holds a slot's CURRENT state
        — the quant-health sampling weight."""
        n_pages = 1 + self.n_slots * RING_DEPTH
        mask = np.zeros((n_pages,), bool)
        for s in range(self.n_slots):
            if self.ring_active[s] and int(self.ring_read[s]) != 0:
                mask[int(self.ring_read[s])] = True
        return mask

    def state_bytes_per_decode_step(self, n_tokens: int) -> int:
        """Persistent-state bytes ONE slot's decode step moves through this
        pool: packed KV pages read plus one token's packed write, the static
        cross pages read, and one ring page read + one written per plane."""
        total = 0
        if self.kv is not None:
            pb = self.kv.cache_bytes() // self.kv.n_pages
            pages = self.kv.pages_needed(min(n_tokens, self.max_len))
            total += pages * pb + pb // self.kv.page_size  # read + 1-token write
        if self.cross is not None:
            pb = self.cross.cache_bytes() // self.cross.n_pages
            total += self.cross.pages_needed(self.cross_tokens) * pb
        for r in self.rings:
            total += 2 * r.page_bytes()  # read current + write next
        return total

    def dense_state_bytes_per_decode_step(self, n_tokens: int) -> int:
        """The same step's traffic in the DenseSlotCache oracle: the FULL
        per-slot dense caches are read (dense attention has no length
        paging), one token's KV is written, and recurrent state is read and
        rewritten whole."""
        total = 0
        if self.kv is not None:
            kv = self.kv
            row = 2 * kv.layers * kv.kv_heads * kv.head_dim * self._dtype.itemsize
            total += row * self.max_len + row  # full read + 1-token write
        if self.cross is not None:
            c = self.cross
            total += (2 * c.layers * self.cross_tokens * c.kv_heads
                      * c.head_dim * self._dtype.itemsize)
        for r in self.rings:
            total += 2 * r.dense_bytes()
        return total
