"""Pluggable draft proposers for speculative decoding.

A proposer's job each spec tick: given the batch of DECODE-state requests,
return ``k`` drafted continuation tokens per slot.  The engine then scores
all drafts (plus the last accepted token) in ONE jitted verify call and
accepts the longest prefix the target model agrees with.

Three built-ins behind a string registry (``SpecConfig.proposer``):

* ``"self"``  — the target model drafts for itself via k sequential batched
  decode steps over the engine's own paged cache.  Costs the same FLOPs as
  plain decoding (plus the verify), so it is NOT a speedup — it is the
  **oracle**: greedy acceptance must be ≈100 % and engine outputs must stay
  token-exact vs the non-speculative engine, which pins the whole verify /
  rollback / accounting machinery.
* ``"ngram"`` — suffix match over the request's own prompt + generation
  (self-prompt speculation): find the most recent earlier occurrence of the
  trailing ``ngram`` tokens and propose what followed it.  Zero extra
  weights, zero device work; pays off on repetitive text.
* ``"draft"`` — a separate (small) registry model running in FP4 with its
  own :class:`~repro.serve.paged_cache.PagedCache`; drafts via k sequential
  decode steps on the draft cache.  The draft cache mirrors the target's
  slot lifecycle: admit → alloc (the full prompt+max_new reservation —
  nothing maps beyond it mid-flight, same contract as the target cache),
  accept → logical rollback of the synced length, retire → free.  A slot's
  context is lazily prefilled on its first spec tick — batched across slots
  through the same ``prefill_all`` step the engine uses, so draft-cache
  catch-up costs one jitted call per chunk-width regardless of how many
  slots are behind.

Custom proposers: subclass :class:`Proposer` and decorate with
``@register_proposer("name")``.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.serve.spec.config import SpecConfig

PROPOSERS: dict[str, type] = {}


def register_proposer(name: str):
    def deco(cls):
        PROPOSERS[name] = cls
        cls.name = name
        return cls
    return deco


def build_proposer(engine, spec: SpecConfig) -> "Proposer":
    if spec.proposer not in PROPOSERS:
        raise ValueError(f"unknown proposer {spec.proposer!r}; "
                         f"registered: {sorted(PROPOSERS)}")
    return PROPOSERS[spec.proposer](engine, spec)


class Proposer:
    """Base class: slot-lifecycle hooks + the propose call.

    ``propose`` returns an int32 ``[n_slots, k]`` array; only rows of
    decoding slots are read.  Hooks are invoked by the engine: ``on_admit``
    when a request takes a slot, ``on_accept`` after each verify tick's
    acceptance/rollback (request still running), ``on_retire`` when the slot
    is released.
    """

    def __init__(self, engine, spec: SpecConfig):
        self.engine, self.spec = engine, spec

    def on_admit(self, req) -> None:
        pass

    def on_accept(self, req) -> None:
        pass

    def on_retire(self, req) -> None:
        pass

    def propose(self, decoding: list) -> np.ndarray:
        raise NotImplementedError


def _draft_loop(engine, decoding, k, *, steps, pool_owner, params, tables):
    """k sequential batched decode steps → drafts [n_slots, k].

    Shared by the self- and draft-model proposers; ``pool_owner`` is the
    cache whose ``.pool`` is threaded through (the engine's own cache for
    self-speculation, the draft cache otherwise).  Draft draws reuse each
    request's sampler at the *same* token indices the verifier will re-draw,
    so a draft from bitwise-identical logits is always accepted.
    """
    B = engine.config.n_slots
    drafts = np.zeros((B, k), np.int32)
    cur = np.zeros((B, 1), np.int32)
    pos = np.zeros((B,), np.int32)
    mask = np.zeros((B,), bool)
    for r in decoding:
        cur[r.slot, 0] = r.tokens[-1]
        pos[r.slot] = r.prompt_len + len(r.tokens) - 1
        mask[r.slot] = True
    import jax.numpy as jnp
    tables_j, mask_j = jnp.asarray(tables), jnp.asarray(mask)
    for j in range(k):
        logits, pool_owner.pool = steps.decode_all(
            params, jnp.asarray(cur), jnp.asarray(pos + j),
            pool_owner.pool, tables_j, mask_j)
        logits_np = np.asarray(logits, np.float32)
        for r in decoding:
            tok = engine._sample(r, logits_np[r.slot], len(r.tokens) + j)
            drafts[r.slot, j] = tok
            cur[r.slot, 0] = tok
    engine.telemetry.registry.counter("draft_decode_calls").inc(k)
    return drafts


@register_proposer("self")
class SelfProposer(Proposer):
    """Target-model self-drafting: the parity / acceptance oracle.

    Drafting writes KV at positions ``p0 .. p0+k-1`` of the engine's own
    cache; the verify step rewrites the same positions with the same values
    before attending, so the pool state after the tick is exactly the
    verify's — identical to what non-speculative decoding would have
    written."""

    def propose(self, decoding):
        eng = self.engine
        return _draft_loop(eng, decoding, self.spec.k, steps=eng._steps,
                           pool_owner=eng.cache, params=eng.params,
                           tables=eng.cache.tables)


@register_proposer("ngram")
class NGramProposer(Proposer):
    """Self-prompt speculation: no weights, no device work.

    Proposes the continuation of the most recent earlier occurrence of the
    trailing n-gram in the request's own (prompt + generated) history;
    falls back to repeating the last token when no match exists."""

    def propose(self, decoding):
        k = self.spec.k
        drafts = np.zeros((self.engine.config.n_slots, k), np.int32)
        for r in decoding:
            ctx = np.concatenate([r.prompt, np.asarray(r.tokens, np.int32)])
            drafts[r.slot] = self._match(ctx, self.spec.ngram, k)
        return drafts

    @staticmethod
    def _match(ctx: np.ndarray, n: int, k: int) -> np.ndarray:
        out = np.full((k,), ctx[-1], np.int32)
        T = len(ctx)
        n = min(n, T - 1)
        if n < 1:
            return out
        suffix = ctx[T - n:]
        for s in range(T - n - 1, -1, -1):  # most recent match wins
            if np.array_equal(ctx[s:s + n], suffix):
                cont = ctx[s + n:s + n + k]
                out[:len(cont)] = cont
                break
        return out


@register_proposer("draft")
class DraftModelProposer(Proposer):
    """A small registry model in FP4 drafts; it owns a full paged cache.

    ``synced[slot]`` tracks how many context positions have valid KV in the
    draft cache.  A slot's context is prefilled lazily on its first spec
    tick — all behind slots together through the draft model's own batched
    ``prefill_all`` step (per-slot [1, C] / [1, 1] chunks only on the gather
    backend); after each verify tick ``on_accept`` shrinks ``synced`` in
    lock-step with the target's accepted length, so rejected draft KV is
    rewritten before any later proposal can see it.  Pages are mapped once
    at admission (the prompt+max_new reservation) and never beyond it:
    draft-loop writes past the budget redirect to the scratch page exactly
    as in the target cache.
    """

    def __init__(self, engine, spec):
        super().__init__(engine, spec)
        if spec.draft_arch is None:
            raise ValueError("SpecConfig.draft_arch is required for the "
                             "'draft' proposer")
        from repro.configs import get_config, get_reduced_config
        from repro.models import build_model
        from repro.serve.paged_cache import PagedCache, reservation_sizing
        from repro.serve.steps import build_paged_steps

        dcfg = (get_reduced_config(spec.draft_arch) if spec.draft_reduced
                else get_config(spec.draft_arch))
        if dcfg.family not in ("dense", "moe"):
            raise ValueError(f"draft model must be a paged family, got {dcfg.family!r}")
        self.model = build_model(dcfg)
        self.params = self.model.init(jax.random.PRNGKey(spec.draft_seed))
        ecfg = engine.config
        pages_per_slot, n_pages = reservation_sizing(
            ecfg.n_slots, ecfg.max_len, ecfg.page_size, spec.k)
        self.cache = PagedCache(
            self.model, n_slots=ecfg.n_slots, pages_per_slot=pages_per_slot,
            page_size=ecfg.page_size, n_pages=n_pages,
            kv_dtype=spec.draft_kv_dtype)
        self._steps = build_paged_steps(
            self.model, method=spec.draft_method, page_size=ecfg.page_size,
            n_layers=self.cache.layers,
            decode_backend="paged" if self.model.cfg.attn_backend == "paged" else "gather")
        self.synced = np.zeros((ecfg.n_slots,), np.int64)

    # -- slot lifecycle (mirrors the target cache) --------------------------

    def on_admit(self, req):
        self.cache.alloc(req.slot, req.prompt_len + req.max_new)
        self.synced[req.slot] = 0

    def on_accept(self, req):
        logical = req.prompt_len + len(req.tokens) - 1
        self.synced[req.slot] = min(int(self.synced[req.slot]), logical)

    def on_retire(self, req):
        self.cache.free(req.slot)
        self.synced[req.slot] = 0

    # -- drafting -----------------------------------------------------------

    def _sync_all(self, decoding) -> None:
        """Catch every behind slot's draft cache up to its context minus its
        last token (which the draft loop feeds itself) — batched: one
        ``prefill_all`` call per chunk-width advances ALL behind slots
        together (ragged tails padded + write-masked in the step)."""
        import jax.numpy as jnp

        targets = {r.slot: r.prompt_len + len(r.tokens) - 1 for r in decoding}
        # steady state (every tick after the first sync) exits before
        # materializing any context copies
        behind = [r for r in decoding
                  if int(self.synced[r.slot]) < targets[r.slot]]
        if not behind:
            return
        ctxs = {r.slot: np.concatenate([r.prompt, np.asarray(r.tokens, np.int32)])
                for r in behind}
        C = self.engine.config.prefill_chunk
        B = self.engine.config.n_slots
        if self._steps.prefill_all is None:  # gather oracle: per-slot chunks
            for r in behind:
                table_row = jnp.asarray(self.cache.tables[r.slot])
                have, p0 = int(self.synced[r.slot]), targets[r.slot]
                while have < p0:
                    step = C if p0 - have >= C else 1
                    toks = jnp.asarray(
                        ctxs[r.slot][have:have + step][None, :], jnp.int32)
                    _, self.cache.pool = self._steps.prefill_chunk(
                        self.params, toks, jnp.int32(have), table_row,
                        self.cache.pool)
                    self.engine.telemetry.registry.counter(
                        "draft_prefill_calls").inc()
                    have += step
                self.synced[r.slot] = have
            return
        from repro.serve.steps import marshal_prefill_batch

        while True:
            items = []
            for r in behind:
                have, p0 = int(self.synced[r.slot]), targets[r.slot]
                if have >= p0:
                    continue
                n = min(C, p0 - have)
                items.append((r.slot, have, ctxs[r.slot][have:have + n]))
            if not items:
                return
            tokens, start, n_valid, mask = marshal_prefill_batch(B, C, items)
            _, self.cache.pool = self._steps.prefill_all(
                self.params, jnp.asarray(tokens), jnp.asarray(start),
                jnp.asarray(n_valid), self.cache.pool,
                jnp.asarray(self.cache.tables), jnp.asarray(mask))
            self.engine.telemetry.registry.counter("draft_prefill_calls").inc()
            for r in behind:
                self.synced[r.slot] = min(self.synced[r.slot] + n_valid[r.slot],
                                          targets[r.slot])

    def propose(self, decoding):
        k = self.spec.k
        self._sync_all(decoding)
        drafts = _draft_loop(self.engine, decoding, k, steps=self._steps,
                             pool_owner=self.cache, params=self.params,
                             tables=self.cache.tables)
        for r in decoding:  # the draft loop fed k tokens from p0 onward
            self.synced[r.slot] = r.prompt_len + len(r.tokens) - 1 + k
        return drafts
