"""Host-side acceptance logic + per-request speculative accounting.

The device side of verification is one jitted call (built by
``train.serve.make_verify_step`` and wired up in ``serve.engine``); what
lives here is the pure-python part that is easy to reason about and unit
test: given the drafted tokens and the target model's (greedy or sampled)
draws at every drafted position, decide how many drafts survive and what
gets emitted.

Greedy / deterministic-draft acceptance rule: walk the drafted suffix
left-to-right, accept while the target's own draw at that position equals
the draft, and on the first mismatch emit the target's draw as the
correction token.  If every draft survives, the position after the last
draft yields a *bonus* token for free.  The emitted prefix is, by
construction, exactly what the non-speculative loop would have produced one
token at a time — speculation changes the schedule, never the tokens (the
engine's parity-oracle tests pin this token-for-token).
"""

from __future__ import annotations

from typing import Iterable, Sequence


def accept_tokens(draft: Sequence[int], target: Sequence[int]) -> tuple[int, list[int]]:
    """(drafted tokens [k], target draws [k+1]) → (n_accepted, emitted).

    ``target[i]`` is the token the target model itself picks after consuming
    the context up to and including draft ``i-1`` (``target[0]`` follows the
    last accepted token; ``target[k]`` is the bonus draw after draft k).
    ``emitted`` is 1..k+1 tokens: the accepted prefix, then either one
    correction (first mismatch) or the bonus token (all accepted).
    """
    if len(target) != len(draft) + 1:
        raise ValueError(
            f"target must carry len(draft)+1 draws, got {len(target)} for k={len(draft)}")
    n_acc, emitted = 0, []
    for d, t in zip(draft, target):
        emitted.append(int(t))
        if int(t) != int(d):
            return n_acc, emitted
        n_acc += 1
    emitted.append(int(target[-1]))
    return n_acc, emitted


def aggregate_stats(requests: Iterable) -> dict:
    """Fleet-level speculative accounting over finished requests.

    ``tokens_per_decode_call`` counts only decode-phase tokens (the prefill-
    produced first token rides on a prefill call): with speculation on and
    any acceptance at all it exceeds 1.0; the non-speculative engine sits at
    exactly 1.0 by construction.
    """
    reqs = list(requests)
    decode_tokens = sum(max(len(r.tokens) - 1, 0) for r in reqs)
    calls = sum(r.decode_calls for r in reqs)
    proposed = sum(r.draft_proposed for r in reqs)
    accepted = sum(r.draft_accepted for r in reqs)
    return {
        "requests": len(reqs),
        "decode_tokens": decode_tokens,
        "decode_calls": calls,
        "tokens_per_decode_call": round(decode_tokens / calls, 3) if calls else None,
        "drafts_proposed": proposed,
        "drafts_accepted": accepted,
        "acceptance_rate": round(accepted / proposed, 3) if proposed else None,
    }
