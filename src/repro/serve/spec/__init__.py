"""Speculative decoding over the paged MXFP4 KV cache.

Draft → batched verify → accept/rollback; see ``serve/README.md`` for the
proposer matrix and the acceptance / rollback semantics.
"""

from repro.serve.spec.config import SpecConfig
from repro.serve.spec.proposers import (
    PROPOSERS,
    DraftModelProposer,
    NGramProposer,
    Proposer,
    SelfProposer,
    build_proposer,
    register_proposer,
)
from repro.serve.spec.verify import accept_tokens, aggregate_stats

__all__ = [
    "SpecConfig",
    "Proposer",
    "SelfProposer",
    "NGramProposer",
    "DraftModelProposer",
    "PROPOSERS",
    "register_proposer",
    "build_proposer",
    "accept_tokens",
    "aggregate_stats",
]
