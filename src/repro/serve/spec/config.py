"""Speculative-decoding configuration.

``SpecConfig`` rides inside :class:`repro.serve.engine.EngineConfig` —
``EngineConfig(spec=SpecConfig(k=4, proposer="ngram"))`` turns every decode
tick of a paged-family engine into a draft → batched-verify → accept/rollback
cycle emitting between 1 and ``k + 1`` tokens per jitted verify call.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Frozen (hashable) so it can nest inside the frozen EngineConfig.

    ``k``        — drafted tokens per verify call; the verify step scores
                   ``k + 1`` tokens (last accepted token + the drafted
                   suffix) and emits 1..k+1 tokens.
    ``proposer`` — registry name: ``"self"`` (the target model drafts for
                   itself — the parity/acceptance oracle), ``"ngram"``
                   (suffix-match over the request's own prompt + generation;
                   no extra weights), or ``"draft"`` (a separate registry
                   model in FP4 with its own paged cache).
    """

    k: int = 4
    proposer: str = "self"
    # -- ngram proposer -----------------------------------------------------
    ngram: int = 2  # suffix length to match against the request's history
    # -- draft-model proposer -----------------------------------------------
    draft_arch: str | None = None  # registry arch name (required for "draft")
    draft_reduced: bool = True  # use the reduced registry config
    draft_kv_dtype: str = "mxfp4"  # draft model's own paged-KV dtype
    draft_method: str = "quartet"  # FP4 forward for the draft model
    draft_seed: int = 0  # draft param init seed

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec.k must be >= 1, got {self.k}")
        if self.ngram < 1:
            raise ValueError(f"spec.ngram must be >= 1, got {self.ngram}")
        if self.draft_kv_dtype not in ("mxfp4", "dense"):
            raise ValueError(
                f"draft_kv_dtype must be 'mxfp4' or 'dense', got {self.draft_kv_dtype!r}")
