"""Device placement for the serving engine: TP shard maps + DP replica policy.

This is the one module that knows about device topology on the serving side.
``Engine`` / ``Scheduler`` stay device-agnostic: they hand their jitted step
builders a :class:`Placement` and their admitted requests to a
:class:`ReplicaPlacer`, and never touch ``jax.devices()`` themselves.

Sharding contract (see serve/README.md "Multi-device serving"):

* the packed pool shards on the KV-head axis over a single ``('model',)``
  mesh axis (``distributed.sharding.serve_pool_partition``); page tables,
  tokens, and positions are replicated; weights are replicated (carve-out —
  serving TP here is KV/attention/expert parallelism, not weight sharding);
* each DP replica owns a disjoint ``tp``-device mesh
  (``launch.mesh.make_serve_meshes``) plus its own PagedCache, prefix cache,
  and telemetry registry — replicas never communicate;
* everything is exactness-preserving: head/expert slices + tiled all_gather
  concats only, never a cross-shard reduction, so a sharded engine emits
  bit-identical tokens to the single-device engine.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import serve_pool_partition
from repro.launch.mesh import make_serve_meshes


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """User-facing knob on :class:`~repro.serve.engine.EngineConfig`.

    ``tp`` shards each replica's pool/attention/experts over a ``('model',)``
    mesh; ``dp`` runs that many independent engine replicas on disjoint
    device groups (``serve.replica.ReplicatedEngine``)."""

    tp: int = 1
    dp: int = 1

    def __post_init__(self):
        if self.tp < 1 or self.dp < 1:
            raise ValueError(f"tp/dp must be >= 1, got tp={self.tp} dp={self.dp}")


class Placement:
    """One engine replica's device placement: a ``('model',)`` mesh of ``tp``
    devices plus helpers to put the pool (head-sharded) and everything else
    (replicated) onto it.  ``tp == 1`` is the no-op placement — no mesh is
    ever built, so single-device engines never touch device state here."""

    AXIS = "model"

    def __init__(self, tp: int = 1, mesh: Mesh | None = None):
        if tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        self.tp = tp
        if tp == 1:
            self.mesh = None
        else:
            self.mesh = mesh if mesh is not None else make_serve_meshes(tp, 1)[0]
            if self.mesh.size != tp:
                raise ValueError(
                    f"placement mesh has {self.mesh.size} devices, want tp={tp}")

    def pool_specs(self, pool):
        """Head-axis PartitionSpecs for a pool pytree (replicated if tp==1)."""
        if self.tp == 1:
            return jax.tree.map(lambda l: P(*([None] * l.ndim)), pool)
        return serve_pool_partition(pool, self.mesh)

    def shard_pool(self, pool):
        if self.tp == 1:
            return pool
        specs = self.pool_specs(pool)
        return jax.tree.map(
            lambda l, s: jax.device_put(l, NamedSharding(self.mesh, s)),
            pool, specs)

    def replicate(self, tree):
        """Replicate a pytree (params, tables, dense caches) over the mesh."""
        if self.tp == 1:
            return tree
        return jax.tree.map(
            lambda l: jax.device_put(
                l, NamedSharding(self.mesh, P(*([None] * l.ndim)))), tree)


class ReplicaPlacer:
    """Places admitted requests onto DP replicas from their local slot/page
    inventories: most free pages first (pages are the scarce, fragmenting
    resource), free slots break ties, round-robin breaks exact ties so equal
    replicas interleave instead of piling onto replica 0."""

    def __init__(self, n_replicas: int):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.n = n_replicas
        self._rr = 0

    def place(self, free_pages, free_slots) -> int:
        """free_pages/free_slots: per-replica inventories (len == n)."""
        assert len(free_pages) == self.n and len(free_slots) == self.n
        order = [(self._rr + i) % self.n for i in range(self.n)]
        best = max(order, key=lambda r: (free_pages[r], free_slots[r]))
        self._rr = (best + 1) % self.n
        return best
