"""Jitted device-step builders for paged-KV serving.

Extracted from ``Engine`` so the speculative draft-model proposer can run the
*same* decode / chunk-prefill / multi-token-verify machinery over its own
:class:`~repro.serve.paged_cache.PagedCache` without duplicating the masking
and scatter plumbing.  Each builder closes over a model and returns pure
functions of ``(params, …, pool, tables, mask)`` — device state in, device
state out; the caller owns the pool.

Four step kinds per paged model:

* ``decode_all``    — one token for every slot in one call (S == 1),
* ``prefill_all``   — one ``[n_slots, C]`` chunk for EVERY prefilling slot in
  one call, quantize-scattering each slot's tokens into its own pages and
  attending *directly over the packed pool* with per-slot start offsets and
  per-row causal bounds (paged backend only; ragged tails are padded and
  write-masked onto the scratch sentinel column —
  ``kernels.paged_attention.prefill_chunk_layout``),
* ``prefill_chunk`` — one slot's ``[1, C]`` prompt chunk via gather-
  dequantize to a dense view; survives as the ``decode_backend="gather"``
  prefill parity oracle (and the dense-slot families' shape),
* ``verify_all``    — S = k+1 tokens for every slot in one call: the
  speculative verify.  With ``decode_backend="paged"`` the drafted suffix is
  scored *directly over the packed MXFP4 pool* (multi-query paged-attention
  kernel, per-row causal bounds); ``"gather"`` materializes the dense view
  and survives as the parity oracle.

``prefill_all`` and ``verify_all`` are the same device computation at
different S: both feed the rows-unshared model with explicit per-token
positions and let the multi-query paged kernel apply per-row bounds.

Masked lanes follow the engine invariants: positions are clamped to 0 and
table rows zeroed, so writes land on the reserved scratch page and the
lane's logits are garbage that the host never reads; every step also passes
``token_valid`` into the model so padding lanes never compete for MoE expert
capacity (a garbage lane with a lucky router score must not displace a real
token from an expert's top-c selection).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.serve import paged_cache as P
from repro.train.serve import (
    _cast_params,
    make_chunk_prefill_step,
    make_decode_step,
    make_verify_step,
)


def marshal_prefill_batch(n_slots: int, chunk: int, items):
    """Host-side operand marshalling for one ``prefill_all`` call.

    ``items`` yields ``(slot, start, tokens_np)`` with
    ``1 <= len(tokens_np) <= chunk``; returns padded numpy operands
    ``(tokens [n_slots, chunk], start [n_slots], n_valid [n_slots],
    mask [n_slots])``.  The ONE definition of the padding/masking convention
    shared by the engine's prefill tick and the draft proposer's context
    sync — the device step relies on rows past ``n_valid`` being ignorable
    and on masked lanes being all-zero, so both callers must marshal
    identically.
    """
    tokens = np.zeros((n_slots, chunk), np.int32)
    start = np.zeros((n_slots,), np.int32)
    n_valid = np.zeros((n_slots,), np.int32)
    mask = np.zeros((n_slots,), bool)
    for slot, s0, toks in items:
        n = len(toks)
        tokens[slot, :n] = toks
        start[slot], n_valid[slot], mask[slot] = s0, n, True
    return tokens, start, n_valid, mask


class PagedSteps(NamedTuple):
    decode_all: Callable  # (params, tokens [B,1], positions [B], pool, tables, mask) -> (logits [B,V], pool)
    prefill_chunk: Callable  # (params, tokens [1,C], start, table_row, pool, extra) -> (logits [1,V], pool)
    verify_all: Callable  # (params, tokens [B,S], start [B], pool, tables, mask) -> (logits [B,S,V], pool)
    # (params, tokens [B,C], start [B], n_valid [B], pool, tables, mask)
    #   -> (last-valid-token logits [B,V], pool); None on the gather backend
    prefill_all: Callable | None

    def compile_counts(self) -> dict[str, int]:
        """Compiled-variant count per step function — the engine's
        one-compile-per-shape contract made observable (telemetry exports
        these as ``jit_compiled_*`` gauges; a compile storm shows up as a
        count > 1 on a fixed-shape step)."""
        return {name: jit_cache_size(fn) for name, fn in zip(self._fields, self)}


def jit_cache_size(fn) -> int:
    """Number of compiled variants a ``jax.jit`` callable holds (0 for None
    or when the private counter is unavailable on this jax version)."""
    if fn is None:
        return 0
    try:
        return int(fn._cache_size())
    except AttributeError:
        return 0


def build_paged_steps(model: Model, *, method: str, page_size: int,
                      n_layers: int, decode_backend: str = "paged",
                      placement=None, pool_example=None) -> PagedSteps:
    """``placement`` (serve.placement.Placement, tp > 1) makes every returned
    step a ``jax.jit(shard_map(...))`` over the placement's ``('model',)``
    mesh: the pool enters head-sharded (``pool_example`` supplies the leaf
    shapes for the PartitionSpecs), everything else replicated, and the model
    is rebuilt with ``cfg.tp_axis/tp_size`` set so its shape-based detection
    slices heads/experts inside the shard_map body.  ``check_rep=False``
    because GSPMD cannot see through the Pallas kernel; exactness is by
    construction (slices + tiled all_gather concats, no reductions)."""
    if decode_backend not in ("paged", "gather"):
        raise ValueError(f"decode_backend must be 'paged' or 'gather', "
                         f"got {decode_backend!r}")
    tp = placement.tp if placement is not None else 1
    if tp > 1:
        if pool_example is None:
            raise ValueError("tp > 1 needs pool_example for pool PartitionSpecs")
        import dataclasses

        from repro.models.registry import build_model

        model = build_model(dataclasses.replace(
            model.cfg, tp_axis=type(placement).AXIS, tp_size=tp))
    decode = make_decode_step(model, method=method)
    chunk = make_chunk_prefill_step(model, method=method)
    verify = make_verify_step(model, method=method)
    dtype = jnp.dtype(model.cfg.dtype)
    ps = page_size

    def _broadcast_tables(tables, mask):
        tbl = jnp.where(mask[:, None], tables, 0)
        return jnp.broadcast_to(tbl[None], (n_layers, *tbl.shape))

    if decode_backend == "paged":

        def decode_all(params, tokens, positions, pool, tables, mask):
            """One decode step for every slot, attending directly over the
            packed pool (no dense gather).  Masked lanes get an all-zero
            table row, so their quantize-on-write lands on the scratch page
            and their (meaningless) logits are discarded."""
            pos_safe = jnp.where(mask, positions, 0)
            paged = P.PagedKV(pool=pool, tables=_broadcast_tables(tables, mask))
            logits, new_caches, _ = decode(params, tokens, pos_safe, paged,
                                           token_valid=mask[:, None])
            return logits, new_caches.pool

        def verify_all(params, tokens, start, pool, tables, mask):
            """Score S = k+1 tokens per slot (last accepted + drafted suffix)
            in one call, directly over the packed pool: the multi-query paged
            kernel applies per-row causal bounds, so draft i only sees
            positions ≤ start + i."""
            pos_safe = jnp.where(mask, start, 0)
            paged = P.PagedKV(pool=pool, tables=_broadcast_tables(tables, mask))
            logits, new_caches = verify(
                params, tokens, pos_safe, paged,
                token_valid=jnp.broadcast_to(mask[:, None], tokens.shape))
            return logits, new_caches.pool

        def prefill_all(params, tokens, start, n_valid, pool, tables, mask):
            """Advance EVERY prefilling slot by one ragged [B, C] chunk in a
            single call over the packed pool — no dense gather, no per-slot
            loop, no [1, 1] remainder shape.  Tokens past a row's ``n_valid``
            are padding: ``prefill_chunk_layout`` positions them on the
            scratch sentinel column, so their quantize-on-write never touches
            live pages and their output rows are garbage the host ignores;
            ``token_valid`` keeps those padding lanes out of MoE expert-
            capacity competition, so routing (and therefore drop patterns at
            capacity-bound scale) is independent of batch padding.
            Returns each row's LAST VALID token logits (the only column the
            engine ever reads — it samples the first generated token from the
            final chunk)."""
            tbl = jnp.where(mask[:, None], tables, 0)
            C = tokens.shape[1]
            tbl_ext, positions = P.prefill_chunk_layout(
                tbl, start, n_valid, C, ps, mask)
            pos_safe = jnp.where(mask, start, 0)
            paged = P.PagedKV(
                pool=pool,
                tables=jnp.broadcast_to(tbl_ext[None], (n_layers, *tbl_ext.shape)))
            valid = mask[:, None] & (jnp.arange(C, dtype=jnp.int32)[None, :]
                                     < n_valid[:, None])
            logits, new_caches = verify(params, tokens, pos_safe, paged,
                                        positions=positions, token_valid=valid)
            last = logits[jnp.arange(tokens.shape[0]),
                          jnp.clip(n_valid - 1, 0, C - 1)]
            return last, new_caches.pool
    else:

        def decode_all(params, tokens, positions, pool, tables, mask):
            """Gather-dequantize parity oracle: materializes the dense
            [L, B, T, Hkv, hd] KV view each step."""
            pos_safe = jnp.where(mask, positions, 0)
            kv = P.gather_pages(pool, tables, dtype)
            logits, (k2, v2), _ = decode(params, tokens, pos_safe, kv,
                                         token_valid=mask[:, None])
            bidx = jnp.arange(tokens.shape[0])
            k_new = k2[:, bidx, pos_safe]  # [L, B, Hkv, hd]
            v_new = v2[:, bidx, pos_safe]
            page_ids = tables[bidx, pos_safe // ps]
            page_ids = jnp.where(mask, page_ids, 0)
            pool = P.scatter_tokens(pool, page_ids, pos_safe % ps, k_new, v_new)
            return logits, pool

        def verify_all(params, tokens, start, pool, tables, mask):
            """Gather-path verify oracle: dense view in, S written tokens
            scattered back per slot."""
            B, S = tokens.shape
            pos_safe = jnp.where(mask, start, 0)
            kv = P.gather_pages(pool, tables, dtype)
            logits, (k2, v2) = verify(
                params, tokens, pos_safe, kv,
                token_valid=jnp.broadcast_to(mask[:, None], tokens.shape))
            bidx = jnp.arange(B)
            positions = pos_safe[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
            k_new = k2[:, bidx[:, None], positions]  # [L, B, S, Hkv, hd]
            v_new = v2[:, bidx[:, None], positions]
            page_ids = tables[bidx[:, None], positions // ps]
            page_ids = jnp.where(mask[:, None], page_ids, 0)
            L_ = k_new.shape[0]
            pool = P.scatter_tokens(
                pool, page_ids.reshape(-1), (positions % ps).reshape(-1),
                k_new.reshape(L_, B * S, *k_new.shape[3:]),
                v_new.reshape(L_, B * S, *v_new.shape[3:]))
            return logits, pool

    def prefill_chunk(params, tokens, start, table_row, pool, extra=None):
        """tokens [1, C] at absolute positions start..start+C for the slot
        mapped by ``table_row`` → (last-token logits, pool)."""
        kv = P.gather_pages(pool, table_row[None], dtype)
        logits, (k2, v2), _ = chunk(
            params, tokens, jnp.full((1,), start, jnp.int32), kv, extra)
        C = tokens.shape[1]
        k_c = jax.lax.dynamic_slice_in_dim(k2, start, C, axis=2)[:, 0]
        v_c = jax.lax.dynamic_slice_in_dim(v2, start, C, axis=2)[:, 0]
        pos = start + jnp.arange(C)
        pool = P.scatter_tokens(pool, table_row[pos // ps], pos % ps, k_c, v_c)
        return logits, pool

    if tp > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS

        R = PS()  # replicated (pytree-prefix spec for params and scalars)
        pspec = placement.pool_specs(pool_example)

        def smap(fn, in_specs):
            return jax.jit(shard_map(fn, mesh=placement.mesh,
                                     in_specs=in_specs, out_specs=(R, pspec),
                                     check_rep=False))

        # pool position differs per step; everything else is replicated
        decode_sm = smap(decode_all, (R, R, R, pspec, R, R))
        verify_sm = smap(verify_all, (R, R, R, pspec, R, R))
        chunk_sm = smap(lambda p, t, s, tr, pool, extra:
                        prefill_chunk(p, t, s, tr, pool, extra),
                        (R, R, R, R, pspec, R))
        chunk_fn = lambda p, t, s, tr, pool, extra=None: chunk_sm(
            p, t, s, tr, pool, extra)
        if decode_backend == "paged":
            prefill_sm = smap(prefill_all, (R, R, R, R, pspec, R, R))
            return PagedSteps(decode_sm, chunk_fn, verify_sm, prefill_sm)
        return PagedSteps(decode_sm, chunk_fn, verify_sm, None)

    if decode_backend == "paged":
        return PagedSteps(jax.jit(decode_all), jax.jit(prefill_chunk),
                          jax.jit(verify_all), jax.jit(prefill_all))
    # gather oracle: prefill stays the per-slot [1, C] + [1, 1] chunk loop
    return PagedSteps(jax.jit(decode_all), jax.jit(prefill_chunk),
                      jax.jit(verify_all), None)


class StateSteps(NamedTuple):
    """Jitted steps for :class:`~repro.serve.state_pool.StatePool` serving —
    the non-attention families' counterpart of :class:`PagedSteps`.  One
    uniform signature per step regardless of which planes the family has
    (absent planes ride through as ``None`` operands)."""

    # (params, tokens [B,1], positions [B], state, kv_tables, cross_tables,
    #  ring_read [B], ring_write [B], mask [B]) -> (logits [B,V], state)
    decode_all: Callable
    # (params, tokens [1,C], start, state, kv_row, cross_row, ring_read [1],
    #  ring_write [1], extra) -> (last-token logits [1,V], state)
    prefill_chunk: Callable
    # (params, embeds [1,T,D], cross_row, cross_pool) -> cross_pool;
    # None for families without a cross plane (ssm / hybrid)
    encode_cross: Callable | None

    def compile_counts(self) -> dict[str, int]:
        """Same key set as :meth:`PagedSteps.compile_counts` so the telemetry
        ``jit_compiled_*`` gauge catalog is backend-independent: state-pool
        engines have no verify step, and the once-per-admission encode-cross
        step reports under the otherwise-unused ``prefill_all`` key."""
        return {"decode_all": jit_cache_size(self.decode_all),
                "prefill_chunk": jit_cache_size(self.prefill_chunk),
                "verify_all": 0,
                "prefill_all": jit_cache_size(self.encode_cross)}


def build_state_steps(model: Model, *, method: str, pool,
                      placement=None) -> StateSteps:
    """Step builders over a :class:`~repro.serve.state_pool.StatePool`.

    Each step assembles the family's dense cache tree FROM the pool planes
    (gather-dequantize KV/cross pages, gather state-ring pages), runs the
    unmodified ``train.serve`` decode/chunk step, and scatters the updated
    state back: the written KV token(s) quantize into their pages, each
    lane's whole recurrent state quantizes into its ring WRITE page, and the
    cross plane is never written outside :func:`encode_cross`.  Gathered
    views are sliced to their exact logical lengths (``max_len`` self-KV,
    ``cross_tokens`` cross-KV) before the model sees them — cross attention
    is non-causal, so an unsliced page-granular tail would be attended.

    Exactness contract: with ``kv_dtype="dense"`` planes hold bit-exact
    values, so every family is token-exact against the ``DenseSlotCache``
    oracle; enc-dec prefill runs ``build_cross=False`` (reads the pooled
    cross-KV written once at admission instead of re-running the encoder per
    chunk), while VLM prefill passes ``extra`` through so its cross k/v are
    recomputed fresh exactly like the oracle (``attention`` ropes q iff
    ``kv_source is None`` — reading the pool during VLM prefill would change
    the q rotation) and the returned cross cache is discarded.

    ``placement`` (tp > 1, enc-dec/VLM only) wraps every step in
    ``jax.jit(shard_map(...))`` with both paged planes sharded on the
    KV-head axis — same mesh contract as :func:`build_paged_steps`; the
    recurrent-state rings have no head axis and are rejected upstream by the
    engine."""
    family = model.cfg.family
    tp = placement.tp if placement is not None else 1
    if tp > 1:
        import dataclasses

        from repro.models.registry import build_model

        model = build_model(dataclasses.replace(
            model.cfg, tp_axis=type(placement).AXIS, tp_size=tp))
    decode = make_decode_step(model, method=method)
    chunk = make_chunk_prefill_step(model, method=method, build_cross=False)
    compute_dtype = jnp.dtype(model.cfg.dtype)
    ps = pool.page_size
    max_len, Ts = pool.max_len, pool.cross_tokens
    rings = pool.rings
    has_kv, has_cross = pool.kv is not None, pool.cross is not None

    def _gather_kv(state, tables):
        k, v = P.gather_pages(state["kv"], tables, compute_dtype)
        return k[:, :, :max_len], v[:, :, :max_len]

    def _gather_cross(state, tables):
        k, v = P.gather_pages(state["cross"], tables, compute_dtype)
        return k[:, :, :Ts], v[:, :, :Ts]

    def _gather_rings(state, read_ids):
        return pool.unflatten_rings(
            r.gather(p, read_ids) for r, p in zip(rings, state["rings"]))

    def assemble(state, kv_tables, cross_tables, ring_read):
        if family == "ssm":
            return _gather_rings(state, ring_read)
        if family == "hybrid":
            return {"attn": _gather_kv(state, kv_tables),
                    "mamba": _gather_rings(state, ring_read)}
        return {"self": _gather_kv(state, kv_tables),
                "cross": _gather_cross(state, cross_tables)}

    def kv_of(new_caches):
        return new_caches["attn"] if family == "hybrid" else new_caches["self"]

    def rings_of(new_caches):
        return new_caches if family == "ssm" else new_caches["mamba"]

    def _scatter_rings(state, new_sub, write_ids):
        pools = tuple(
            r.scatter(p, write_ids, leaf)
            for r, p, leaf in zip(rings, state["rings"], jax.tree.leaves(new_sub)))
        return {**state, "rings": pools}

    def decode_all(params, tokens, positions, state, kv_tables, cross_tables,
                   ring_read, ring_write, mask):
        """One decode token for every slot: dense views gathered from the
        planes, the family's unmodified decode step, then quantize-on-write
        scatter-back.  Masked lanes read the zero ring sentinel / their stale
        tables and write to page 0 (KV) and ring page 0 (state) — the host
        never advances their ring cursor, so their logical state is
        untouched, the exact analogue of the dense path's ``merge_masked``."""
        pos_safe = jnp.where(mask, positions, 0)
        caches = assemble(state, kv_tables, cross_tables, ring_read)
        # no token_valid: none of the state families has MoE capacity routing
        # (the ssm block does not even accept it), matching the dense oracle
        logits, new_caches, _ = decode(params, tokens, pos_safe, caches)
        if has_kv:
            k2, v2 = kv_of(new_caches)
            bidx = jnp.arange(tokens.shape[0])
            k_new = k2[:, bidx, pos_safe]  # [L_kv, B, Hkv, hd]
            v_new = v2[:, bidx, pos_safe]
            page_ids = jnp.where(mask, kv_tables[bidx, pos_safe // ps], 0)
            state = {**state, "kv": P.scatter_tokens(
                state["kv"], page_ids, pos_safe % ps, k_new, v_new)}
        if rings:
            state = _scatter_rings(state, rings_of(new_caches), ring_write)
        return logits, state

    def prefill_chunk(params, tokens, start, state, kv_row, cross_row,
                      ring_read, ring_write, extra=None):
        """One slot's [1, C] prompt chunk: self-KV for positions
        start..start+C quantize-scatters into the slot's pages, the whole
        updated recurrent state lands in the ring write page, and any cross
        cache the model returned is discarded (the pooled cross plane was
        written at admission and is read-only afterwards)."""
        caches = assemble(state,
                          None if kv_row is None else kv_row[None],
                          None if cross_row is None else cross_row[None],
                          ring_read)
        logits, new_caches, _ = chunk(
            params, tokens, jnp.full((1,), start, jnp.int32), caches, extra)
        C = tokens.shape[1]
        if has_kv:
            k2, v2 = kv_of(new_caches)
            k_c = jax.lax.dynamic_slice_in_dim(k2, start, C, axis=2)[:, 0]
            v_c = jax.lax.dynamic_slice_in_dim(v2, start, C, axis=2)[:, 0]
            pos = start + jnp.arange(C)
            state = {**state, "kv": P.scatter_tokens(
                state["kv"], kv_row[pos // ps], pos % ps, k_c, v_c)}
        if rings:
            state = _scatter_rings(state, rings_of(new_caches), ring_write)
        return logits, state

    encode_cross = None
    if has_cross:
        if family == "encdec":
            from repro.models.encdec import encode_cross_kv as ckv
        else:
            from repro.models.vlm import encode_cross_kv as ckv
        mcfg = model.cfg

        def encode_cross(params, embeds, cross_row, cross_pool):
            """Write one request's cross-KV into its cross-plane pages, ONCE
            (admission time): [1, T_src, D] conditioning → stacked per-layer
            (k, v) → quantize-scatter over the slot's cross page row.  Params
            are cast exactly as the chunk/decode steps cast them, so a dense
            plane round-trips bit-identically to what a ``build_cross=True``
            prefill would have attended over."""
            cparams = _cast_params(params, compute_dtype)
            ks, vs = ckv(cparams, embeds, mcfg, jnp.uint32(0), method)
            if tp > 1:
                # the plane shard holds Hkv/tp local heads; the projection
                # above computed all of them — keep this shard's slice
                local = next(iter(cross_pool.values())).shape[3]
                if local != ks.shape[3]:
                    r = jax.lax.axis_index(type(placement).AXIS)
                    ks = jax.lax.dynamic_slice_in_dim(ks, r * local, local, axis=3)
                    vs = jax.lax.dynamic_slice_in_dim(vs, r * local, local, axis=3)
            T = ks.shape[2]
            pos = jnp.arange(T)
            return P.scatter_tokens(cross_pool, cross_row[pos // ps], pos % ps,
                                    ks[:, 0], vs[:, 0])

    if tp > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS

        R = PS()
        sspec = placement.pool_specs(pool.pools())
        cspec = sspec["cross"]

        def smap(fn, in_specs, out_specs):
            return jax.jit(shard_map(fn, mesh=placement.mesh,
                                     in_specs=in_specs, out_specs=out_specs,
                                     check_rep=False))

        decode_sm = smap(decode_all, (R, R, R, sspec, R, R, R, R, R), (R, sspec))
        chunk_sm = smap(lambda p, t, s, st, kr, cr, rr, rw, extra:
                        prefill_chunk(p, t, s, st, kr, cr, rr, rw, extra),
                        (R, R, R, sspec, R, R, R, R, R), (R, sspec))
        chunk_fn = lambda p, t, s, st, kr, cr, rr, rw, extra=None: chunk_sm(
            p, t, s, st, kr, cr, rr, rw, extra)
        enc_sm = smap(encode_cross, (R, R, R, cspec), cspec)
        return StateSteps(decode_sm, chunk_fn, enc_sm)

    return StateSteps(jax.jit(decode_all), jax.jit(prefill_chunk),
                      jax.jit(encode_cross) if encode_cross else None)
