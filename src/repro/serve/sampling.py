"""Per-request sampling: temperature / top-k / top-p with a seeded PRNG.

One sampling implementation is shared by every decode path — the reference
``train.serve.greedy_generate`` loop, the engine's batched decode tick, and
the speculative verifier's accept/reject pass — so that, given bitwise-equal
logits, all of them draw the *same* token for the same (seed, row,
token_index) triple.  That determinism is what lets speculative decoding
stay token-exact against the non-speculative engine even at temperature > 0:
the verifier re-samples each drafted position with the position's own key
and accepts iff the draw matches the draft.

Key discipline: ``row_key(seed, row, t) = fold_in(fold_in(PRNGKey(seed),
row), t)`` where ``row`` is the batch row within a generate call (a single
engine request is always row 0) and ``t`` indexes generated tokens from 0
(the prefill-produced token).  No global stream — any path can sample token
``t`` without replaying tokens ``< t``.

``temperature == 0`` is greedy argmax and is the default everywhere; the
greedy paths never touch the PRNG, preserving the engine's existing
token-exact parity contracts bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode sampling.  Frozen + hashable → usable as a cache
    key for compiled samplers."""

    temperature: float = 0.0  # 0 → greedy argmax (default)
    top_k: int = 0  # 0 → disabled
    top_p: float = 1.0  # 1 → disabled
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def filter_logits(logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """[..., V] logits → temperature-scaled logits with non-top-k / non-
    nucleus entries pushed to -inf.  Pure jnp, differentiability irrelevant."""
    l = logits.astype(jnp.float32) / sp.temperature
    V = l.shape[-1]
    if sp.top_k and sp.top_k < V:
        kth = jax.lax.top_k(l, sp.top_k)[0][..., -1:]
        l = jnp.where(l >= kth, l, NEG_INF)
    if sp.top_p < 1.0:
        srt = jnp.flip(jnp.sort(l, axis=-1), axis=-1)
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # exclusive cumsum below top_p: the argmax token always survives
        keep = cum - probs < sp.top_p
        cutoff = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
        l = jnp.where(l >= cutoff, l, NEG_INF)
    return l


def row_key(seed, row, token_idx) -> jnp.ndarray:
    """Stateless per-token key: (request seed, batch row, generated-token
    index) → PRNG key.  ``token_idx`` counts generated tokens from 0.
    ``seed`` may be a traced value — the compiled samplers pass it as a
    runtime operand so distinct seeds share one executable."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), row), token_idx)


def sample_row(logits: jnp.ndarray, sp: SamplingParams, row, token_idx,
               seed=None) -> jnp.ndarray:
    """One row's token draw ([V] logits → scalar int32).  Traceable; the
    greedy branch resolves at trace time and never builds a key.  ``seed``
    overrides ``sp.seed`` (used to trace the seed as a runtime argument)."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = row_key(sp.seed if seed is None else seed, row, token_idx)
    return jax.random.categorical(key, filter_logits(logits, sp)).astype(jnp.int32)


# Compiled sampler cache, keyed by the DISTRIBUTION params only
# (temperature, top_k, top_p).  The seed is a runtime operand of the traced
# function — only these three change the computation graph, so a workload
# where every request carries its own seed (the normal case: distinct seeds
# decorrelate concurrent streams) still compiles exactly one sampler per
# distribution shape instead of one per request.
_COMPILED: dict[tuple, Any] = {}


def _compiled_sampler(sp: SamplingParams):
    dist = (sp.temperature, sp.top_k, sp.top_p)
    if dist not in _COMPILED:
        trace_sp = dataclasses.replace(sp, seed=0)  # seed unused at trace time
        _COMPILED[dist] = jax.jit(
            lambda logits, seed, t: sample_row(
                logits, trace_sp, jnp.int32(0), t, seed=seed))
    return _COMPILED[dist]


def compiled_sampler_cache_size() -> int:
    """Number of compiled (non-greedy) samplers held by the process — the
    regression guard for the one-compile-per-distribution contract."""
    return len(_COMPILED)


class Sampler:
    """Host-facing sampler for one ``SamplingParams``.

    ``sampler(logits, token_idx)`` → python int.  Greedy short-circuits to
    ``np.argmax`` on the host (identical tie-breaking to ``jnp.argmax``:
    first maximum wins) so the default path costs no device dispatch.
    Non-greedy draws share the per-distribution compiled function and feed
    their own seed at call time.
    """

    def __init__(self, sp: SamplingParams):
        self.sp = sp
        if not sp.greedy:
            self._fn = _compiled_sampler(sp)

    def __call__(self, logits, token_idx: int) -> int:
        if self.sp.greedy:
            return int(np.argmax(np.asarray(logits)))
        return int(self._fn(jnp.asarray(logits), jnp.uint32(self.sp.seed),
                            jnp.int32(token_idx)))


_SAMPLERS: dict[SamplingParams, Sampler] = {}


def get_sampler(sp: SamplingParams) -> Sampler:
    """Process-wide sampler cache.  Sampler objects are cheap host wrappers
    (one per SamplingParams); the expensive compiled function behind them is
    shared per (temperature, top_k, top_p)."""
    if sp not in _SAMPLERS:
        _SAMPLERS[sp] = Sampler(sp)
    return _SAMPLERS[sp]
