"""Per-request sampling: temperature / top-k / top-p with a seeded PRNG.

One sampling implementation is shared by every decode path — the reference
``train.serve.greedy_generate`` loop, the engine's batched decode tick, and
the speculative verifier's accept/reject pass — so that, given bitwise-equal
logits, all of them draw the *same* token for the same (seed, row,
token_index) triple.  That determinism is what lets speculative decoding
stay token-exact against the non-speculative engine even at temperature > 0:
the verifier re-samples each drafted position with the position's own key
and accepts iff the draw matches the draft.

Key discipline: ``row_key(seed, row, t) = fold_in(fold_in(PRNGKey(seed),
row), t)`` where ``row`` is the batch row within a generate call (a single
engine request is always row 0) and ``t`` indexes generated tokens from 0
(the prefill-produced token).  No global stream — any path can sample token
``t`` without replaying tokens ``< t``.

``temperature == 0`` is greedy argmax and is the default everywhere; the
greedy paths never touch the PRNG, preserving the engine's existing
token-exact parity contracts bit-for-bit.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode sampling.  Frozen + hashable → usable as a cache
    key for compiled samplers."""

    temperature: float = 0.0  # 0 → greedy argmax (default)
    top_k: int = 0  # 0 → disabled
    top_p: float = 1.0  # 1 → disabled
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def filter_logits(logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """[..., V] logits → temperature-scaled logits with non-top-k / non-
    nucleus entries pushed to -inf.  Pure jnp, differentiability irrelevant."""
    l = logits.astype(jnp.float32) / sp.temperature
    V = l.shape[-1]
    if sp.top_k and sp.top_k < V:
        kth = jax.lax.top_k(l, sp.top_k)[0][..., -1:]
        l = jnp.where(l >= kth, l, NEG_INF)
    if sp.top_p < 1.0:
        srt = jnp.flip(jnp.sort(l, axis=-1), axis=-1)
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # exclusive cumsum below top_p: the argmax token always survives
        keep = cum - probs < sp.top_p
        cutoff = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
        l = jnp.where(l >= cutoff, l, NEG_INF)
    return l


def row_key(seed: int, row, token_idx) -> jnp.ndarray:
    """Stateless per-token key: (request seed, batch row, generated-token
    index) → PRNG key.  ``token_idx`` counts generated tokens from 0."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), row), token_idx)


def sample_row(logits: jnp.ndarray, sp: SamplingParams, row, token_idx) -> jnp.ndarray:
    """One row's token draw ([V] logits → scalar int32).  Traceable; the
    greedy branch resolves at trace time and never builds a key."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = row_key(sp.seed, row, token_idx)
    return jax.random.categorical(key, filter_logits(logits, sp)).astype(jnp.int32)


class Sampler:
    """Host-facing compiled sampler for one ``SamplingParams``.

    ``sampler(logits, token_idx)`` → python int.  Greedy short-circuits to
    ``np.argmax`` on the host (identical tie-breaking to ``jnp.argmax``:
    first maximum wins) so the default path costs no device dispatch.
    """

    def __init__(self, sp: SamplingParams):
        self.sp = sp
        if not sp.greedy:
            self._fn = jax.jit(
                lambda logits, t: sample_row(logits, sp, jnp.int32(0), t))

    def __call__(self, logits, token_idx: int) -> int:
        if self.sp.greedy:
            return int(np.argmax(np.asarray(logits)))
        return int(self._fn(jnp.asarray(logits), jnp.int32(token_idx)))


_SAMPLERS: dict[SamplingParams, Sampler] = {}


def get_sampler(sp: SamplingParams) -> Sampler:
    """Process-wide sampler cache — one compile per distinct SamplingParams."""
    if sp not in _SAMPLERS:
        _SAMPLERS[sp] = Sampler(sp)
    return _SAMPLERS[sp]
