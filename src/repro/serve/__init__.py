"""Continuous-batching serving engine with a paged (optionally MXFP4) KV cache."""

from repro.serve.engine import Engine, EngineConfig
from repro.serve.paged_cache import DenseSlotCache, PagedCache, PagedKV
from repro.serve.scheduler import Request, RequestState, Scheduler

__all__ = [
    "Engine",
    "EngineConfig",
    "PagedCache",
    "PagedKV",
    "DenseSlotCache",
    "Request",
    "RequestState",
    "Scheduler",
]
