"""Continuous-batching serving engine with a paged (optionally MXFP4) KV cache."""

from repro.serve.engine import Engine, EngineConfig
from repro.serve.paged_cache import DenseSlotCache, PagedCache
from repro.serve.scheduler import Request, RequestState, Scheduler

__all__ = [
    "Engine",
    "EngineConfig",
    "PagedCache",
    "DenseSlotCache",
    "Request",
    "RequestState",
    "Scheduler",
]
