"""Continuous-batching serving engine with a paged (optionally MXFP4) KV
cache, per-request sampling, speculative decoding, and built-in telemetry."""

from repro.serve.engine import Engine, EngineConfig
from repro.serve.paged_cache import DenseSlotCache, PagedCache, PagedKV
from repro.serve.placement import Placement, ReplicaPlacer, ShardingConfig
from repro.serve.prefix_cache import PrefixIndex
from repro.serve.replica import ReplicatedEngine, make_engine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.serve.spec import SpecConfig
from repro.serve.state_pool import CrossIndex, StatePool
from repro.serve.telemetry import EngineTelemetry, MetricsRegistry, TelemetryConfig

__all__ = [
    "Engine",
    "EngineConfig",
    "Placement",
    "ReplicaPlacer",
    "ReplicatedEngine",
    "ShardingConfig",
    "make_engine",
    "PagedCache",
    "PagedKV",
    "PrefixIndex",
    "DenseSlotCache",
    "StatePool",
    "CrossIndex",
    "Request",
    "RequestState",
    "Scheduler",
    "SamplingParams",
    "SpecConfig",
    "TelemetryConfig",
    "EngineTelemetry",
    "MetricsRegistry",
]
