"""Radix prefix index over the packed (MXFP4) KV pool.

Requests that share a prompt prefix — N users × one system prompt, or
conversation continuations — produce **bit-identical** KV pages, because the
pool's MXFP4 packing is deterministic quantize-on-write (same tokens at the
same positions ⇒ same E2M1 codes + E8M0 scales; dense pools trivially so).
That makes aliasing safe: a new request can map already-written physical
pages into its own page table and skip re-prefilling them entirely.

The index is a radix trie keyed on **page-sized token chunks**: each node
owns exactly one physical page and the ``page_size`` token ids whose KV it
holds; a node's path from the root spells the full token prefix, so two
prompts share a node only when their ENTIRE prefix up to that page matches
(KV at position p depends on all positions ≤ p — matching the chunk alone
would be unsound).  Only fully-written pages are ever indexed or aliased:
partial tail pages are re-prefilled by the admitting request through the
scratch-sentinel write-mask machinery, never shared.

Page lifetime is reference-counted by :class:`~repro.serve.paged_cache.
PagedCache`: the index pins each cached page with one external reference
(``ref_page``), every slot that aliases it adds another, and the physical
page returns to the free list only when the last holder lets go.  Under pool
pressure the engine evicts least-recently-matched leaves (``evict``) until
admission fits; evicting a node whose page some slot still maps merely drops
the index's pin (the page frees later, when the slot retires).
"""

from __future__ import annotations

import numpy as np


class _Node:
    """One cached page: ``key`` is the page's token chunk (bytes of
    ``page_size`` int32 ids), ``page`` its physical page id, ``stamp`` the
    last time the node was matched or inserted (LRU eviction order)."""

    __slots__ = ("key", "page", "stamp", "parent", "children")

    def __init__(self, key: bytes, page: int, stamp: float, parent):
        self.key, self.page, self.stamp = key, page, stamp
        self.parent = parent
        self.children: dict[bytes, _Node] = {}


class PrefixIndex:
    """Host-side radix trie mapping token prefixes to pool page ids."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._root = _Node(b"", 0, 0.0, None)  # sentinel, owns no page
        self._n_nodes = 0

    # -- helpers ------------------------------------------------------------

    def _chunks(self, tokens: np.ndarray, n_pages: int):
        ps = self.page_size
        tokens = np.ascontiguousarray(tokens, np.int32)
        for i in range(n_pages):
            yield i, tokens[i * ps:(i + 1) * ps].tobytes()

    def _iter_nodes(self):
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    def cached_pages(self) -> int:
        """Nodes in the index == physical pages it pins (1:1)."""
        return self._n_nodes

    # -- admission-side API --------------------------------------------------

    def match(self, tokens: np.ndarray, stamp: float) -> list[int]:
        """Longest cached chain of FULL pages prefixing ``tokens`` → their
        page ids, root-first.  Touches every matched node's LRU stamp.  The
        caller aliases these pages (``PagedCache.alloc(shared=...)``) and
        prefills only the uncovered tail."""
        out: list[int] = []
        node = self._root
        for _, key in self._chunks(tokens, len(tokens) // self.page_size):
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = stamp
            out.append(child.page)
            node = child
        return out

    def evictable_pages(self, cache, exclude=()) -> int:
        """Pages eviction could return to the free list right now: cached
        nodes whose page has no holder besides the index's own pin
        (refcount == 1) and is not in ``exclude`` (a match about to be
        aliased must not be counted as reclaimable)."""
        exclude = set(exclude)
        return sum(1 for nd in self._iter_nodes()
                   if nd.page not in exclude and int(cache.refcounts[nd.page]) == 1)

    def evict(self, cache, n_pages: int, exclude=()) -> int:
        """LRU-evict leaves until ``n_pages`` pages have returned to the free
        list (or nothing evictable remains); returns pages actually freed.
        Leaf-first keeps every surviving node reachable from the root; a
        dropped node whose page a live slot still maps frees no page now but
        unblocks its ancestors for the next pass.  ``exclude`` pins pages
        (the admission match being aliased)."""
        exclude = set(exclude)
        freed = 0
        while freed < n_pages:
            leaf = None
            for nd in self._iter_nodes():
                if nd.children or nd.page in exclude:
                    continue
                if leaf is None or nd.stamp < leaf.stamp:
                    leaf = nd
            if leaf is None:
                break
            del leaf.parent.children[leaf.key]
            self._n_nodes -= 1
            if cache.unref_page(leaf.page):
                freed += 1
        return freed

    # -- publish-side API ----------------------------------------------------

    def insert(self, cache, tokens: np.ndarray, table_row, stamp: float) -> int:
        """Publish a slot's fully-written pages: walk the chain for
        ``tokens`` (only ``len(tokens) // page_size`` FULL pages), creating
        missing nodes from ``table_row``'s page ids and pinning each new page
        with ``cache.ref_page``.  Existing nodes keep their page — same chain
        means same full prefix, and deterministic quantize-on-write makes the
        payloads bit-identical.  Returns pages newly inserted."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        node, added = self._root, 0
        for i, key in self._chunks(tokens, len(tokens) // self.page_size):
            child = node.children.get(key)
            if child is None:
                pid = int(table_row[i])
                if pid == 0:
                    break  # slot doesn't map this page — nothing to publish
                cache.ref_page(pid)
                child = _Node(key, pid, stamp, node)
                node.children[key] = child
                self._n_nodes += 1
                added += 1
            else:
                child.stamp = stamp
            node = child
        return added
