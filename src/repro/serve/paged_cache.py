"""Paged KV storage for the continuous-batching engine.

Two cache backends behind one interface:

* :class:`PagedCache` — attention-KV families (``dense``/``moe``): a global
  pool of fixed-size pages ``[L, n_pages, page_size, Hkv, hd]`` with a host-
  side free-list allocator and per-slot page tables.  In ``kv_dtype="mxfp4"``
  mode pages hold the *real* 4.25-bit payload (packed E2M1 nibble codes +
  E8M0 scale-exponent bytes, via ``core.quantizers.kv_quantize``); the
  ``"dense"`` mode stores the model compute dtype for parity testing.
  Quantize happens once per token on write; gather dequantizes pages into the
  stacked dense cache layout the model's decode step already consumes.

* :class:`DenseSlotCache` — families whose decode state is not positional KV
  (SSM conv+ssm states, hybrid, enc-dec / VLM cross caches): one dense cache
  slot per sequence, preallocated at ``max_len``, with per-slot slice /
  write-back / reset helpers.  These schedule identically; they just don't
  page.

Page id 0 is reserved as a scratch page: masked (inactive) decode lanes
redirect their writes there, so one jitted decode step can cover every slot
without corrupting sequences that are still prefilling.  Stale page contents
are never zeroed — causal attention masks every position greater than the
querying token's, and a sequence writes position ``p`` before any of its
queries reach ``p``, so garbage is unreachable by construction.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import quantizers as Q
from repro.kernels.paged_attention import (  # noqa: F401  (re-exports)
    PagedKV,
    prefill_chunk_layout,
    quant_fmt as _quant_fmt,
    scatter_token,
)
from repro.models.registry import Model

# ---------------------------------------------------------------------------
# pure (jit-traceable) pool ops
# ---------------------------------------------------------------------------


def quantize_kv(x: jnp.ndarray) -> Q.PackedQuant:
    """[..., hd] values → packed MXFP4 payload (codes [..., hd/2] u8,
    scale codes [..., hd/block] u8)."""
    return Q.kv_quantize(x, _quant_fmt(x.shape[-1]))


def dequantize_kv(codes: jnp.ndarray, scales: jnp.ndarray, dtype) -> jnp.ndarray:
    """Packed payload → [..., hd] values in the model compute dtype."""
    hd = codes.shape[-1] * 2
    return Q.kv_dequantize(Q.PackedQuant(codes, scales), _quant_fmt(hd), dtype)


def gather_pages(pool: dict, tables: jnp.ndarray, dtype) -> tuple[jnp.ndarray, jnp.ndarray]:
    """pool pages → dense stacked KV caches.

    tables [B, n_pages_per_slot] int32 → (k, v) [L, B, T, Hkv, hd] with
    T = n_pages_per_slot · page_size, dequantizing if the pool is packed.

    Used only by the ``decode_backend="gather"`` parity oracle (per-slot
    chunked prefill and gather decode/verify); the default paged backend —
    batched decode, verify AND batched prefill — attends directly over the
    packed pool (``kernels/paged_attention``) and never materializes this
    dense view.
    """

    def one(codes, scales=None):
        g = codes[:, tables]  # [L, B, np, ps, H, hd?]
        if scales is None:
            return g.reshape(*g.shape[:2], -1, *g.shape[4:])
        s = scales[:, tables]
        vals = dequantize_kv(g, s, dtype)
        return vals.reshape(*vals.shape[:2], -1, *vals.shape[4:])

    if "k" in pool:  # dense mode
        return one(pool["k"]), one(pool["v"])
    return (one(pool["k_codes"], pool["k_scales"]),
            one(pool["v_codes"], pool["v_scales"]))


def scatter_tokens(pool: dict, page_ids: jnp.ndarray, offsets: jnp.ndarray,
                   k_new: jnp.ndarray, v_new: jnp.ndarray) -> dict:
    """Write one token per (page, offset) pair into the pool.

    page_ids/offsets [N]; k_new/v_new [L, N, Hkv, hd].  Quantize-on-write in
    packed mode.  Duplicate (page, offset) pairs (masked lanes redirected to
    the scratch page) resolve arbitrarily — scratch contents are never read.
    """
    if "k" in pool:
        k_store = k_new.astype(pool["k"].dtype)
        v_store = v_new.astype(pool["v"].dtype)
        return {
            "k": pool["k"].at[:, page_ids, offsets].set(k_store),
            "v": pool["v"].at[:, page_ids, offsets].set(v_store),
        }
    kq, vq = quantize_kv(k_new), quantize_kv(v_new)
    return {
        "k_codes": pool["k_codes"].at[:, page_ids, offsets].set(kq.codes),
        "k_scales": pool["k_scales"].at[:, page_ids, offsets].set(kq.scales),
        "v_codes": pool["v_codes"].at[:, page_ids, offsets].set(vq.codes),
        "v_scales": pool["v_scales"].at[:, page_ids, offsets].set(vq.scales),
    }


@jax.jit
def copy_page(pool: dict, src: jnp.ndarray, dst: jnp.ndarray) -> dict:
    """Copy one physical page's full payload ``src`` → ``dst`` across every
    layer and stream (packed E2M1 codes + E8M0 scales, or dense k/v) — the
    copy-on-write primitive.  ``src``/``dst`` are runtime int32 operands, so
    one compile covers every COW the pool ever performs."""
    return {name: arr.at[:, dst].set(arr[:, src]) for name, arr in pool.items()}


def reservation_sizing(n_slots: int, max_len: int, page_size: int,
                       spec_k: int = 0) -> tuple[int, int]:
    """``(pages_per_slot, n_pages)`` under the admission-reservation contract
    — the ONE sizing rule shared by the engine's target cache and the draft
    proposer's mirror cache (they must not drift: the no-OOM contract rests
    on it).

    Page-table WIDTH carries ``+spec_k`` sentinel-capacity columns so a
    speculative burst's beyond-budget positions index the table in bounds
    (their entries are never mapped, redirecting writes to scratch page 0);
    the POOL holds exactly one full reservation of
    ``ceil(max_len / page_size)`` pages per slot plus the scratch page —
    mapped pages never exceed a request's admission reservation, so no +k
    pool headroom exists or is needed."""
    pages_per_slot = -(-(max_len + spec_k) // page_size)
    n_pages = 1 + n_slots * (-(-max_len // page_size))
    return pages_per_slot, n_pages


# ---------------------------------------------------------------------------
# PagedCache (attention-KV families)
# ---------------------------------------------------------------------------


class PagedCache:
    """Fixed-size KV pages + free-list allocator + per-slot page tables.

    Device state (``self.pool``) is a dict of jnp arrays and is only mutated
    through the pure functions above (the engine threads it through its jitted
    steps).  Allocator state (free list, page tables) is host-side numpy —
    tables are passed into jitted functions as ordinary int32 operands.
    """

    def __init__(self, model: Model | None, *, n_slots: int, pages_per_slot: int,
                 page_size: int, n_pages: int | None = None,
                 kv_dtype: str = "mxfp4", debug: bool = False,
                 geometry: tuple[int, int, int] | None = None,
                 dtype=None):
        """``geometry=(layers, kv_heads, head_dim)`` (with ``dtype``) sizes the
        pool explicitly instead of via ``model.cache_spec`` — how
        :class:`~repro.serve.state_pool.StatePool` carves attention-KV and
        cross-KV planes out of families whose cache tree is NOT a plain
        stacked (k, v) pair (enc-dec, VLM, hybrid).  The family gate applies
        only to the model-derived path: an explicit geometry is, by
        construction, a positional-KV plane."""
        if geometry is None:
            cfg = model.cfg
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"PagedCache supports attention-KV families, got {cfg.family!r} "
                    f"(non-attention families carve planes via explicit geometry=)")
            spec_k, _ = model.cache_spec(1, page_size)  # [L, 1, ps, Hkv, hd]
            L, _, _, H, hd = spec_k.shape
            dtype = cfg.dtype
        else:
            L, H, hd = geometry
            if dtype is None:
                raise ValueError("explicit geometry= needs an explicit dtype=")
        if kv_dtype not in ("mxfp4", "dense"):
            raise ValueError(f"kv_dtype must be 'mxfp4' or 'dense', got {kv_dtype!r}")
        if hd % 2 != 0:
            raise ValueError(f"head dim {hd} must be even for nibble packing")
        # page 0 is the reserved scratch page
        n_pages = n_pages if n_pages is not None else 1 + n_slots * pages_per_slot
        self.n_slots, self.page_size = n_slots, page_size
        self.pages_per_slot, self.n_pages = pages_per_slot, n_pages
        self.kv_dtype = kv_dtype
        self.layers, self.kv_heads, self.head_dim = L, H, hd
        self._dtype = jnp.dtype(dtype)
        nb = hd // _quant_fmt(hd).block
        if kv_dtype == "dense":
            shape = (L, n_pages, page_size, H, hd)
            self.pool = {"k": jnp.zeros(shape, self._dtype),
                         "v": jnp.zeros(shape, self._dtype)}
        else:
            cshape = (L, n_pages, page_size, H, hd // 2)
            sshape = (L, n_pages, page_size, H, nb)
            self.pool = {"k_codes": jnp.zeros(cshape, jnp.uint8),
                         "k_scales": jnp.zeros(sshape, jnp.uint8),
                         "v_codes": jnp.zeros(cshape, jnp.uint8),
                         "v_scales": jnp.zeros(sshape, jnp.uint8)}
        self._free = list(range(n_pages - 1, 0, -1))  # pop() hands out low ids first
        self.tables = np.zeros((n_slots, pages_per_slot), np.int32)
        # physical-page reference counts: a page may be mapped by MANY slot
        # tables (prefix sharing) and pinned by external holders (the radix
        # prefix index) — it returns to the free list only at refcount zero.
        self.refcounts = np.zeros((n_pages,), np.int32)
        self._external = np.zeros((n_pages,), np.int32)  # non-table pins
        self.debug = debug  # run check_invariants after every mutate

    # -- allocator ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_alloc(self, n_tokens: int) -> bool:
        n = self.pages_needed(n_tokens)
        return n <= min(len(self._free), self.pages_per_slot)

    def _take_fresh(self) -> int:
        """Pop a page off the free list with refcount 1 (sole owner)."""
        pid = self._free.pop()
        if self.refcounts[pid] != 0:
            raise RuntimeError(f"free-list page {pid} has refcount "
                               f"{self.refcounts[pid]} != 0")
        self.refcounts[pid] = 1
        return pid

    def _decref(self, pid: int) -> bool:
        """Drop one reference; True if the page returned to the free list.
        Callers re-sort the free list after a batch of decrefs."""
        rc = int(self.refcounts[pid]) - 1
        if rc < 0:
            raise RuntimeError(f"refcount underflow on page {pid}")
        self.refcounts[pid] = rc
        if rc == 0:
            self._free.append(pid)
            return True
        return False

    def alloc(self, slot: int, n_tokens: int, shared=()) -> None:
        """Map enough pages onto ``slot`` to hold ``n_tokens`` positions.

        ``shared`` is an optional sequence of LIVE page ids (a radix-index
        prefix match) aliased at the front of the table row instead of fresh
        pages — each gains a reference; only the remainder pops the free
        list.  A slot that still carries live mappings is freed first —
        zeroing the table row without dropping its references would silently
        leak pages if the engine's alloc/free ordering ever regresses,
        shrinking the pool until admission wedges.  Page conservation
        (live + free == n_pages - 1) therefore survives re-alloc."""
        n = self.pages_needed(n_tokens)
        if n > self.pages_per_slot:
            raise ValueError(f"{n_tokens} tokens need {n} pages > pages_per_slot={self.pages_per_slot}")
        shared = [int(p) for p in shared]
        if len(shared) > n:
            raise ValueError(f"{len(shared)} shared pages > {n} pages needed")
        if self.tables[slot].any():
            self.free(slot)
        if n - len(shared) > len(self._free):
            raise RuntimeError(
                f"out of pages: need {n - len(shared)}, free {len(self._free)}")
        for i, pid in enumerate(shared):
            if pid == 0 or self.refcounts[pid] <= 0:
                raise ValueError(f"cannot alias dead/scratch page {pid}")
            self.tables[slot, i] = pid
            self.refcounts[pid] += 1
        for i in range(len(shared), n):
            self.tables[slot, i] = self._take_fresh()
        self._check()

    def free(self, slot: int) -> None:
        for pid in self.tables[slot]:
            if pid != 0:
                self._decref(int(pid))
        # keep the free list sorted (descending) so the low-ids-first contract
        # of pop() survives out-of-order retirement — allocation stays
        # deterministic under any admission/finish interleaving
        self._free.sort(reverse=True)
        self.tables[slot] = 0
        self._check()

    def mapped_pages(self, slot: int) -> int:
        """Pages currently mapped onto ``slot`` (alloc/ensure fill from index
        0 and truncate frees from the tail, so nonzero entries are a prefix)."""
        return int(np.count_nonzero(self.tables[slot]))

    def mapped_total(self) -> int:
        """Pages mapped across ALL slots.  Page conservation means
        ``mapped_total() + free_pages == n_pages - 1`` (scratch excluded)."""
        return int(np.count_nonzero(self.tables))

    def occupancy(self) -> float:
        """Live fraction of the allocatable pool (scratch page excluded) —
        the telemetry ``pool_occupancy`` gauge.  Counts physical pages, so
        prefix-shared pages contribute once however many slots alias them."""
        allocatable = self.n_pages - 1
        return self.live_pages() / allocatable if allocatable else 0.0

    def live_pages(self) -> int:
        """Physical pages with at least one reference (slot table or external
        pin).  Conservation: ``live_pages() + free_pages == n_pages - 1``
        always — unlike ``mapped_total()``, which double-counts a page
        aliased by several slots."""
        return int((self.refcounts > 0).sum())

    def page_mask(self) -> np.ndarray:
        """[n_pages] bool — True where the page is live (referenced by a slot
        table or an external pin such as the prefix index).  The runtime
        operand of the telemetry pool-health reduction (scratch page 0 is
        never referenced, so it is never counted)."""
        return self.refcounts > 0

    def ensure(self, slot: int, n_tokens: int) -> int:
        """Extend ``slot``'s mapping to cover ``n_tokens`` positions (no-op if
        already covered); returns pages added.  Allocator primitive: the
        engine itself never maps beyond a request's admission reservation
        mid-flight (that is the "reserved up front so decode never OOMs"
        contract — speculative writes past the budget redirect to the
        scratch page instead of mapping headroom on demand)."""
        need = self.pages_needed(n_tokens)
        if need > self.pages_per_slot:
            raise ValueError(
                f"{n_tokens} tokens need {need} pages > pages_per_slot={self.pages_per_slot}")
        have = self.mapped_pages(slot)
        if need <= have:
            return 0
        if need - have > len(self._free):
            raise RuntimeError(
                f"out of pages: need {need - have} more, free {len(self._free)}")
        for i in range(have, need):
            self.tables[slot, i] = self._take_fresh()
        self._check()
        return need - have

    def truncate(self, slot: int, n_tokens: int) -> int:
        """Shrink ``slot``'s mapping to cover only ``n_tokens`` positions,
        unmapping the *trailing* pages (pages wholly past
        ``ceil(n_tokens / page_size)``).  Page contents are left as-is —
        causal masking makes positions ≥ the logical length unreachable, and
        a future ``ensure`` re-maps (possibly different) pages that are
        rewritten before they are read, exactly like any recycled page.
        Allocator primitive: speculative rollback in the engine is logical
        (lengths shrink, pages stay mapped within the reservation), so this
        is for cache-external policies that really do want to give pages
        back early.  Keeps the free list sorted descending (same contract
        as :meth:`free`); returns the number of pages released."""
        keep = self.pages_needed(n_tokens)
        released = 0
        for i in range(keep, self.pages_per_slot):
            pid = int(self.tables[slot, i])
            if pid != 0:
                self._decref(pid)
                self.tables[slot, i] = 0
                released += 1
        if released:
            self._free.sort(reverse=True)
        self._check()
        return released

    # -- prefix sharing: external pins + copy-on-write ----------------------

    def ref_page(self, pid: int) -> None:
        """Take an external (non-table) reference on a live page — how the
        radix prefix index pins a cached page so it survives the writing
        slot's retirement."""
        if pid == 0 or self.refcounts[pid] <= 0:
            raise ValueError(f"cannot pin dead/scratch page {pid}")
        self.refcounts[pid] += 1
        self._external[pid] += 1
        self._check()

    def unref_page(self, pid: int) -> bool:
        """Drop an external reference; True if the page returned to the free
        list (no slot maps it either) — the eviction path."""
        if self._external[pid] <= 0:
            raise ValueError(f"page {pid} has no external reference to drop")
        self._external[pid] -= 1
        if self._decref(pid):
            self._free.sort(reverse=True)
            self._check()
            return True
        self._check()
        return False

    def cow_range(self, slot: int, start_tok: int, n_tokens: int) -> int:
        """Copy-on-write guard: before ``slot`` writes positions
        ``[start_tok, start_tok + n_tokens)``, any page in that range that is
        SHARED (refcount > 1 — aliased by another slot or pinned by the
        prefix index) is copied payload-and-all into a fresh page mapped only
        by this slot; the other holders keep the original bits.  Pages the
        slot owns outright pass through untouched, so this is free on the
        non-sharing path.  Returns the number of pages copied."""
        if n_tokens <= 0:
            return 0
        first = start_tok // self.page_size
        last = (start_tok + n_tokens - 1) // self.page_size
        copied = 0
        for idx in range(first, min(last + 1, self.pages_per_slot)):
            pid = int(self.tables[slot, idx])
            if pid == 0 or self.refcounts[pid] <= 1:
                continue  # unmapped (scratch-redirected) or exclusively owned
            if not self._free:
                raise RuntimeError(f"out of pages for copy-on-write of page {pid}")
            new = self._take_fresh()
            self.pool = copy_page(self.pool, jnp.int32(pid), jnp.int32(new))
            self.tables[slot, idx] = new
            self._decref(pid)  # refcount was > 1: never frees here
            copied += 1
        self._check()
        return copied

    # -- invariants ---------------------------------------------------------

    def _check(self) -> None:
        if self.debug:
            self.check_invariants()

    def check_invariants(self) -> None:
        """Allocator-state invariants, asserted after every mutate when the
        ``debug`` flag is on (and directly by tests):

        * free-list hygiene — in-range ids, no duplicates, sorted descending
          (the low-ids-first pop contract), every free page at refcount 0;
        * refcount consistency — each page's refcount equals its table-cell
          mappings plus its external pins, the scratch page is never
          referenced, no negative counts;
        * page conservation — live pages + free pages == n_pages - 1, which
          also implies no slot maps a freed page.
        """
        free = self._free
        if len(set(free)) != len(free):
            raise AssertionError("free list contains duplicate pages")
        if any(p <= 0 or p >= self.n_pages for p in free):
            raise AssertionError("free list contains out-of-range/scratch ids")
        if free != sorted(free, reverse=True):
            raise AssertionError("free list not sorted descending")
        rc = self.refcounts
        if int(rc[0]) != 0 or int(self._external[0]) != 0:
            raise AssertionError("scratch page 0 acquired a reference")
        if (rc < 0).any() or (self._external < 0).any():
            raise AssertionError("negative refcount")
        counts = np.bincount(self.tables.reshape(-1), minlength=self.n_pages)
        counts[0] = 0  # table zeros mean unmapped, not scratch references
        expect = counts[:self.n_pages] + self._external
        if not (rc == expect).all():
            bad = np.nonzero(rc != expect)[0][:8].tolist()
            raise AssertionError(
                f"refcount mismatch on pages {bad}: rc={rc[bad].tolist()} "
                f"!= tables+external={expect[bad].tolist()}")
        for p in free:
            if int(rc[p]) != 0:
                raise AssertionError(f"page {p} is free but has refcount {rc[p]}")
        live = int((rc > 0).sum())
        if live + len(free) != self.n_pages - 1:
            raise AssertionError(
                f"page conservation violated: live {live} + free {len(free)} "
                f"!= {self.n_pages - 1}")

    # -- accounting ---------------------------------------------------------

    def cache_bytes(self) -> int:
        """Persistent KV bytes held by the pool (the number the FP4 mode
        shrinks; transient gather buffers are working memory, not state)."""
        return sum(int(a.nbytes) for a in self.pool.values())

    def bits_per_element(self) -> float:
        elems = self.layers * self.n_pages * self.page_size * self.kv_heads * self.head_dim * 2
        return self.cache_bytes() * 8 / elems


# ---------------------------------------------------------------------------
# DenseSlotCache (SSM / hybrid / cross-KV fallback)
# ---------------------------------------------------------------------------


def slice_slot(caches: Any, slot: jnp.ndarray) -> Any:
    """Select one slot's cache (batch axis 1 on every leaf) → batch-1 view."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), caches)


def write_slot(caches: Any, update: Any, slot: jnp.ndarray) -> Any:
    """Write a batch-1 cache back into ``slot``."""
    return jax.tree.map(
        lambda a, u: jax.lax.dynamic_update_slice_in_dim(a, u.astype(a.dtype), slot, axis=1),
        caches, update)


def merge_masked(old: Any, new: Any, mask: jnp.ndarray) -> Any:
    """Per-slot select: keep ``new`` where mask (batch axis 1), else ``old`` —
    the one batched decode step leaves non-decoding slots untouched."""

    def sel(o, n):
        shape = [1] * o.ndim
        shape[1] = mask.shape[0]
        return jnp.where(mask.reshape(shape), n.astype(o.dtype), o)

    return jax.tree.map(sel, old, new)


class DenseSlotCache:
    """Per-slot dense decode state for families without paged attention KV."""

    def __init__(self, model: Model, *, n_slots: int, max_len: int):
        self.n_slots, self.max_len = n_slots, max_len
        spec = model.cache_spec(n_slots, max_len)
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        self._reset = jax.jit(self._reset_impl)

    @staticmethod
    def _reset_impl(caches, slot):
        zero = jax.tree.map(
            lambda a: jnp.zeros((a.shape[0], 1, *a.shape[2:]), a.dtype), caches)
        return write_slot(caches, zero, slot)

    def reset_slot(self, slot: int) -> None:
        """Zero one slot's state before a new request prefills into it (SSM
        recurrences have no positional masking to hide a predecessor's state)."""
        self.caches = self._reset(self.caches, jnp.int32(slot))

    def cache_bytes(self) -> int:
        return sum(int(a.nbytes) for a in jax.tree.leaves(self.caches))
