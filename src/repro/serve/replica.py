"""Data-parallel engine replicas over disjoint device groups.

:class:`ReplicatedEngine` runs ``dp`` independent :class:`~repro.serve.engine.
Engine` instances, each on its own ``tp``-device ``('model',)`` mesh
(``launch.mesh.make_serve_meshes``).  Replicas never communicate: each owns
its PagedCache, prefix cache, scheduler, and telemetry registry.  The only
cross-replica machinery is host-side:

* **placement** — ``submit`` consults :class:`~repro.serve.placement.
  ReplicaPlacer` over the replicas' live (free_pages, free_slots)
  inventories, so requests land where capacity is (most free pages first,
  slots break ties, round-robin breaks exact ties);
* **identity** — replicas share one rid counter (``Scheduler(ids=...)``) so
  request ids stay globally unique and ``completed`` can merge back into
  submission order;
* **accounting** — per-replica busy seconds accrue in ``busy_s``; replicas
  step concurrently in real deployments, so aggregate throughput is
  ``total tokens / max(busy_s)`` (the critical-path replica), which is what
  the benchmark reports;
* **telemetry** — ``aggregate_telemetry`` merges the per-replica registries
  (``registry.merge_registries``): counters sum, ``*_peak`` gauges take the
  max, ``*_watermark`` gauges the min, other gauges the mean — and
  histograms POOL their sample reservoirs and cumulative buckets, so
  DP-aggregate TTFT/TPOT percentiles are computed over all replicas'
  samples (averaging per-replica percentiles would be statistically wrong);
* **profiling** — with ``TelemetryConfig.profile_trace_path`` set, each
  replica records its own trace lane (``pid`` = replica index) and
  :meth:`ReplicatedEngine.write_profile` merges them into one
  Perfetto-loadable document (per-replica engines get the path stripped so
  they don't clobber each other's files).

Exactness: a request's tokens depend only on its own replica's engine, and
every replica is token-exact vs a single-device engine (the TP contract), so
the DP ensemble is token-exact per request as well.
"""

from __future__ import annotations

import itertools
import time

from repro.launch.mesh import make_serve_meshes
from repro.models.registry import Model
from repro.serve.engine import Engine, EngineConfig
from repro.serve.placement import Placement, ReplicaPlacer, ShardingConfig
from repro.serve.scheduler import Request


class _SchedView:
    """Minimal scheduler facade so drivers written against ``engine.sched``
    (e.g. ``launch.serve_engine.run_workload``) work unchanged."""

    def __init__(self, engines):
        self._engines = engines

    @property
    def pending(self) -> int:
        return sum(e.sched.pending for e in self._engines)

    @property
    def queue(self):
        return [r for e in self._engines for r in e.sched.queue]


class ReplicatedEngine:
    """``dp`` data-parallel Engine replicas behind the Engine driver API
    (``submit`` / ``step`` / ``drain`` / ``completed`` / ``sched.pending``)."""

    def __init__(self, model: Model, params, config: EngineConfig | None = None,
                 sharding: ShardingConfig | None = None):
        config = config or EngineConfig()
        sharding = sharding or config.sharding or ShardingConfig()
        if sharding.dp < 2:
            raise ValueError("ReplicatedEngine needs dp >= 2; use Engine for dp=1")
        self.sharding = sharding
        tp, dp = sharding.tp, sharding.dp
        meshes = make_serve_meshes(tp, dp)
        ids = itertools.count()  # shared → globally-unique rids
        # replicas get a dp-stripped config: each Engine validates tp only.
        # A shared profile trace path is also stripped (replicas would
        # clobber one file) — write_profile() merges the per-replica lanes.
        import dataclasses
        rep_cfg = dataclasses.replace(config, sharding=None)
        self.profile_trace_path = None
        tel = rep_cfg.telemetry
        if tel is not None and tel.profile_trace_path:
            self.profile_trace_path = tel.profile_trace_path
            rep_cfg = dataclasses.replace(
                rep_cfg, telemetry=dataclasses.replace(
                    tel, profile_trace_path=None, profile=True))
        self.engines = [
            Engine(model, params, rep_cfg,
                   placement=Placement(tp, mesh=m), ids=ids)
            for m in meshes
        ]
        for r, e in enumerate(self.engines):
            if e.telemetry.profiler is not None:
                e.telemetry.profiler.pid = r
        self.placer = ReplicaPlacer(dp)
        self.busy_s = [0.0] * dp
        self.sched = _SchedView(self.engines)
        self.model, self.config = model, config
        self.paged = self.engines[0].paged
        self.decode_backend = self.engines[0].decode_backend
        self.steps = 0

    # ------------------------------------------------------------------ API

    def submit(self, prompt, max_new: int, extra=None,
               arrival_time: float | None = None, sampling=None) -> Request:
        free_pages = [e.cache.free_pages if e.paged else e.config.n_slots
                      for e in self.engines]
        free_slots = [len(e.sched.free_slots) for e in self.engines]
        r = self.placer.place(free_pages, free_slots)
        req = self.engines[r].submit(prompt, max_new, extra=extra,
                                     arrival_time=arrival_time,
                                     sampling=sampling)
        req.replica = r
        return req

    def step(self, now: float | None = None) -> dict:
        """Tick every replica that has work; busy wall-time accrues per
        replica (replicas run concurrently in deployment, so the driver's
        virtual clock should advance by the max, not the sum — the summary
        dict's ``busy_s`` carries the per-replica splits for that)."""
        now = time.monotonic() if now is None else now
        infos, busy = [], []
        for r, eng in enumerate(self.engines):
            if not eng.sched.pending:
                continue
            t0 = time.perf_counter()
            infos.append(eng.step(now=now))
            dt = time.perf_counter() - t0
            self.busy_s[r] += dt
            busy.append(dt)
        self.steps += 1
        keys = ("admitted", "prefilling", "decoding", "queued")
        out = {k: sum(i[k] for i in infos) for k in keys}
        out["step"] = self.steps
        out["busy_s"] = busy
        return out

    def drain(self, max_steps: int = 100_000) -> list[Request]:
        while self.sched.pending:
            self.step()
            if self.steps > max_steps:
                raise RuntimeError("drain exceeded max_steps — engine wedged?")
        return self.completed

    @property
    def completed(self) -> list[Request]:
        out = [r for e in self.engines for r in e.completed]
        return sorted(out, key=lambda r: r.rid)

    def cache_bytes(self) -> int:
        return sum(e.cache_bytes() for e in self.engines)

    def aggregate_telemetry(self) -> dict:
        """One merged snapshot across replicas via
        :func:`~repro.serve.telemetry.registry.merge_registries`: counters
        sum; gauges ending ``_peak`` take the max, ``_watermark`` the min,
        anything else the mean; histograms pool reservoirs and buckets so
        the aggregate percentiles are over all replicas' samples; binned
        counts add; EWMA rates sum.  Carries the full snapshot sections
        (histograms/binned/rates included — they used to be dropped)."""
        from repro.serve.telemetry.registry import merge_registries
        merged = merge_registries([e.telemetry.registry for e in self.engines])
        agg = merged.snapshot()
        agg["replicas"] = len(self.engines)
        return agg

    def write_profile(self, path: str | None = None) -> str | None:
        """Finalize every replica's profiler (folding its completed request
        traces in) and write ONE merged Chrome-trace document with a
        process lane per replica.  Returns the path written, or ``None``
        when profiling is off."""
        from repro.serve.telemetry.profiling import write_trace
        path = path or self.profile_trace_path
        sinks = []
        for e in self.engines:
            prof = e.telemetry.profiler
            if prof is not None:
                prof.finalize(e.telemetry.tracer)
                sinks.append(prof.sink)
        if not sinks or path is None:
            return None
        write_trace(path, sinks)
        return path


def make_engine(model: Model, params, config: EngineConfig | None = None):
    """Factory honoring ``EngineConfig.sharding``: a plain (possibly
    tensor-parallel) :class:`Engine` for ``dp == 1``, a
    :class:`ReplicatedEngine` for ``dp > 1``."""
    config = config or EngineConfig()
    sh = config.sharding
    if sh is not None and sh.dp > 1:
        return ReplicatedEngine(model, params, config, sh)
    return Engine(model, params, config)
