"""Request lifecycle + slot admission for the continuous-batching engine.

State machine per request::

    QUEUED ──admit──▶ PREFILL ──prompt consumed──▶ DECODE ──EOS/max──▶ DONE
              ▲ needs a free slot (and, paged mode, enough free pages for
                prompt + max_new — reserved up front so decode never OOMs)

The scheduler is pure host logic: it decides *which* slots prefill/decode
each step and tracks timing; the engine owns the device state and jitted
steps.  Prefill is chunked — each engine step advances every PREFILL request
by at most ``prefill_chunk`` tokens — and all DECODE slots step together in
one jitted call.  This bounds the latency any single long prompt can impose
on in-flight decodes.  How a tick's chunks execute is the engine's choice:
paged families run every prefilling slot in ONE batched jitted call
(``prefill_batch`` supplies the ragged per-slot chunks; tails are padded and
write-masked in the kernel layout), while dense-slot families keep one
per-slot call and finish remainders with single-token chunks, because SSM
recurrences must never see padding tokens.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from enum import Enum
from typing import Any

import numpy as np


class RequestState(Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request; doubles as the user-facing handle."""

    rid: int
    prompt: np.ndarray  # [P] int32
    max_new: int
    extra: Any = None  # per-request conditioning (source/image embeds)
    sampling: Any = None  # SamplingParams | None (None → greedy argmax)
    state: RequestState = RequestState.QUEUED
    slot: int = -1
    prefill_pos: int = 0  # prompt tokens consumed so far
    tokens: list[int] = dataclasses.field(default_factory=list)  # generated
    logits_trace: list[np.ndarray] = dataclasses.field(default_factory=list)
    arrival_time: float = 0.0
    first_token_time: float | None = None
    finish_time: float | None = None
    finish_reason: str = ""
    # -- decode-phase accounting (speculative decoding emits a VARIABLE
    #    number of tokens per batched call; these make that visible) --------
    decode_calls: int = 0  # batched decode/verify invocations that fed this slot
    draft_proposed: int = 0  # drafted tokens scored on this request's behalf
    draft_accepted: int = 0  # drafted tokens the target model agreed with

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.state is RequestState.DONE

    def latency(self) -> float | None:
        return None if self.finish_time is None else self.finish_time - self.arrival_time

    def ttft(self) -> float | None:
        return (None if self.first_token_time is None
                else self.first_token_time - self.arrival_time)

    def acceptance_rate(self) -> float | None:
        """Fraction of drafted tokens accepted (None without speculation)."""
        return (self.draft_accepted / self.draft_proposed
                if self.draft_proposed else None)

    def tokens_per_decode_call(self) -> float | None:
        """Decode-phase tokens per batched call: 1.0 for plain decoding,
        up to k+1 with speculation (the prefill-produced token is excluded
        — it rides on a prefill call)."""
        return (max(len(self.tokens) - 1, 0) / self.decode_calls
                if self.decode_calls else None)


class Scheduler:
    """Slot/queue bookkeeping.  ``can_admit`` is a callback the engine wires
    to the cache backend (page availability in paged mode, always-true for
    dense slots)."""

    def __init__(self, n_slots: int, max_len: int, prefill_chunk: int = 16,
                 tracer: Any = None, ids=None):
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.n_slots, self.max_len = n_slots, max_len
        self.prefill_chunk = prefill_chunk
        self.tracer = tracer  # telemetry.Tracer | None — submit/retire spans
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}  # slot -> request
        self.free_slots = deque(range(n_slots))
        # ``ids`` lets data-parallel engine replicas share one counter so
        # rids stay globally unique (replica.ReplicatedEngine merges its
        # replicas' completed lists back into rid order)
        self._ids = ids if ids is not None else itertools.count()

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int, extra: Any = None,
               arrival_time: float = 0.0, sampling: Any = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt({prompt.size}) + max_new({max_new}) exceeds max_len {self.max_len}")
        req = Request(rid=next(self._ids), prompt=prompt, max_new=max_new,
                      extra=extra, sampling=sampling, arrival_time=arrival_time)
        self.queue.append(req)
        if self.tracer is not None:
            self.tracer.event(req.rid, "submit", arrival_time)
        return req

    # -- per-step decisions -------------------------------------------------

    def admit(self, can_admit, on_admit=None) -> list[Request]:
        """Move queued requests into free slots.  Strict FIFO: the head waits
        until it fits (admission caps guarantee it eventually does), so no
        request can be starved by later, smaller arrivals.

        ``on_admit(req)``, when given, runs INLINE per admitted request —
        before ``can_admit`` is consulted for the next one.  The engine uses
        it to commit cache-side effects (page allocation, prefix aliasing,
        eviction) transactionally, so a later head's admissibility is judged
        against the pool state this admission actually left behind, and it
        may overwrite ``prefill_pos`` when a cached prefix skips prompt
        tokens."""
        admitted = []
        while self.queue and self.free_slots:
            req = self.queue[0]
            if not can_admit(req):
                break
            self.queue.popleft()
            req.slot = self.free_slots.popleft()
            req.state = RequestState.PREFILL
            req.prefill_pos = 0
            self.active[req.slot] = req
            if on_admit is not None:
                on_admit(req)
            admitted.append(req)
        return admitted

    def prefilling(self) -> list[Request]:
        return [r for r in self.active.values() if r.state is RequestState.PREFILL]

    def prefill_batch(self) -> list[tuple[Request, int, int]]:
        """One ``(req, start, n_valid)`` chunk per PREFILL request for this
        tick: ``start`` is the request's consumed-prompt offset and
        ``n_valid = min(prefill_chunk, remaining)`` its ragged valid count —
        the batched paged prefill pads rows to ``prefill_chunk`` and write-
        masks the tail, so every prefilling slot advances in ONE jitted call
        regardless of how its prompt straddles chunk/page boundaries."""
        return [
            (r, r.prefill_pos,
             min(self.prefill_chunk, r.prompt_len - r.prefill_pos))
            for r in self.prefilling()
        ]

    def decoding(self) -> list[Request]:
        return [r for r in self.active.values() if r.state is RequestState.DECODE]

    def retire(self, req: Request, reason: str, now: float) -> int:
        """Release the request's slot; returns the freed slot id."""
        req.state = RequestState.DONE
        req.finish_reason = reason
        req.finish_time = now
        slot = req.slot
        del self.active[slot]
        self.free_slots.append(slot)
        if self.tracer is not None:
            self.tracer.event(req.rid, "retire", now)
        return slot

    @property
    def pending(self) -> int:
        return len(self.queue) + len(self.active)
