"""Continuous-batching inference engine.

``Engine`` multiplexes many generation requests over a fixed set of decode
slots:

* ``submit(prompt, max_new, sampling=...) -> Request`` queues work (the
  returned object is the handle; ``.tokens`` fills in as the engine runs),
* ``step()`` advances the world by one scheduler tick: admit queued requests
  into free slots, advance every prefilling request by one chunk — paged
  families in **one** batched jitted call over the packed pool — then step
  every decoding slot in **one** jitted call,
* ``drain()`` steps until nothing is queued or active.

Model families with positional attention KV (``dense``/``moe``) store their
cache in :class:`PagedCache` pages — optionally MXFP4-packed (4.25
bits/element) with quantize-on-write.  Batched decode AND batched prefill
attend *directly over the packed pool* via the fused Pallas paged-attention
kernel (the raw pool + int32 page tables are operands of the jitted steps;
no dense [L, B, T, Hkv, hd] gather is ever materialized).  Prefill advances
ALL prefilling slots per tick in one ``[n_slots, prefill_chunk]`` call:
each slot's chunk is quantize-scattered into its own pages at its own start
offset, ragged tails are padded and write-masked onto the scratch sentinel
column, and the multi-query kernel applies per-row causal bounds — so
prefill HBM traffic is O(packed KV) and TTFT no longer degrades linearly
with concurrent arrivals.  The legacy gather-dequantize decode and
per-slot-gather prefill survive together as a parity oracle behind
``EngineConfig(decode_backend="gather")``.  The OTHER families (ssm /
hybrid / encdec / vlm) now pool their per-slot decode state too, behind
:class:`~repro.serve.state_pool.StatePool`: positional self-KV in paged
planes, enc-dec/VLM cross-KV encoded ONCE at admission into a static
refcounted plane (shareable across requests with identical conditioning
when ``prefix_cache`` is on), and SSM recurrent/conv state in quantized
double-buffer page rings.  They schedule identically to the paged path but
keep per-slot chunk-then-single-token prefill, since an SSM recurrence
must never consume a padding token; the old per-slot dense caches survive
as the parity oracle behind ``decode_backend="dense_slots"``.

**Speculative decoding** (``EngineConfig(spec=SpecConfig(...))``, paged
families): each decode tick becomes draft → verify → accept.  A pluggable
proposer (``serve.spec.proposers``) drafts ``k`` tokens per slot; ONE jitted
verify call scores all ``k + 1`` tokens per slot directly over the packed
pool (multi-query paged-attention with per-row causal bounds); the host
accepts the longest draft prefix the target model itself reproduces and
emits 1..k+1 tokens.  Rollback is purely *logical*: the slot's length
shrinks on the host and the rejected suffix's positions become unreachable
(causal bounds + rewrite-before-read), while the pages themselves stay
mapped — admission reserved them for ``prompt + max_new`` and nothing a
speculative tick does may map beyond that reservation, so a full pool can
never raise "out of pages" mid-flight.  Draft positions past the token
budget redirect their writes to the scratch page (their KV is never read
by any emittable row).  Greedy self-speculation is token-exact against the
non-speculative engine (the extended parity-oracle contract).

Sampling is per request (:class:`~repro.serve.sampling.SamplingParams`):
greedy argmax by default; temperature / top-k / top-p draws use stateless
per-token keys, which is also what lets the speculative verifier re-draw any
drafted position independently.

Both paths reuse the same step builders as ``train.serve.greedy_generate``
(``make_chunk_prefill_step`` / ``make_decode_step`` / ``make_verify_step``
via :func:`repro.serve.steps.build_paged_steps`), so engine outputs are
token-for-token those of the reference loop in dense-cache mode.  On the
default paged backend exactly three shapes compile per engine: the
``[n_slots]`` decode, the ``[n_slots, k+1]`` verify, and the
``[n_slots, prefill_chunk]`` batched prefill (ragged tails are padded into
it — there is no ``[1, 1]`` remainder shape).  The gather oracle and the
dense-slot families keep the per-slot ``[1, prefill_chunk]`` + ``[1, 1]``
prefill shapes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.serve import paged_cache as P
from repro.serve.placement import Placement, ShardingConfig
from repro.serve.prefix_cache import PrefixIndex
from repro.serve.sampling import SamplingParams, get_sampler
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.serve.spec.config import SpecConfig
from repro.serve.spec.proposers import build_proposer
from repro.serve.spec.verify import accept_tokens
from repro.serve.state_pool import STATE_FAMILIES, StatePool, cross_key
from repro.serve.steps import (build_paged_steps, build_state_steps,
                               jit_cache_size, marshal_prefill_batch)
from repro.serve.telemetry import EngineTelemetry, TelemetryConfig
from repro.train.serve import make_chunk_prefill_step, make_decode_step

PAGED_FAMILIES = ("dense", "moe")
_EMBED_KEY = {"encdec": "source_embeds", "vlm": "image_embeds"}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128  # per-slot token capacity (prompt + generation)
    page_size: int = 16  # paged families only
    kv_dtype: str = "mxfp4"  # "mxfp4" | "dense" (paged families only)
    prefill_chunk: int = 16
    method: str = "quartet"
    eos_id: int | None = None
    keep_logits: bool = False  # record per-step logits on each Request (tests)
    # backend selection.  Paged families (decode, verify AND prefill):
    #   None     — follow ModelConfig.attn_backend ("paged" unless overridden)
    #   "paged"  — fused Pallas kernel directly over the packed pool (default);
    #              prefill runs batched across all prefilling slots
    #   "gather" — legacy gather-dequantize-to-dense oracle (parity testing);
    #              prefill stays the per-slot [1, C] + [1, 1] chunk loop
    # Non-attention families (ssm / hybrid / encdec / vlm):
    #   None / "statepool" — unified StatePool planes (default): paged
    #              self-KV, encode-once cross-KV, quantized state rings
    #   "dense_slots"      — per-slot dense caches; survives as the
    #              state-pool parity oracle
    decode_backend: str | None = None
    # pool size override (pages incl. the scratch page).  None → one full
    # reservation (ceil(max_len / page_size) pages) per slot + scratch.
    # Admission reserves prompt + max_new pages up front and NOTHING maps
    # beyond a reservation mid-flight, so a pool sized exactly to the
    # reservations it admits can never raise "out of pages".
    n_pages: int | None = None
    # prefix sharing: on paged families, radix-index prompt token ids at
    # admission, alias every fully-covered cached page into the new slot's
    # table (refcounted; copy-on-write before any divergent write) and
    # prefill only the unshared tail; LRU-evict refcount-one cached prefixes
    # under pool pressure.  Token-exact vs the non-sharing engine — aliasing
    # is safe because MXFP4 quantize-on-write is deterministic, so a shared
    # prefix's packed pages are bit-identical to what a cold prefill would
    # have produced.  On state-pool enc-dec/VLM engines the same flag turns
    # on CROSS-KV sharing: requests whose conditioning tensors are byte-
    # identical alias one encoded cross page set (state_pool.CrossIndex) and
    # skip the encode entirely — warm is token-exact vs cold because both
    # read the same pooled pages.  ssm/hybrid have no shareable pages.
    prefix_cache: bool = False
    # run PagedCache.check_invariants after EVERY allocator mutate (page
    # conservation, refcount consistency, free-list hygiene) — tests/debug
    debug_cache: bool = False
    # speculative decoding (paged families only); None → plain decode
    spec: SpecConfig | None = None
    # observability (serve.telemetry).  None → metrics + tracing still
    # collected in-memory (host dicts, no sinks, no device sampling); set a
    # TelemetryConfig to stream JSONL metrics / traces, expose Prometheus
    # text, or sample pool quantization health at a tick stride.
    telemetry: TelemetryConfig | None = None
    # multi-device serving (paged families only).  ``tp`` shards the pool /
    # paged-attention / MoE experts over a ``('model',)`` mesh inside this
    # engine's jitted steps; ``dp > 1`` is only honored by
    # ``serve.replica.make_engine`` (data-parallel replicas) — constructing a
    # bare Engine with dp > 1 raises.  Token-exact vs single-device.
    sharding: ShardingConfig | None = None


class Engine:
    def __init__(self, model: Model, params, config: EngineConfig | None = None,
                 *, placement: Placement | None = None, ids=None):
        self.model, self.params = model, params
        self.config = cfg = config or EngineConfig()
        family = model.cfg.family
        self.paged = family in PAGED_FAMILIES
        if self.paged:
            self.backend = "paged"
        elif cfg.decode_backend in (None, "statepool"):
            self.backend = "statepool"
        elif cfg.decode_backend == "dense_slots":
            self.backend = "dense_slots"
        else:
            raise ValueError(
                f"decode_backend for {family!r} must be 'statepool' (default) "
                f"or 'dense_slots' (parity oracle), got {cfg.decode_backend!r}")
        self.spec = cfg.spec
        if self.spec is not None and not self.paged:
            raise ValueError(
                f"speculative decoding needs a paged family (dense/moe): "
                f"{family!r} serving has no multi-token verify step — an SSM "
                f"recurrence scores one token per state transition and the "
                f"state rings hold no positional history to roll back")
        if cfg.prefix_cache and not self.paged:
            if self.backend != "statepool" or family not in ("encdec", "vlm"):
                raise ValueError(
                    f"prefix caching needs shareable pages: a paged family "
                    f"(dense/moe, radix prompt prefixes) or a state-pool "
                    f"enc-dec/VLM engine (cross-KV sharing); "
                    f"{family!r} with backend {self.backend!r} has neither")
        if placement is None:
            if cfg.sharding is not None and cfg.sharding.dp > 1:
                raise ValueError(
                    "dp > 1 needs data-parallel replicas — build via "
                    "serve.replica.make_engine / ReplicatedEngine")
            placement = Placement(cfg.sharding.tp if cfg.sharding else 1)
        if placement.tp > 1 and not self.paged:
            if self.backend != "statepool" or family not in ("encdec", "vlm"):
                raise ValueError(
                    f"tensor-parallel serving shards pooled KV on the head "
                    f"axis: paged families and state-pool enc-dec/VLM only; "
                    f"{family!r} with backend {self.backend!r} keeps "
                    f"recurrent-state rings, which have no head axis to shard")
        self.placement = placement
        self.telemetry = EngineTelemetry(cfg.telemetry)
        self.sched = Scheduler(cfg.n_slots, cfg.max_len, cfg.prefill_chunk,
                               tracer=self.telemetry.tracer, ids=ids)
        self.completed: list[Request] = []
        self._dtype = jnp.dtype(model.cfg.dtype)
        self.steps = 0

        if self.paged:
            # sizing (table width vs pool pages) is the shared reservation-
            # contract rule — see paged_cache.reservation_sizing
            spec_k = self.spec.k if self.spec else 0
            pages_per_slot, n_pages = P.reservation_sizing(
                cfg.n_slots, cfg.max_len, cfg.page_size, spec_k)
            if cfg.n_pages is not None:
                # fail fast: a pool that cannot hold even one maximal
                # reservation would wedge admission forever (can_admit False
                # on every tick) instead of erroring
                min_pages = 1 + (-(-cfg.max_len // cfg.page_size))
                if cfg.n_pages < min_pages:
                    raise ValueError(
                        f"n_pages={cfg.n_pages} cannot hold one max_len="
                        f"{cfg.max_len} reservation plus the scratch page "
                        f"(need >= {min_pages})")
                n_pages = cfg.n_pages
            self.cache = P.PagedCache(
                model, n_slots=cfg.n_slots, pages_per_slot=pages_per_slot,
                page_size=cfg.page_size, n_pages=n_pages, kv_dtype=cfg.kv_dtype,
                debug=cfg.debug_cache)
            if placement.tp > 1:
                # pool shards on the KV-head axis over the placement mesh;
                # params replicate (serving TP = KV/attention/expert
                # parallelism, not weight sharding — see serve/README.md)
                self.cache.pool = placement.shard_pool(self.cache.pool)
                self.params = placement.replicate(self.params)
            self.decode_backend = cfg.decode_backend or (
                "paged" if model.cfg.attn_backend == "paged" else "gather")
            self._steps = build_paged_steps(
                model, method=cfg.method, page_size=cfg.page_size,
                n_layers=self.cache.layers, decode_backend=self.decode_backend,
                placement=placement if placement.tp > 1 else None,
                pool_example=self.cache.pool)
            self._decode_all = self._steps.decode_all
            self._prefill_chunk = self._steps.prefill_chunk
            self._verify_all = self._steps.verify_all
            self._prefill_all = self._steps.prefill_all  # None on gather
            self._encode_cross = None
        elif self.backend == "statepool":
            self.cache = StatePool(
                model, n_slots=cfg.n_slots, max_len=cfg.max_len,
                page_size=cfg.page_size, kv_dtype=cfg.kv_dtype,
                debug=cfg.debug_cache)
            if placement.tp > 1:
                # both paged planes shard on the KV-head axis (same mesh
                # contract as the dense/moe pool); params replicate
                self.cache.kv.pool = placement.shard_pool(self.cache.kv.pool)
                self.cache.cross.pool = placement.shard_pool(self.cache.cross.pool)
                self.params = placement.replicate(self.params)
            self.decode_backend = "statepool"
            self._steps = build_state_steps(
                model, method=cfg.method, pool=self.cache,
                placement=placement if placement.tp > 1 else None)
            self._decode_all = self._steps.decode_all
            self._prefill_chunk = self._steps.prefill_chunk
            self._encode_cross = self._steps.encode_cross
            self._prefill_all = None  # per-slot chunks: no padding into rings
        else:
            self.cache = P.DenseSlotCache(model, n_slots=cfg.n_slots,
                                          max_len=cfg.max_len)
            self.decode_backend = "dense_slots"
            decode = make_decode_step(model, method=cfg.method)
            chunk = make_chunk_prefill_step(model, method=cfg.method)

            def decode_all(params, tokens, positions, caches, mask):
                pos_safe = jnp.where(mask, positions, 0)
                logits, new_caches, _ = decode(params, tokens, pos_safe, caches)
                return logits, P.merge_masked(caches, new_caches, mask)

            def prefill_chunk(params, tokens, start, slot, caches, extra=None):
                sub = P.slice_slot(caches, slot)
                logits, new_sub, _ = chunk(
                    params, tokens, jnp.full((1,), start, jnp.int32), sub, extra)
                return logits, P.write_slot(caches, new_sub, slot)

            self._decode_all = jax.jit(decode_all)
            self._prefill_chunk = jax.jit(prefill_chunk)
            self._prefill_all = None  # dense slots: SSM state must never see padding
            self._encode_cross = None

        self.prefix = (PrefixIndex(cfg.page_size)
                       if (self.paged and cfg.prefix_cache) else None)
        # cross-KV sharing: the state-pool analogue of the prefix cache
        self.cross_share = (self.backend == "statepool" and cfg.prefix_cache
                            and self.cache.cross is not None)
        self._admit_plan: dict[int, list[int]] = {}  # rid -> matched page ids
        self._cross_plan: dict[int, tuple] = {}  # rid -> (content key, pages)
        self.proposer = (build_proposer(self, self.spec)
                         if self.spec is not None else None)
        self.telemetry.attach(self)

    # ------------------------------------------------------------------ API

    def submit(self, prompt, max_new: int, extra: Any = None,
               arrival_time: float | None = None,
               sampling: SamplingParams | None = None) -> Request:
        now = time.monotonic() if arrival_time is None else arrival_time
        if self.backend == "statepool" and self.cache.cross is not None:
            key = _EMBED_KEY[self.model.cfg.family]
            if extra is None or extra.get(key) is None:
                raise ValueError(
                    f"state-pool {self.model.cfg.family!r} serving encodes "
                    f"cross-KV once at admission: submit() needs "
                    f"extra[{key!r}]")
        req = self.sched.submit(prompt, max_new, extra=extra, arrival_time=now,
                                sampling=sampling)
        self.telemetry.registry.counter("requests_submitted").inc()
        return req

    def step(self, now: float | None = None) -> dict:
        """One scheduler tick: admit → chunked prefill → batched decode (or
        draft/verify/accept with speculation on) → retire.  Returns a small
        summary dict (counts) for driver loops."""
        now = time.monotonic() if now is None else now
        cfg = self.config
        reg = self.telemetry.registry
        t_tick = time.perf_counter()

        # -- admit ---------------------------------------------------------
        def can_admit(req: Request) -> bool:
            if self.backend == "dense_slots":
                return True
            if self.backend == "statepool":
                key = cross_key(req.extra) if self.cross_share else None
                match = self.cache.cross_match(key, now)
                ok = self.cache.can_admit(req.prompt_len + req.max_new,
                                          cross_shared=bool(match))
                if ok and self.cross_share:
                    self._cross_plan[req.rid] = (key, match)
                return ok
            if self.prefix is None:
                return self.cache.can_alloc(req.prompt_len + req.max_new)
            match = self.prefix.match(req.prompt, now)
            ok = self._fresh_pages_needed(req, match) <= (
                self.cache.free_pages
                + self.prefix.evictable_pages(self.cache, exclude=match))
            if ok:
                self._admit_plan[req.rid] = match
            return ok

        admitted = self.sched.admit(
            can_admit, on_admit=lambda req: self._on_admit(req, now))
        for req in admitted:
            if self.proposer is not None:
                self.proposer.on_admit(req)
            self.telemetry.tracer.event(req.rid, "admit", now)
        reg.counter("requests_admitted").inc(len(admitted))
        if self.sched.queue and self.sched.free_slots:
            # a slot is free but the FIFO head didn't fit: page pressure
            reg.counter("admission_blocked_pages").inc()

        # -- chunked prefill: ALL prefilling paged slots in one jitted call
        #    (gather oracle / dense slots: one per-slot call each) ----------
        t0 = time.perf_counter()
        did_prefill = False
        if self._prefill_all is not None:
            batch = self.sched.prefill_batch()
            if batch:
                self._prefill_tick(batch, now)
                did_prefill = True
        else:
            for req in self.sched.prefilling():
                self._advance_prefill(req, now)
                did_prefill = True
        if did_prefill:
            self.telemetry.phase("prefill", now, t_tick, t0, time.perf_counter())

        # -- one batched decode/verify over all decoding slots ---------------
        decoding = self.sched.decoding()
        if decoding:
            t0 = time.perf_counter()
            if self.spec is not None:
                self._spec_tick(decoding, now)
                self.telemetry.phase("verify", now, t_tick, t0,
                                     time.perf_counter())
            else:
                self._decode_tick(decoding, now)
                self.telemetry.phase("decode", now, t_tick, t0,
                                     time.perf_counter())

        self.steps += 1
        self.telemetry.end_tick(self, now, time.perf_counter() - t_tick)
        return {"admitted": len(admitted), "prefilling": len(self.sched.prefilling()),
                "decoding": len(self.sched.decoding()),
                "queued": len(self.sched.queue), "step": self.steps}

    def drain(self, max_steps: int = 100_000) -> list[Request]:
        """Step until every submitted request has finished."""
        while self.sched.pending:
            self.step()
            if self.steps > max_steps:
                raise RuntimeError("drain exceeded max_steps — engine wedged?")
        return self.completed

    def cache_bytes(self) -> int:
        return self.cache.cache_bytes()

    def compile_counts(self) -> dict[str, int]:
        """Compiled-variant count per jitted step — the one-compile-per-shape
        contract made observable (exported as ``jit_compiled_*`` gauges and
        pinned by the telemetry no-recompile test)."""
        if self.backend in ("paged", "statepool"):
            return self._steps.compile_counts()
        return {"decode_all": jit_cache_size(self._decode_all),
                "prefill_chunk": jit_cache_size(self._prefill_chunk),
                "verify_all": 0, "prefill_all": 0}

    # ------------------------------------------------------------- internals

    def _sample(self, req: Request, logits_row, token_idx: int) -> int:
        """One token draw for ``req`` (greedy argmax unless the request set
        SamplingParams) — the single sampling call site for prefill, decode,
        drafting, and verification, keyed by generated-token index."""
        sp = req.sampling if req.sampling is not None else SamplingParams()
        return get_sampler(sp)(logits_row, token_idx)

    # -- prefix sharing ------------------------------------------------------

    def _fresh_pages_needed(self, req: Request, match: list[int]) -> int:
        """Free-list pages this admission must produce beyond the aliased
        prefix ``match``: the reservation's uncovered tail, plus one
        copy-on-write target when the hit covers the ENTIRE prompt (the final
        prompt token is then re-prefilled into the shared tail page, which
        must first be detached)."""
        need = self.cache.pages_needed(req.prompt_len + req.max_new)
        full = len(match) * self.config.page_size == req.prompt_len
        return need - len(match) + (1 if full else 0)

    def _on_admit(self, req: Request, now: float) -> None:
        """Commit the cache side of one admission.  Runs INLINE inside
        ``Scheduler.admit`` — before the next head's ``can_admit`` — so page
        allocation, prefix aliasing, eviction, and the eager full-hit COW are
        transactional against the pool the next admission is judged on."""
        if self.backend == "dense_slots":
            self.cache.reset_slot(req.slot)
            return
        total = req.prompt_len + req.max_new
        if self.backend == "statepool":
            reg = self.telemetry.registry
            key, match = self._cross_plan.pop(req.rid, (None, []))
            self.cache.alloc(req.slot, total, cross_shared=match)
            if self.cache.cross is None:
                return
            if self.cross_share:
                reg.counter("prefix_lookups").inc()
            if match:
                # warm: the slot's cross row aliases the cached page set —
                # no encode, and decode reads bit-identical pages to cold
                reg.counter("prefix_hit_requests").inc()
                reg.counter("prefix_shared_tokens").inc(self.cache.cross_tokens)
                return
            embeds = req.extra[_EMBED_KEY[self.model.cfg.family]]
            cross_row = jnp.asarray(self.cache.cross.tables[req.slot])
            self.cache.cross.pool = self._encode_cross(
                self.params, jnp.asarray(embeds), cross_row,
                self.cache.cross.pool)
            reg.counter("cross_encode_calls").inc()
            if self.cross_share and key is not None:
                reg.counter("prefix_inserted_pages").inc(
                    self.cache.cross_publish(key, req.slot, now))
            return
        if self.prefix is None:
            self.cache.alloc(req.slot, total)
            return
        reg = self.telemetry.registry
        match = self._admit_plan.pop(req.rid, [])
        shortfall = self._fresh_pages_needed(req, match) - self.cache.free_pages
        if shortfall > 0:
            reg.counter("prefix_evicted_pages").inc(
                self.prefix.evict(self.cache, shortfall, exclude=match))
        self.cache.alloc(req.slot, total, shared=match)
        reg.counter("prefix_lookups").inc()
        if not match:
            return
        reg.counter("prefix_hit_requests").inc()
        covered = len(match) * self.config.page_size
        if covered == req.prompt_len:
            # full-prefix hit: skip everything but the final prompt token,
            # whose logits must be recomputed to sample the first generated
            # token.  That one-token re-prefill rewrites (bit-identically)
            # into the last shared page — detach it NOW so the free-list
            # accounting above stays exact.
            req.prefill_pos = req.prompt_len - 1
            reg.counter("prefix_cow_pages").inc(
                self.cache.cow_range(req.slot, req.prefill_pos, 1))
            covered -= 1
        else:
            req.prefill_pos = covered
        reg.counter("prefix_shared_tokens").inc(covered)

    def _cow_guard(self, reqs_spans) -> None:
        """Copy-on-write safety net before a write phase: for each
        ``(slot, start_tok, n_tokens)`` span about to be written, detach any
        still-shared page in range (``PagedCache.cow_range``).  Normally a
        no-op — slots only write past their aliased prefix, and the one real
        divergence (full-hit re-prefill) is COWed eagerly at admission — but
        it makes "a slot never writes into a page another holder can see"
        locally true at every write site rather than a global argument."""
        if self.prefix is None:
            return
        cow = self.telemetry.registry.counter("prefix_cow_pages")
        for slot, start, n in reqs_spans:
            cow.inc(self.cache.cow_range(slot, start, n))

    def _prefix_insert(self, req: Request, tokens: np.ndarray, now: float) -> None:
        """Publish ``req``'s fully-written pages under token chain ``tokens``
        into the radix index (partial tail pages are never published)."""
        if self.prefix is None:
            return
        added = self.prefix.insert(self.cache, tokens,
                                   self.cache.tables[req.slot], now)
        self.telemetry.registry.counter("prefix_inserted_pages").inc(added)

    def _run_prefill_call(self, req: Request, tokens_np: np.ndarray):
        start = jnp.int32(req.prefill_pos)
        tokens = jnp.asarray(tokens_np[None, :], jnp.int32)
        if self.paged:
            self._cow_guard([(req.slot, req.prefill_pos, int(tokens_np.shape[0]))])
            table_row = jnp.asarray(self.cache.tables[req.slot])
            logits, self.cache.pool = self._prefill_chunk(
                self.params, tokens, start, table_row, self.cache.pool, req.extra)
        elif self.backend == "statepool":
            sp = self.cache
            kv_row = (jnp.asarray(sp.kv.tables[req.slot])
                      if sp.kv is not None else None)
            cross_row = (jnp.asarray(sp.cross.tables[req.slot])
                         if sp.cross is not None else None)
            read = np.array([sp.ring_read[req.slot]], np.int32)
            write = np.array([sp.ring_write_id(req.slot)], np.int32)
            logits, state = self._prefill_chunk(
                self.params, tokens, start, sp.pools(), kv_row, cross_row,
                jnp.asarray(read), jnp.asarray(write), req.extra)
            sp.set_pools(state)
            if sp.rings:
                one = np.zeros((self.config.n_slots,), bool)
                one[req.slot] = True
                sp.ring_advance(one)
        else:
            logits, self.cache.caches = self._prefill_chunk(
                self.params, tokens, start, jnp.int32(req.slot),
                self.cache.caches, req.extra)
        req.prefill_pos += tokens_np.shape[0]
        self.telemetry.registry.counter("prefill_calls").inc()
        self.telemetry.registry.counter("prompt_tokens_prefilled").inc(
            int(tokens_np.shape[0]))
        return logits

    def _prefill_tick(self, batch, now: float) -> None:
        """Advance EVERY prefilling slot by one chunk in ONE jitted call over
        the packed pool (paged backend).  Rows are ``[n_slots, C]`` with
        ragged tails padded; the step write-masks padding onto the scratch
        sentinel column and returns each row's last-valid-token logits, from
        which slots that just consumed their whole prompt sample their first
        token."""
        self._cow_guard([(req.slot, pos, n) for req, pos, n in batch])
        tokens, start, n_valid, mask = marshal_prefill_batch(
            self.config.n_slots, self.config.prefill_chunk,
            ((req.slot, pos, req.prompt[pos:pos + n]) for req, pos, n in batch))
        logits, self.cache.pool = self._prefill_all(
            self.params, jnp.asarray(tokens), jnp.asarray(start),
            jnp.asarray(n_valid), self.cache.pool,
            jnp.asarray(self.cache.tables), jnp.asarray(mask))
        reg = self.telemetry.registry
        reg.counter("prefill_calls").inc()
        reg.counter("prompt_tokens_prefilled").inc(int(sum(n for _, _, n in batch)))
        logits_np = None  # [B, V]; fetched only if some slot finished
        for req, pos, n in batch:
            req.prefill_pos = pos + n
            if req.prefill_pos == req.prompt_len:
                if logits_np is None:
                    logits_np = np.asarray(logits, np.float32)
                row = logits_np[req.slot]
                tok = self._sample(req, row, 0)
                if self.config.keep_logits:
                    req.logits_trace.append(row)
                req.tokens.append(tok)
                req.first_token_time = now
                req.state = RequestState.DECODE
                self._prefix_insert(req, req.prompt, now)
                self._record_first_token(req, now)
                self._maybe_finish(req, now)

    def _advance_prefill(self, req: Request, now: float) -> None:
        """Per-slot prefill: the gather parity oracle and the dense-slot
        families (whose SSM recurrences must never see padding)."""
        C = self.config.prefill_chunk
        remaining = req.prompt_len - req.prefill_pos
        if remaining >= C:
            logits = self._run_prefill_call(
                req, req.prompt[req.prefill_pos:req.prefill_pos + C])
        else:
            # remainder (< C tokens): single-token chunks — never pad, so SSM
            # recurrences and MoE routing only ever see real tokens
            for _ in range(remaining):
                logits = self._run_prefill_call(
                    req, req.prompt[req.prefill_pos:req.prefill_pos + 1])
        if req.prefill_pos == req.prompt_len:
            logits_np = np.asarray(logits[0], np.float32)
            tok = self._sample(req, logits_np, 0)
            if self.config.keep_logits:
                req.logits_trace.append(logits_np)
            req.tokens.append(tok)
            req.first_token_time = now
            req.state = RequestState.DECODE
            if self.paged:
                self._prefix_insert(req, req.prompt, now)
            self._record_first_token(req, now)
            self._maybe_finish(req, now)

    def _record_first_token(self, req: Request, now: float) -> None:
        """Prefill just produced the request's first token: trace the span
        boundary and count the emission (it rides on a prefill call, so it
        counts toward ``tokens_generated`` but NOT ``decode_tokens``)."""
        self.telemetry.tracer.event(req.rid, "first_token", now)
        self.telemetry.tracer.tokens(req.rid, now, 1)
        self.telemetry.registry.counter("tokens_generated").inc()

    def _decode_tick(self, decoding: list[Request], now: float) -> None:
        B = self.config.n_slots
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        for req in decoding:
            tokens[req.slot, 0] = req.tokens[-1]
            positions[req.slot] = req.prompt_len + len(req.tokens) - 1
            mask[req.slot] = True
        args = (self.params, jnp.asarray(tokens), jnp.asarray(positions))
        if self.paged:
            self._cow_guard([(r.slot, int(positions[r.slot]), 1) for r in decoding])
            logits, self.cache.pool = self._decode_all(
                *args, self.cache.pool, jnp.asarray(self.cache.tables),
                jnp.asarray(mask))
        elif self.backend == "statepool":
            sp = self.cache
            ring_read, ring_write = sp.ring_ids(mask)
            logits, state = self._decode_all(
                *args, sp.pools(),
                jnp.asarray(sp.kv.tables) if sp.kv is not None else None,
                jnp.asarray(sp.cross.tables) if sp.cross is not None else None,
                jnp.asarray(ring_read), jnp.asarray(ring_write),
                jnp.asarray(mask))
            sp.set_pools(state)
            sp.ring_advance(mask)
        else:
            logits, self.cache.caches = self._decode_all(
                *args, self.cache.caches, jnp.asarray(mask))
        logits_np = np.asarray(logits, np.float32)
        reg = self.telemetry.registry
        reg.counter("decode_calls").inc()
        for req in decoding:
            tok = self._sample(req, logits_np[req.slot], len(req.tokens))
            if self.config.keep_logits:
                req.logits_trace.append(logits_np[req.slot])
            req.tokens.append(tok)
            req.decode_calls += 1
            self.telemetry.tracer.tokens(req.rid, now, 1)
            self._maybe_finish(req, now)
        reg.counter("tokens_generated").inc(len(decoding))
        reg.counter("decode_tokens").inc(len(decoding))

    def _spec_tick(self, decoding: list[Request], now: float) -> None:
        """Draft → one batched verify → accept/rollback.

        Per slot with last accepted token t at position p0 and drafts
        d1..dk: the verify call feeds [t, d1..dk] at positions p0..p0+k
        (writing all k+1 tokens' KV before attending — the usual
        write-before-read causal invariant) and returns k+1 logit rows;
        row i is the target's distribution after consuming token i.  The
        host accepts the longest draft prefix the target's own draws
        reproduce and emits the correction/bonus draw.

        No pages are mapped for the burst: admission already reserved
        ``prompt + max_new`` and the engine never maps beyond that
        reservation ("reserved up front so decode never OOMs" — mapping
        speculative headroom on demand from a full pool is exactly how the
        old ``ensure(p0 + k + 1)`` could raise "out of pages" mid-flight).
        Draft positions past the budget fall on unmapped (zero) table
        columns, so their quantize-on-write redirects to the scratch page;
        every row whose draw can be EMITTED attends only to reserved,
        properly-written positions, so emitted tokens never see the
        garbage.  Rollback is likewise logical-only: the host shrinks the
        slot's length and the rejected positions become unreachable (causal
        bounds + rewrite-before-read), with no page traffic.
        """
        cfg, k = self.config, self.spec.k
        B = cfg.n_slots
        eos = cfg.eos_id

        drafts = self.proposer.propose(decoding)  # [n_slots, k] int32

        tokens = np.zeros((B, k + 1), np.int32)
        start = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        for req in decoding:
            tokens[req.slot, 0] = req.tokens[-1]
            tokens[req.slot, 1:] = drafts[req.slot]
            start[req.slot] = req.prompt_len + len(req.tokens) - 1
            mask[req.slot] = True
        self._cow_guard([(r.slot, int(start[r.slot]), k + 1) for r in decoding])
        logits, self.cache.pool = self._verify_all(
            self.params, jnp.asarray(tokens), jnp.asarray(start),
            self.cache.pool, jnp.asarray(self.cache.tables), jnp.asarray(mask))
        logits_np = np.asarray(logits, np.float32)  # [B, k+1, V]
        reg = self.telemetry.registry
        reg.counter("verify_calls").inc()

        for req in decoding:
            base = len(req.tokens)
            target = [self._sample(req, logits_np[req.slot, i], base + i)
                      for i in range(k + 1)]
            n_acc, emitted = accept_tokens(drafts[req.slot].tolist(), target)
            req.decode_calls += 1
            n_emit, stopped = 0, False
            for i, tok in enumerate(emitted):
                if self.config.keep_logits:
                    req.logits_trace.append(logits_np[req.slot, i])
                req.tokens.append(tok)
                n_emit += 1
                if ((eos is not None and tok == eos)
                        or len(req.tokens) >= req.max_new):
                    stopped = True
                    break  # emission stops at EOS / budget even mid-burst
            # acceptance accounting counts only drafts at EMITTABLE
            # positions: when emission stops mid-burst (EOS / budget) the
            # drafts past the stop could never have been emitted, and
            # counting them as proposed-but-not-accepted skews
            # acceptance_rate low for short-tail requests (the self-proposer
            # oracle must report exactly 1.0 even on a request that hits its
            # budget mid-burst).  A burst that ends by REJECTION still
            # counts all k drafts — the rejected draft's unreached
            # successors were honestly proposed and scored, and dropping
            # them would bias acceptance upward for real proposers.
            proposed = min(n_emit if stopped else k, k)
            req.draft_proposed += proposed
            req.draft_accepted += min(n_acc, proposed)
            reg.counter("tokens_generated").inc(n_emit)
            reg.counter("decode_tokens").inc(n_emit)
            reg.counter("drafts_proposed").inc(proposed)
            reg.counter("drafts_accepted").inc(min(n_acc, proposed))
            self.telemetry.tracer.tokens(req.rid, now, n_emit)
            self._maybe_finish(req, now)
            if not req.done:
                # rollback is logical: the rejected suffix's positions are
                # simply beyond the new length — pages stay mapped within the
                # admission reservation and every position is rewritten
                # before it is next read
                self.proposer.on_accept(req)
        if (total := reg.counter("drafts_proposed").value):
            reg.gauge("spec_acceptance_rate").set(
                reg.counter("drafts_accepted").value / total)

    def _maybe_finish(self, req: Request, now: float) -> None:
        eos = self.config.eos_id
        reason = None
        if eos is not None and req.tokens and req.tokens[-1] == eos:
            reason = "eos"
        elif len(req.tokens) >= req.max_new:
            reason = "max_tokens"
        if reason is not None:
            self.sched.retire(req, reason, now)  # fires the "retire" span
            if self.paged:
                if self.prefix is not None:
                    # publish the whole conversation before releasing the
                    # slot — a continuation request (this prompt + these
                    # tokens + more) aliases it later.  The final token is
                    # excluded: it was emitted but never consumed, so its KV
                    # was never written; every position up to it holds the
                    # correct token's KV at retirement (a speculative
                    # correction is rewritten by the next burst's first row,
                    # and a retiring burst's correction IS the final token).
                    chain = np.concatenate(
                        [req.prompt, np.asarray(req.tokens[:-1], np.int32)])
                    self._prefix_insert(req, chain, now)
                self.cache.free(req.slot)
            elif self.backend == "statepool":
                # frees KV reservation + cross mapping + deactivates the
                # ring; CrossIndex pins keep a published cross page set
                # alive past this release
                self.cache.free(req.slot)
            if self.proposer is not None:
                self.proposer.on_retire(req)
            self.completed.append(req)
            reg = self.telemetry.registry
            reg.counter(f"requests_retired_{reason}").inc()
            if (tpc := req.tokens_per_decode_call()) is not None:
                reg.histogram("tokens_per_decode_call").observe(tpc)
