"""Continuous-batching inference engine.

``Engine`` multiplexes many generation requests over a fixed set of decode
slots:

* ``submit(prompt, max_new) -> Request`` queues work (the returned object is
  the handle; ``.tokens`` fills in as the engine runs),
* ``step()`` advances the world by one scheduler tick: admit queued requests
  into free slots, run one chunked-prefill call per prefilling request, then
  step every decoding slot in **one** jitted decode call,
* ``drain()`` steps until nothing is queued or active.

Model families with positional attention KV (``dense``/``moe``) store their
cache in :class:`PagedCache` pages — optionally MXFP4-packed (4.25
bits/element) with quantize-on-write.  Batched decode attends *directly over
the packed pool* via the fused Pallas paged-attention kernel (the raw pool +
int32 page tables are operands of the one jitted decode step; no dense
[L, B, T, Hkv, hd] gather is ever materialized).  The legacy
gather-dequantize decode survives as a parity oracle behind
``EngineConfig(decode_backend="gather")``.  Other families (SSM recurrent
state, hybrid, enc-dec / VLM cross-KV) fall back to :class:`DenseSlotCache`
but schedule identically.

Both paths reuse the same step builders as ``train.serve.greedy_generate``
(``make_chunk_prefill_step`` / ``make_decode_step``), so engine outputs are
token-for-token those of the reference loop in dense-cache mode.  Exactly
three shapes compile per engine: the ``[n_slots]`` decode, the
``[1, prefill_chunk]`` prefill chunk, and the ``[1, 1]`` remainder chunk.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.serve import paged_cache as P
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.train.serve import make_chunk_prefill_step, make_decode_step

PAGED_FAMILIES = ("dense", "moe")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128  # per-slot token capacity (prompt + generation)
    page_size: int = 16  # paged families only
    kv_dtype: str = "mxfp4"  # "mxfp4" | "dense" (paged families only)
    prefill_chunk: int = 16
    method: str = "quartet"
    eos_id: int | None = None
    keep_logits: bool = False  # record per-step logits on each Request (tests)
    # batched-decode attention path for paged families:
    #   None     — follow ModelConfig.attn_backend ("paged" unless overridden)
    #   "paged"  — fused Pallas kernel directly over the packed pool (default)
    #   "gather" — legacy gather-dequantize-to-dense oracle (parity testing)
    decode_backend: str | None = None


class Engine:
    def __init__(self, model: Model, params, config: EngineConfig | None = None):
        self.model, self.params = model, params
        self.config = cfg = config or EngineConfig()
        self.paged = model.cfg.family in PAGED_FAMILIES
        self.sched = Scheduler(cfg.n_slots, cfg.max_len, cfg.prefill_chunk)
        self.completed: list[Request] = []
        self._dtype = jnp.dtype(model.cfg.dtype)
        self.steps = 0

        if self.paged:
            pages_per_slot = -(-cfg.max_len // cfg.page_size)
            self.cache = P.PagedCache(
                model, n_slots=cfg.n_slots, pages_per_slot=pages_per_slot,
                page_size=cfg.page_size, kv_dtype=cfg.kv_dtype)
        else:
            self.cache = P.DenseSlotCache(model, n_slots=cfg.n_slots,
                                          max_len=cfg.max_len)

        decode = make_decode_step(model, method=cfg.method)
        chunk = make_chunk_prefill_step(model, method=cfg.method)
        ps = cfg.page_size

        if self.paged:
            self.decode_backend = cfg.decode_backend or (
                "paged" if model.cfg.attn_backend == "paged" else "gather")
            if self.decode_backend not in ("paged", "gather"):
                raise ValueError(f"decode_backend must be 'paged' or 'gather', "
                                 f"got {self.decode_backend!r}")
            n_layers = self.cache.layers

            if self.decode_backend == "paged":

                def decode_all(params, tokens, positions, pool, tables, mask):
                    """One decode step for every slot, attending directly over
                    the packed pool (no dense gather).  Masked lanes get an
                    all-zero table row, so their quantize-on-write lands on
                    the scratch page and their (meaningless) logits are
                    discarded."""
                    pos_safe = jnp.where(mask, positions, 0)
                    tbl = jnp.where(mask[:, None], tables, 0)
                    paged = P.PagedKV(
                        pool=pool,
                        tables=jnp.broadcast_to(tbl[None], (n_layers, *tbl.shape)))
                    logits, new_caches, _ = decode(params, tokens, pos_safe, paged)
                    return logits, new_caches.pool
            else:

                def decode_all(params, tokens, positions, pool, tables, mask):
                    """Gather-dequantize parity oracle: materializes the dense
                    [L, B, T, Hkv, hd] KV view each step."""
                    pos_safe = jnp.where(mask, positions, 0)
                    kv = P.gather_pages(pool, tables, self._dtype)
                    logits, (k2, v2), _ = decode(params, tokens, pos_safe, kv)
                    bidx = jnp.arange(tokens.shape[0])
                    k_new = k2[:, bidx, pos_safe]  # [L, B, Hkv, hd]
                    v_new = v2[:, bidx, pos_safe]
                    page_ids = tables[bidx, pos_safe // ps]
                    page_ids = jnp.where(mask, page_ids, 0)
                    pool = P.scatter_tokens(pool, page_ids, pos_safe % ps, k_new, v_new)
                    return logits, pool

            def prefill_chunk(params, tokens, start, table_row, pool, extra=None):
                """tokens [1, C] at absolute positions start..start+C for the
                slot mapped by ``table_row`` → (last-token logits, pool)."""
                kv = P.gather_pages(pool, table_row[None], self._dtype)
                logits, (k2, v2), _ = chunk(
                    params, tokens, jnp.full((1,), start, jnp.int32), kv, extra)
                C = tokens.shape[1]
                k_c = jax.lax.dynamic_slice_in_dim(k2, start, C, axis=2)[:, 0]
                v_c = jax.lax.dynamic_slice_in_dim(v2, start, C, axis=2)[:, 0]
                pos = start + jnp.arange(C)
                pool = P.scatter_tokens(pool, table_row[pos // ps], pos % ps, k_c, v_c)
                return logits, pool

            self._decode_all = jax.jit(decode_all)
            self._prefill_chunk = jax.jit(prefill_chunk)
        else:
            self.decode_backend = "dense_slots"

            def decode_all(params, tokens, positions, caches, mask):
                pos_safe = jnp.where(mask, positions, 0)
                logits, new_caches, _ = decode(params, tokens, pos_safe, caches)
                return logits, P.merge_masked(caches, new_caches, mask)

            def prefill_chunk(params, tokens, start, slot, caches, extra=None):
                sub = P.slice_slot(caches, slot)
                logits, new_sub, _ = chunk(
                    params, tokens, jnp.full((1,), start, jnp.int32), sub, extra)
                return logits, P.write_slot(caches, new_sub, slot)

            self._decode_all = jax.jit(decode_all)
            self._prefill_chunk = jax.jit(prefill_chunk)

    # ------------------------------------------------------------------ API

    def submit(self, prompt, max_new: int, extra: Any = None,
               arrival_time: float | None = None) -> Request:
        now = time.monotonic() if arrival_time is None else arrival_time
        return self.sched.submit(prompt, max_new, extra=extra, arrival_time=now)

    def step(self, now: float | None = None) -> dict:
        """One scheduler tick: admit → chunked prefill → batched decode →
        retire.  Returns a small summary dict (counts) for driver loops."""
        now = time.monotonic() if now is None else now
        cfg = self.config

        # -- admit ---------------------------------------------------------
        def can_admit(req: Request) -> bool:
            if not self.paged:
                return True
            return self.cache.can_alloc(req.prompt_len + req.max_new)

        admitted = self.sched.admit(can_admit)
        for req in admitted:
            if self.paged:
                self.cache.alloc(req.slot, req.prompt_len + req.max_new)
            else:
                self.cache.reset_slot(req.slot)

        # -- chunked prefill (one chunk per prefilling request per tick) ----
        for req in self.sched.prefilling():
            self._advance_prefill(req, now)

        # -- one batched decode over all decoding slots ---------------------
        decoding = self.sched.decoding()
        if decoding:
            self._decode_tick(decoding, now)

        self.steps += 1
        return {"admitted": len(admitted), "prefilling": len(self.sched.prefilling()),
                "decoding": len(self.sched.decoding()),
                "queued": len(self.sched.queue), "step": self.steps}

    def drain(self, max_steps: int = 100_000) -> list[Request]:
        """Step until every submitted request has finished."""
        while self.sched.pending:
            self.step()
            if self.steps > max_steps:
                raise RuntimeError("drain exceeded max_steps — engine wedged?")
        return self.completed

    def cache_bytes(self) -> int:
        return self.cache.cache_bytes()

    # ------------------------------------------------------------- internals

    def _run_prefill_call(self, req: Request, tokens_np: np.ndarray):
        start = jnp.int32(req.prefill_pos)
        tokens = jnp.asarray(tokens_np[None, :], jnp.int32)
        if self.paged:
            table_row = jnp.asarray(self.cache.tables[req.slot])
            logits, self.cache.pool = self._prefill_chunk(
                self.params, tokens, start, table_row, self.cache.pool, req.extra)
        else:
            logits, self.cache.caches = self._prefill_chunk(
                self.params, tokens, start, jnp.int32(req.slot),
                self.cache.caches, req.extra)
        req.prefill_pos += tokens_np.shape[0]
        return logits

    def _advance_prefill(self, req: Request, now: float) -> None:
        C = self.config.prefill_chunk
        remaining = req.prompt_len - req.prefill_pos
        if remaining >= C:
            logits = self._run_prefill_call(
                req, req.prompt[req.prefill_pos:req.prefill_pos + C])
        else:
            # remainder (< C tokens): single-token chunks — never pad, so SSM
            # recurrences and MoE routing only ever see real tokens
            for _ in range(remaining):
                logits = self._run_prefill_call(
                    req, req.prompt[req.prefill_pos:req.prefill_pos + 1])
        if req.prefill_pos == req.prompt_len:
            tok = int(jnp.argmax(logits[0]))
            if self.config.keep_logits:
                req.logits_trace.append(np.asarray(logits[0], np.float32))
            req.tokens.append(tok)
            req.first_token_time = now
            req.state = RequestState.DECODE
            self._maybe_finish(req, now)

    def _decode_tick(self, decoding: list[Request], now: float) -> None:
        B = self.config.n_slots
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        for req in decoding:
            tokens[req.slot, 0] = req.tokens[-1]
            positions[req.slot] = req.prompt_len + len(req.tokens) - 1
            mask[req.slot] = True
        args = (self.params, jnp.asarray(tokens), jnp.asarray(positions))
        if self.paged:
            logits, self.cache.pool = self._decode_all(
                *args, self.cache.pool, jnp.asarray(self.cache.tables),
                jnp.asarray(mask))
        else:
            logits, self.cache.caches = self._decode_all(
                *args, self.cache.caches, jnp.asarray(mask))
        logits_np = np.asarray(logits, np.float32)
        for req in decoding:
            tok = int(np.argmax(logits_np[req.slot]))
            if self.config.keep_logits:
                req.logits_trace.append(logits_np[req.slot])
            req.tokens.append(tok)
            self._maybe_finish(req, now)

    def _maybe_finish(self, req: Request, now: float) -> None:
        eos = self.config.eos_id
        reason = None
        if eos is not None and req.tokens and req.tokens[-1] == eos:
            reason = "eos"
        elif len(req.tokens) >= req.max_new:
            reason = "max_tokens"
        if reason is not None:
            self.sched.retire(req, reason, now)
            if self.paged:
                self.cache.free(req.slot)
            self.completed.append(req)
