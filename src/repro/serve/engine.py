"""Continuous-batching inference engine.

``Engine`` multiplexes many generation requests over a fixed set of decode
slots:

* ``submit(prompt, max_new, sampling=...) -> Request`` queues work (the
  returned object is the handle; ``.tokens`` fills in as the engine runs),
* ``step()`` advances the world by one scheduler tick: admit queued requests
  into free slots, run one chunked-prefill call per prefilling request, then
  step every decoding slot in **one** jitted call,
* ``drain()`` steps until nothing is queued or active.

Model families with positional attention KV (``dense``/``moe``) store their
cache in :class:`PagedCache` pages — optionally MXFP4-packed (4.25
bits/element) with quantize-on-write.  Batched decode attends *directly over
the packed pool* via the fused Pallas paged-attention kernel (the raw pool +
int32 page tables are operands of the one jitted decode step; no dense
[L, B, T, Hkv, hd] gather is ever materialized).  The legacy
gather-dequantize decode survives as a parity oracle behind
``EngineConfig(decode_backend="gather")``.  Other families (SSM recurrent
state, hybrid, enc-dec / VLM cross-KV) fall back to :class:`DenseSlotCache`
but schedule identically.

**Speculative decoding** (``EngineConfig(spec=SpecConfig(...))``, paged
families): each decode tick becomes draft → verify → accept.  A pluggable
proposer (``serve.spec.proposers``) drafts ``k`` tokens per slot; ONE jitted
verify call scores all ``k + 1`` tokens per slot directly over the packed
pool (multi-query paged-attention with per-row causal bounds); the host
accepts the longest draft prefix the target model itself reproduces and
emits 1..k+1 tokens.  Rejected suffixes are rolled back with
``PagedCache.truncate`` — the slot's logical length shrinks and
now-unreferenced trailing pages return to the free list.  Greedy
self-speculation is token-exact against the non-speculative engine (the
extended parity-oracle contract).

Sampling is per request (:class:`~repro.serve.sampling.SamplingParams`):
greedy argmax by default; temperature / top-k / top-p draws use stateless
per-token keys, which is also what lets the speculative verifier re-draw any
drafted position independently.

Both paths reuse the same step builders as ``train.serve.greedy_generate``
(``make_chunk_prefill_step`` / ``make_decode_step`` / ``make_verify_step``
via :func:`repro.serve.steps.build_paged_steps`), so engine outputs are
token-for-token those of the reference loop in dense-cache mode.  At most
four shapes compile per engine: the ``[n_slots]`` decode, the
``[n_slots, k+1]`` verify, the ``[1, prefill_chunk]`` prefill chunk, and the
``[1, 1]`` remainder chunk.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.serve import paged_cache as P
from repro.serve.sampling import SamplingParams, get_sampler
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.serve.spec.config import SpecConfig
from repro.serve.spec.proposers import build_proposer
from repro.serve.spec.verify import accept_tokens
from repro.serve.steps import build_paged_steps
from repro.train.serve import make_chunk_prefill_step, make_decode_step

PAGED_FAMILIES = ("dense", "moe")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128  # per-slot token capacity (prompt + generation)
    page_size: int = 16  # paged families only
    kv_dtype: str = "mxfp4"  # "mxfp4" | "dense" (paged families only)
    prefill_chunk: int = 16
    method: str = "quartet"
    eos_id: int | None = None
    keep_logits: bool = False  # record per-step logits on each Request (tests)
    # batched-decode attention path for paged families:
    #   None     — follow ModelConfig.attn_backend ("paged" unless overridden)
    #   "paged"  — fused Pallas kernel directly over the packed pool (default)
    #   "gather" — legacy gather-dequantize-to-dense oracle (parity testing)
    decode_backend: str | None = None
    # speculative decoding (paged families only); None → plain decode
    spec: SpecConfig | None = None


class Engine:
    def __init__(self, model: Model, params, config: EngineConfig | None = None):
        self.model, self.params = model, params
        self.config = cfg = config or EngineConfig()
        self.paged = model.cfg.family in PAGED_FAMILIES
        self.spec = cfg.spec
        if self.spec is not None and not self.paged:
            raise ValueError(
                f"speculative decoding needs a paged family (dense/moe), "
                f"got {model.cfg.family!r}")
        self.sched = Scheduler(cfg.n_slots, cfg.max_len, cfg.prefill_chunk)
        self.completed: list[Request] = []
        self._dtype = jnp.dtype(model.cfg.dtype)
        self.steps = 0

        if self.paged:
            # +k headroom: a verify burst writes up to k positions past the
            # request's reserved prompt+max_new window; ``ensure`` maps those
            # pages on demand and ``truncate`` returns the unused ones
            spec_k = self.spec.k if self.spec else 0
            pages_per_slot = -(-(cfg.max_len + spec_k) // cfg.page_size)
            self.cache = P.PagedCache(
                model, n_slots=cfg.n_slots, pages_per_slot=pages_per_slot,
                page_size=cfg.page_size, kv_dtype=cfg.kv_dtype)
            self.decode_backend = cfg.decode_backend or (
                "paged" if model.cfg.attn_backend == "paged" else "gather")
            self._steps = build_paged_steps(
                model, method=cfg.method, page_size=cfg.page_size,
                n_layers=self.cache.layers, decode_backend=self.decode_backend)
            self._decode_all = self._steps.decode_all
            self._prefill_chunk = self._steps.prefill_chunk
            self._verify_all = self._steps.verify_all
        else:
            self.cache = P.DenseSlotCache(model, n_slots=cfg.n_slots,
                                          max_len=cfg.max_len)
            self.decode_backend = "dense_slots"
            decode = make_decode_step(model, method=cfg.method)
            chunk = make_chunk_prefill_step(model, method=cfg.method)

            def decode_all(params, tokens, positions, caches, mask):
                pos_safe = jnp.where(mask, positions, 0)
                logits, new_caches, _ = decode(params, tokens, pos_safe, caches)
                return logits, P.merge_masked(caches, new_caches, mask)

            def prefill_chunk(params, tokens, start, slot, caches, extra=None):
                sub = P.slice_slot(caches, slot)
                logits, new_sub, _ = chunk(
                    params, tokens, jnp.full((1,), start, jnp.int32), sub, extra)
                return logits, P.write_slot(caches, new_sub, slot)

            self._decode_all = jax.jit(decode_all)
            self._prefill_chunk = jax.jit(prefill_chunk)

        self.proposer = (build_proposer(self, self.spec)
                         if self.spec is not None else None)

    # ------------------------------------------------------------------ API

    def submit(self, prompt, max_new: int, extra: Any = None,
               arrival_time: float | None = None,
               sampling: SamplingParams | None = None) -> Request:
        now = time.monotonic() if arrival_time is None else arrival_time
        return self.sched.submit(prompt, max_new, extra=extra, arrival_time=now,
                                 sampling=sampling)

    def step(self, now: float | None = None) -> dict:
        """One scheduler tick: admit → chunked prefill → batched decode (or
        draft/verify/accept with speculation on) → retire.  Returns a small
        summary dict (counts) for driver loops."""
        now = time.monotonic() if now is None else now
        cfg = self.config

        # -- admit ---------------------------------------------------------
        def can_admit(req: Request) -> bool:
            if not self.paged:
                return True
            return self.cache.can_alloc(req.prompt_len + req.max_new)

        admitted = self.sched.admit(can_admit)
        for req in admitted:
            if self.paged:
                self.cache.alloc(req.slot, req.prompt_len + req.max_new)
            else:
                self.cache.reset_slot(req.slot)
            if self.proposer is not None:
                self.proposer.on_admit(req)

        # -- chunked prefill (one chunk per prefilling request per tick) ----
        for req in self.sched.prefilling():
            self._advance_prefill(req, now)

        # -- one batched decode/verify over all decoding slots ---------------
        decoding = self.sched.decoding()
        if decoding:
            if self.spec is not None:
                self._spec_tick(decoding, now)
            else:
                self._decode_tick(decoding, now)

        self.steps += 1
        return {"admitted": len(admitted), "prefilling": len(self.sched.prefilling()),
                "decoding": len(self.sched.decoding()),
                "queued": len(self.sched.queue), "step": self.steps}

    def drain(self, max_steps: int = 100_000) -> list[Request]:
        """Step until every submitted request has finished."""
        while self.sched.pending:
            self.step()
            if self.steps > max_steps:
                raise RuntimeError("drain exceeded max_steps — engine wedged?")
        return self.completed

    def cache_bytes(self) -> int:
        return self.cache.cache_bytes()

    # ------------------------------------------------------------- internals

    def _sample(self, req: Request, logits_row, token_idx: int) -> int:
        """One token draw for ``req`` (greedy argmax unless the request set
        SamplingParams) — the single sampling call site for prefill, decode,
        drafting, and verification, keyed by generated-token index."""
        sp = req.sampling if req.sampling is not None else SamplingParams()
        return get_sampler(sp)(logits_row, token_idx)

    def _run_prefill_call(self, req: Request, tokens_np: np.ndarray):
        start = jnp.int32(req.prefill_pos)
        tokens = jnp.asarray(tokens_np[None, :], jnp.int32)
        if self.paged:
            table_row = jnp.asarray(self.cache.tables[req.slot])
            logits, self.cache.pool = self._prefill_chunk(
                self.params, tokens, start, table_row, self.cache.pool, req.extra)
        else:
            logits, self.cache.caches = self._prefill_chunk(
                self.params, tokens, start, jnp.int32(req.slot),
                self.cache.caches, req.extra)
        req.prefill_pos += tokens_np.shape[0]
        return logits

    def _advance_prefill(self, req: Request, now: float) -> None:
        C = self.config.prefill_chunk
        remaining = req.prompt_len - req.prefill_pos
        if remaining >= C:
            logits = self._run_prefill_call(
                req, req.prompt[req.prefill_pos:req.prefill_pos + C])
        else:
            # remainder (< C tokens): single-token chunks — never pad, so SSM
            # recurrences and MoE routing only ever see real tokens
            for _ in range(remaining):
                logits = self._run_prefill_call(
                    req, req.prompt[req.prefill_pos:req.prefill_pos + 1])
        if req.prefill_pos == req.prompt_len:
            logits_np = np.asarray(logits[0], np.float32)
            tok = self._sample(req, logits_np, 0)
            if self.config.keep_logits:
                req.logits_trace.append(logits_np)
            req.tokens.append(tok)
            req.first_token_time = now
            req.state = RequestState.DECODE
            self._maybe_finish(req, now)

    def _decode_tick(self, decoding: list[Request], now: float) -> None:
        B = self.config.n_slots
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        for req in decoding:
            tokens[req.slot, 0] = req.tokens[-1]
            positions[req.slot] = req.prompt_len + len(req.tokens) - 1
            mask[req.slot] = True
        args = (self.params, jnp.asarray(tokens), jnp.asarray(positions))
        if self.paged:
            logits, self.cache.pool = self._decode_all(
                *args, self.cache.pool, jnp.asarray(self.cache.tables),
                jnp.asarray(mask))
        else:
            logits, self.cache.caches = self._decode_all(
                *args, self.cache.caches, jnp.asarray(mask))
        logits_np = np.asarray(logits, np.float32)
        for req in decoding:
            tok = self._sample(req, logits_np[req.slot], len(req.tokens))
            if self.config.keep_logits:
                req.logits_trace.append(logits_np[req.slot])
            req.tokens.append(tok)
            req.decode_calls += 1
            self._maybe_finish(req, now)

    def _spec_tick(self, decoding: list[Request], now: float) -> None:
        """Draft → one batched verify → accept/rollback.

        Per slot with last accepted token t at position p0 and drafts
        d1..dk: the verify call feeds [t, d1..dk] at positions p0..p0+k
        (writing all k+1 tokens' KV before attending — the usual
        write-before-read causal invariant) and returns k+1 logit rows;
        row i is the target's distribution after consuming token i.  The
        host accepts the longest draft prefix the target's own draws
        reproduce, emits the correction/bonus draw, then truncates the
        slot back to its logical length so rejected-suffix pages free up.
        """
        cfg, k = self.config, self.spec.k
        B = cfg.n_slots
        eos = cfg.eos_id

        for req in decoding:  # map headroom for the burst before any writes
            p0 = req.prompt_len + len(req.tokens) - 1
            self.cache.ensure(req.slot, p0 + k + 1)

        drafts = self.proposer.propose(decoding)  # [n_slots, k] int32

        tokens = np.zeros((B, k + 1), np.int32)
        start = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        for req in decoding:
            tokens[req.slot, 0] = req.tokens[-1]
            tokens[req.slot, 1:] = drafts[req.slot]
            start[req.slot] = req.prompt_len + len(req.tokens) - 1
            mask[req.slot] = True
        logits, self.cache.pool = self._verify_all(
            self.params, jnp.asarray(tokens), jnp.asarray(start),
            self.cache.pool, jnp.asarray(self.cache.tables), jnp.asarray(mask))
        logits_np = np.asarray(logits, np.float32)  # [B, k+1, V]

        for req in decoding:
            base = len(req.tokens)
            target = [self._sample(req, logits_np[req.slot, i], base + i)
                      for i in range(k + 1)]
            n_acc, emitted = accept_tokens(drafts[req.slot].tolist(), target)
            req.decode_calls += 1
            req.draft_proposed += k
            req.draft_accepted += n_acc
            for i, tok in enumerate(emitted):
                if self.config.keep_logits:
                    req.logits_trace.append(logits_np[req.slot, i])
                req.tokens.append(tok)
                if ((eos is not None and tok == eos)
                        or len(req.tokens) >= req.max_new):
                    break  # emission stops at EOS / budget even mid-burst
            self._maybe_finish(req, now)
            if not req.done:
                # rollback: drop the rejected suffix's pages; valid KV covers
                # t and the accepted drafts, the freshly emitted token is fed
                # (and written) by the next tick
                logical = req.prompt_len + len(req.tokens) - 1
                self.cache.truncate(req.slot, logical)
                self.proposer.on_accept(req)

    def _maybe_finish(self, req: Request, now: float) -> None:
        eos = self.config.eos_id
        reason = None
        if eos is not None and req.tokens and req.tokens[-1] == eos:
            reason = "eos"
        elif len(req.tokens) >= req.max_new:
            reason = "max_tokens"
        if reason is not None:
            self.sched.retire(req, reason, now)
            if self.paged:
                self.cache.free(req.slot)
            if self.proposer is not None:
                self.proposer.on_retire(req)
            self.completed.append(req)
