"""Engine performance profiler: device cost accounting + Chrome-trace export.

Two halves, both opt-in via :class:`~repro.serve.telemetry.TelemetryConfig`
(``profile=True`` / ``profile_trace_path=...``) and both **host-side**: the
profiler never changes what the engine's step functions compile or compute
(the zero-interference contract extends to it — pinned by
``tests/test_profiler.py``).

**Per-phase device cost accounting** (:class:`EngineProfiler`).  Each jitted
step the engine owns — batched decode, batched paged prefill, per-slot chunk
prefill, speculative verify, TP-sharded ``shard_map`` variants included — is
AOT-lowered with the exact operand avals the engine feeds it and compiled
*out of band* (``fn.lower(...).compile()`` never touches the call-site jit
cache, so ``jit_compiled_*`` gauges are unaffected).  The compiled module
then goes through the scan-aware HLO analyzer in ``launch/roofline.py``
(``compiled.cost_analysis()`` alone under-counts ``lax.scan`` bodies), giving
model FLOPs, an HBM-traffic proxy, and collective bytes **per call**.  Paired
with the per-phase wall-time sections the engine already measures
(``decode_tick_s`` / ``prefill_tick_s`` / ``verify_tick_s``) this publishes,
per phase and per tick:

* ``roofline_util_<phase>``   — achieved FLOP/s over the peak (how far from
  compute-bound the tick ran),
* ``effective_bw_<phase>``    — HBM-proxy bytes/s actually sustained,
* ``profile_flops_per_call_<phase>`` / ``profile_hbm_bytes_per_call_<phase>``
  — the static per-call cost (the FP4 bytes win as a live number).

Interpret-mode caveat: on CPU the Pallas paged-attention kernel runs in
interpret mode, so its *internal* FLOPs/bytes surface only partially in the
HLO; per-call costs are exact on real backends and a floor here (see
``serve/README.md#observability``).  Utilization gauges divide by the v5e
constants from ``launch.roofline`` unless overridden — on CPU they are
relative numbers for A/B deltas, not absolute hardware truth.

**Chrome-trace export** (:class:`TraceEventSink`).  Engine ticks and their
phase sections, request lifecycles (``queued → prefill → decode`` spans from
the existing :class:`~repro.serve.telemetry.tracing.Tracer`), and
jit-compile events are rendered as Chrome trace-event JSON —
``chrome://tracing`` / Perfetto's legacy JSON format — on ONE shared clock:
the engine's ``now`` (virtual or wall), with intra-tick phase offsets taken
from the same ``perf_counter`` deltas that advance the virtual clock.
Replicas map to trace *processes* (``pid`` = replica index), so a
data-parallel engine renders as parallel lanes; within a process, lane 0 is
the tick/phase timeline and each request gets its own named thread lane.
"""

from __future__ import annotations

import dataclasses
import json

import jax
import numpy as np

from repro.launch.roofline import HBM_BW, PEAK_FLOPS_BF16, analyze_compiled

# engine phase -> (calls counter, paged step, fallback step) — the fallback
# covers the gather oracle / dense-slot families whose prefill is the
# per-slot [1, C] chunk loop
PHASES = ("prefill", "decode", "verify")
_PHASE_COUNTERS = {"prefill": "prefill_calls", "decode": "decode_calls",
                   "verify": "verify_calls"}

# trace lanes (tid) inside one engine process (pid)
TID_ENGINE = 0
TID_REQ_BASE = 2


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Per-call device cost of one jitted step, from its compiled HLO."""

    flops: float
    hbm_bytes: float
    collective_bytes: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _aval(x):
    return jax.ShapeDtypeStruct(np.shape(x), np.result_type(x)) \
        if np.isscalar(x) else jax.ShapeDtypeStruct(x.shape, x.dtype)


def _avals(tree):
    return jax.tree_util.tree_map(_aval, tree)


def lower_step_cost(fn, example_args) -> StepCost | None:
    """AOT-lower ``fn`` at ``example_args``'s avals, compile out of band, and
    run the scan-aware roofline analyzer.  Returns ``None`` for steps that
    cannot be lowered (e.g. the TP chunk-prefill convenience lambda).

    This deliberately does NOT call the jitted function: ``lower().compile()``
    produces its own executable and leaves the call-site cache — and
    therefore the engine's ``jit_compiled_*`` gauges and the
    one-compile-per-shape contract — untouched.
    """
    lower = getattr(fn, "lower", None)
    if lower is None:
        return None
    compiled = lower(*_avals(example_args)).compile()
    rep = analyze_compiled(compiled)
    return StepCost(flops=float(rep["flops"]),
                    hbm_bytes=float(rep["mem_bytes"]),
                    collective_bytes=float(rep["total_collective_bytes"]))


def step_example_args(engine) -> dict[str, tuple]:
    """Example operands per jitted step, mirroring exactly what
    ``Engine.step`` marshals (shapes only matter — values are never run)."""
    cfg = engine.config
    B, C = cfg.n_slots, cfg.prefill_chunk
    i32, b8 = np.int32, np.bool_
    tok = lambda s: np.zeros((B, s), i32)
    vec = np.zeros((B,), i32)
    mask = np.zeros((B,), b8)
    params = engine.params
    if engine.paged:
        pool, tables = engine.cache.pool, np.asarray(engine.cache.tables)
        out = {
            "decode_all": (params, tok(1), vec, pool, tables, mask),
            "prefill_chunk": (params, np.zeros((1, C), i32), np.int32(0),
                              tables[0], pool, None),
        }
        if engine._prefill_all is not None:
            out["prefill_all"] = (params, tok(C), vec, vec, pool, tables, mask)
        if engine.spec is not None:
            out["verify_all"] = (params, tok(engine.spec.k + 1), vec, pool,
                                 tables, mask)
        return out
    if engine.backend == "statepool":
        sp = engine.cache
        state = sp.pools()
        kv_tables = np.asarray(sp.kv.tables) if sp.kv is not None else None
        cross_tables = (np.asarray(sp.cross.tables)
                        if sp.cross is not None else None)
        ring1 = np.zeros((1,), i32)
        # extra=None mirrors the dense-slot convention: the lowered cross
        # path reads the pooled plane (decode semantics) — close enough for
        # cost accounting, and shape-compatible for every family
        return {
            "decode_all": (params, tok(1), vec, state, kv_tables, cross_tables,
                           vec, vec, mask),
            "prefill_chunk": (params, np.zeros((1, C), i32), np.int32(0), state,
                              None if kv_tables is None else kv_tables[0],
                              None if cross_tables is None else cross_tables[0],
                              ring1, ring1, None),
        }
    caches = engine.cache.caches
    return {
        "decode_all": (params, tok(1), vec, caches, mask),
        "prefill_chunk": (params, np.zeros((1, C), i32), np.int32(0),
                          np.int32(0), caches, None),
    }


def _phase_step(engine, phase: str) -> str | None:
    """Which jitted step one engine phase spends its device time in."""
    if phase == "decode":
        return "decode_all"
    if phase == "verify":
        return "verify_all" if engine.spec is not None else None
    if phase == "prefill":
        return "prefill_all" if engine._prefill_all is not None else "prefill_chunk"
    return None


class TraceEventSink:
    """Accumulates Chrome trace-event JSON objects for one engine process.

    Complete (``ph: "X"``) events carry microsecond ``ts``/``dur`` on the
    engine's clock; instant (``ph: "i"``) events mark point occurrences like
    jit compiles.  ``write_trace`` merges any number of sinks (one per
    replica) into one Perfetto-loadable document.
    """

    def __init__(self, pid: int = 0, process_name: str = "engine"):
        self.pid = pid
        self.process_name = process_name
        self._events: list[dict] = []
        self._thread_names: dict[int, str] = {TID_ENGINE: "engine ticks"}

    def complete(self, name: str, cat: str, ts_s: float, dur_s: float,
                 tid: int = TID_ENGINE, args: dict | None = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "X", "pid": self.pid, "tid": tid,
              "ts": round(ts_s * 1e6, 3), "dur": round(max(dur_s, 0.0) * 1e6, 3)}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, name: str, cat: str, ts_s: float,
                tid: int = TID_ENGINE, args: dict | None = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t", "pid": self.pid,
              "tid": tid, "ts": round(ts_s * 1e6, 3)}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def thread_name(self, tid: int, name: str) -> None:
        self._thread_names.setdefault(tid, name)

    def trace_events(self) -> list[dict]:
        """Metadata events first, then payload sorted by timestamp (Perfetto
        tolerates unsorted input; sorted keeps the monotonicity testable)."""
        meta = [{"name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
                 "args": {"name": self.process_name}}]
        for tid, name in sorted(self._thread_names.items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                         "tid": tid, "args": {"name": name}})
        return meta + sorted(self._events, key=lambda e: (e["ts"], e["tid"]))


def write_trace(path: str, sinks) -> dict:
    """Merge sinks (one per replica) into one trace-event JSON document."""
    doc = {"traceEvents": [ev for s in sinks for ev in s.trace_events()],
           "displayTimeUnit": "ms"}
    with open(path, "w") as fh:
        json.dump(doc, fh)
        fh.write("\n")
    return doc


class EngineProfiler:
    """Per-engine performance profiler: step cost accounting, roofline /
    bandwidth gauges, and the tick/request/compile trace timeline.

    Created by :class:`~repro.serve.telemetry.EngineTelemetry` when profiling
    is configured; the telemetry hub forwards phase sections
    (:meth:`on_phase`), tick boundaries (:meth:`on_tick`), compile-count
    bumps (:meth:`compile_event`), and finalization (:meth:`finalize`).
    Everything is lazy: a step's HLO is analyzed the first time its phase
    fires (or on an explicit :meth:`phase_costs` call), out of band of the
    measured sections.
    """

    def __init__(self, engine, registry, *, trace_path: str | None = None,
                 pid: int = 0, peak_flops: float = PEAK_FLOPS_BF16,
                 peak_bw: float = HBM_BW):
        self.engine = engine
        self.registry = registry
        self.trace_path = trace_path
        self.peak_flops = peak_flops
        self.peak_bw = peak_bw
        self.sink = TraceEventSink(pid=pid)
        self._costs: dict[str, StepCost | None] = {}
        self._seen_calls: dict[str, int] = {}
        # accumulated (flops, bytes, wall_s, ticks) per phase for run means
        self._accum: dict[str, list[float]] = {p: [0.0, 0.0, 0.0, 0.0]
                                               for p in PHASES}
        self._finalized = False

    @property
    def pid(self) -> int:
        return self.sink.pid

    @pid.setter
    def pid(self, value: int) -> None:
        self.sink.pid = int(value)

    # -- cost accounting ----------------------------------------------------

    def step_cost(self, name: str) -> StepCost | None:
        """Per-call cost of one jitted step (memoized; ``None`` when the step
        does not exist on this engine or cannot be lowered)."""
        if name not in self._costs:
            examples = step_example_args(self.engine)
            if name not in examples:
                self._costs[name] = None
            else:
                fn = getattr(self.engine, "_steps", None)
                fn = getattr(fn, name, None) if fn is not None else None
                if fn is None:  # dense-slot engines keep bare jitted attrs
                    fn = getattr(self.engine, f"_{name}", None)
                self._costs[name] = (lower_step_cost(fn, examples[name])
                                     if fn is not None else None)
        return self._costs[name]

    def phase_costs(self) -> dict[str, dict]:
        """Per-call cost for every step this engine owns — deterministic for
        a fixed engine config (the HLO is a pure function of the avals)."""
        out = {}
        for name in step_example_args(self.engine):
            cost = self.step_cost(name)
            if cost is not None:
                out[name] = cost.to_dict()
        return out

    # -- live hooks (called by EngineTelemetry) ------------------------------

    def on_phase(self, phase: str, start_t: float, dur_s: float) -> None:
        """One tick's phase section finished: trace it and refresh the
        roofline/bandwidth gauges from (cost per call) x (calls this tick)."""
        step = _phase_step(self.engine, phase)
        cost = self.step_cost(step) if step is not None else None
        counter = self.registry.counter(_PHASE_COUNTERS[phase]).value
        ncalls = counter - self._seen_calls.get(phase, 0)
        self._seen_calls[phase] = counter
        args = {"calls": ncalls}
        if cost is not None and ncalls > 0 and dur_s > 0:
            flops = cost.flops * ncalls
            hbm = cost.hbm_bytes * ncalls
            g = self.registry.gauge
            g(f"profile_flops_per_call_{phase}").set(cost.flops)
            g(f"profile_hbm_bytes_per_call_{phase}").set(cost.hbm_bytes)
            g(f"roofline_util_{phase}").set(flops / dur_s / self.peak_flops)
            g(f"effective_bw_{phase}").set(hbm / dur_s)
            acc = self._accum[phase]
            acc[0] += flops
            acc[1] += hbm
            acc[2] += dur_s
            acc[3] += 1
            args.update(gflops=round(flops / 1e9, 3),
                        mb=round(hbm / 1e6, 3))
        self.sink.complete(phase, "phase", start_t, dur_s, TID_ENGINE, args)

    def on_tick(self, engine, now: float, wall_s: float) -> None:
        self.sink.complete("tick", "tick", now, wall_s, TID_ENGINE,
                           {"step": engine.steps})

    def compile_event(self, step: str, t: float, count: int) -> None:
        self.sink.instant(f"jit_compile:{step}", "compile", t, TID_ENGINE,
                          {"compiled_variants": count})

    # -- summaries / export --------------------------------------------------

    def utilization_summary(self) -> dict:
        """Run-mean utilization per phase: totals over every profiled tick
        (robust to per-tick jitter, unlike the last-tick gauges)."""
        out = {"peak_flops": self.peak_flops, "peak_bw": self.peak_bw}
        for phase in PHASES:
            flops, hbm, wall, ticks = self._accum[phase]
            step = _phase_step(self.engine, phase)
            cost = self._costs.get(step) if step is not None else None
            if cost is None or not ticks:
                out[phase] = None
                continue
            out[phase] = {
                "flops_per_call": cost.flops,
                "hbm_bytes_per_call": cost.hbm_bytes,
                "calls": self._seen_calls.get(phase, 0),
                "wall_s": round(wall, 6),
                "roofline_util_mean": (flops / wall / self.peak_flops
                                       if wall > 0 else None),
                "effective_bw_mean": hbm / wall if wall > 0 else None,
            }
        return out

    def add_request_traces(self, traces) -> None:
        """Render retired requests' lifecycle spans into per-request lanes."""
        for tr in traces:
            tid = TID_REQ_BASE + tr.rid
            self.sink.thread_name(tid, f"req {tr.rid}")
            for name, a, b in tr.spans():
                self.sink.complete(name, "request", a, b - a, tid,
                                   {"rid": tr.rid})
            for t, n in tr.token_times:
                self.sink.instant("tokens", "request", t, tid, {"n": n})

    def finalize(self, tracer=None) -> str | None:
        """Fold completed request traces in and write the trace file (when a
        path is configured).  Idempotent."""
        if self._finalized:
            return self.trace_path
        self._finalized = True
        if tracer is not None:
            self.add_request_traces(tracer.completed)
        if self.trace_path:
            write_trace(self.trace_path, [self.sink])
            return self.trace_path
        return None


def validate_trace(doc: dict) -> list[str]:
    """Structural validation of a trace-event JSON document (the shape
    Perfetto's legacy-JSON importer requires).  Returns human-readable
    errors; empty means loadable."""
    errors: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list) or not evs:
        return ["traceEvents must be a non-empty list"]
    last_ts: dict[tuple, float] = {}
    for i, ev in enumerate(evs):
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                errors.append(f"event {i}: missing {key}")
        ph = ev.get("ph")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i}: complete event with bad dur {dur!r}")
        lane = (ev.get("pid"), ev.get("tid"))
        if ts < last_ts.get(lane, -np.inf):
            errors.append(f"event {i}: ts {ts} not monotonic on lane {lane}")
        last_ts[lane] = ts
    return errors


def validate_trace_file(path: str) -> dict:
    with open(path) as fh:
        doc = json.load(fh)
    errors = validate_trace(doc)
    if errors:
        raise ValueError(f"{path} failed trace validation:\n  "
                         + "\n  ".join(errors))
    return doc


def profile_report(engine, snapshot: dict, *,
                   peak_flops: float = PEAK_FLOPS_BF16,
                   peak_bw: float = HBM_BW) -> dict | None:
    """Post-hoc per-phase cost/utilization report from a finished run's
    telemetry snapshot — the benchmark path: cost-account the steps AFTER the
    timed region and pair them with the measured phase wall-time histograms
    (no live profiler, zero impact on the timed numbers).

    Returns the ``profile`` block of ``BENCH_serve.json`` (schema v4), or
    ``None`` for engines with nothing to account (no jitted steps lowered).
    """
    prof = EngineProfiler(engine, registry=None, peak_flops=peak_flops,
                          peak_bw=peak_bw)
    costs = {name: StepCost(**c) for name, c in prof.phase_costs().items()}
    if not costs:
        return None
    hists, counters = snapshot["histograms"], snapshot["counters"]
    out: dict = {"peak_flops": peak_flops, "peak_bw": peak_bw}
    for phase in PHASES:
        step = _phase_step(engine, phase)
        cost = costs.get(step) if step is not None else None
        wall = (hists.get(f"{phase}_tick_s") or {}).get("sum", 0.0)
        calls = counters.get(_PHASE_COUNTERS[phase], 0)
        if cost is None or not calls:
            out[phase] = None
            continue
        flops, hbm = cost.flops * calls, cost.hbm_bytes * calls
        out[phase] = {
            "flops_per_call": cost.flops,
            "hbm_bytes_per_call": cost.hbm_bytes,
            "calls": calls,
            "wall_s": round(wall, 6),
            "roofline_util_mean": (flops / wall / peak_flops
                                   if wall > 0 else None),
            "effective_bw_mean": hbm / wall if wall > 0 else None,
        }
    return out
