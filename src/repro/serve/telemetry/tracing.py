"""Request-lifecycle tracing: spans + first-class latency derivation.

Every request leaves a :class:`RequestTrace` — the event timestamps
``submit → admit → first_token → retire`` plus per-tick token-emission
timestamps ``(t, n_tokens)``.  From those the tracer derives the serving
latencies as *first-class metrics* (fed straight into the registry's
histograms on retire, rather than recomputed by every benchmark):

* ``queue_wait_s``  = admit − submit,
* ``ttft_s``        = first_token − submit,
* ``tpot_s``        = (last_token_t − first_token_t) / (n_tokens − 1)
  (time-per-output-token over the decode phase; ``None`` for single-token
  requests),
* ``request_latency_s`` = retire − submit.

Timestamps are whatever clock the engine is driven on — wall time in live
serving, the virtual clock in ``benchmarks/serve_throughput.py`` — the
derivations only ever subtract them.  With a trace path configured, each
retired request is appended as one JSON line (rid, spans, events, token
timeline, derived latencies); the last ``keep`` completed traces stay
in memory for tests and post-run inspection.
"""

from __future__ import annotations

import dataclasses
import json
from collections import deque

SPAN_EVENTS = ("submit", "admit", "first_token", "retire")


@dataclasses.dataclass
class RequestTrace:
    rid: int
    events: list = dataclasses.field(default_factory=list)  # [(name, t)]
    token_times: list = dataclasses.field(default_factory=list)  # [(t, n)]

    def event_time(self, name: str) -> float | None:
        for n, t in self.events:
            if n == name:
                return t
        return None

    @property
    def n_tokens(self) -> int:
        return sum(n for _, n in self.token_times)

    def spans(self) -> list[tuple[str, float, float]]:
        """Derived (name, start, end) spans: queued → prefill → decode."""
        out = []
        for name, a, b in (("queued", "submit", "admit"),
                           ("prefill", "admit", "first_token"),
                           ("decode", "first_token", "retire")):
            ta, tb = self.event_time(a), self.event_time(b)
            if ta is not None and tb is not None:
                out.append((name, ta, tb))
        return out

    def derived(self) -> dict:
        sub, adm = self.event_time("submit"), self.event_time("admit")
        ft, ret = self.event_time("first_token"), self.event_time("retire")
        n = self.n_tokens
        tpot = None
        if n > 1 and ft is not None and self.token_times:
            tpot = (self.token_times[-1][0] - ft) / (n - 1)
        return {
            "queue_wait_s": adm - sub if None not in (adm, sub) else None,
            "ttft_s": ft - sub if None not in (ft, sub) else None,
            "tpot_s": tpot,
            "request_latency_s": ret - sub if None not in (ret, sub) else None,
            "n_tokens": n,
        }


class Tracer:
    """Collects per-request traces; feeds latency histograms on retire.

    The engine (and scheduler) report events by request id — the tracer owns
    no request objects.  ``registry`` may be ``None`` (tracing without
    metrics); ``path`` may be ``None`` (metrics without a trace file).
    """

    def __init__(self, registry=None, path: str | None = None, keep: int = 1024):
        self.registry = registry
        self._fh = open(path, "w") if path else None
        self.active: dict[int, RequestTrace] = {}
        self.completed: deque[RequestTrace] = deque(maxlen=max(keep, 1))

    def event(self, rid: int, name: str, t: float) -> None:
        tr = self.active.get(rid)
        if tr is None:
            tr = self.active[rid] = RequestTrace(rid)
        tr.events.append((name, t))
        if name == "retire":
            self._finish(tr)

    def tokens(self, rid: int, t: float, n: int) -> None:
        tr = self.active.get(rid)
        if tr is not None and n > 0:
            tr.token_times.append((t, n))

    def _finish(self, tr: RequestTrace) -> None:
        d = tr.derived()
        if self.registry is not None:
            for name in ("queue_wait_s", "ttft_s", "tpot_s", "request_latency_s"):
                if d[name] is not None:
                    self.registry.histogram(name).observe(d[name])
        if self._fh is not None:
            self._fh.write(json.dumps({
                "rid": tr.rid,
                "spans": [[n, a, b] for n, a, b in tr.spans()],
                "events": [[n, t] for n, t in tr.events],
                "tokens": [[t, n] for t, n in tr.token_times],
                "derived": d,
            }) + "\n")
            self._fh.flush()
        self.completed.append(tr)
        del self.active[tr.rid]

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
