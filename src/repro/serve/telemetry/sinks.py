"""Pluggable sinks for registry snapshots.

A sink consumes :meth:`MetricsRegistry.snapshot` dicts; the engine's
telemetry hub calls ``emit`` at a tick stride and once more on finalize.
All sinks are host-only and exception-tolerant writers — losing a metrics
line must never take the engine down with it.

* :class:`NullSink` — drops everything (the zero-overhead default; the
  compile-count guard in ``tests/test_telemetry.py`` pins that instrumented
  engines with this sink compile exactly the same step shapes as the seed).
* :class:`JsonlSink` — one JSON object per emit, appended to a file: the
  stream ``benchmarks/serve_throughput.py --smoke`` validates.
* :class:`PrometheusTextSink` — full text exposition rewritten atomically
  on every emit (point a file scraper at it).
* :class:`ConsoleSink` — a compact human summary every N emits.
"""

from __future__ import annotations

import json
import os
import sys


class Sink:
    def emit(self, snapshot: dict, registry=None) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class NullSink(Sink):
    def emit(self, snapshot: dict, registry=None) -> None:
        pass


class JsonlSink(Sink):
    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "w")

    def emit(self, snapshot: dict, registry=None) -> None:
        self._fh.write(json.dumps(snapshot) + "\n")
        self._fh.flush()

    def close(self) -> None:
        self._fh.close()


class PrometheusTextSink(Sink):
    def __init__(self, path: str):
        self.path = path

    def emit(self, snapshot: dict, registry=None) -> None:
        if registry is None:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(registry.prometheus_text())
        os.replace(tmp, self.path)


class ConsoleSink(Sink):
    def __init__(self, every: int = 1, stream=None):
        self.every = max(1, every)
        self.stream = stream if stream is not None else sys.stderr
        self._n = 0

    def emit(self, snapshot: dict, registry=None) -> None:
        self._n += 1
        if self._n % self.every:
            return
        print(render_summary(snapshot), file=self.stream)


def _fmt(v, unit: str = "") -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}{unit}"
    return f"{v}{unit}"


def render_summary(snapshot: dict) -> str:
    """Compact fixed-order console table of the serving metrics that matter
    at a glance — shared by :class:`ConsoleSink` and the launchers' final
    summaries (replacing their hand-rolled per-request prints)."""
    c, g, h, r = (snapshot.get(k, {}) for k in
                  ("counters", "gauges", "histograms", "rates"))

    def hp(name, q):
        s = h.get(name) or {}
        return s.get(q)

    meta = snapshot.get("meta", {})
    head = " ".join(f"{k}={v}" for k, v in meta.items() if v is not None)
    rows = [
        ("t", _fmt(snapshot.get("t"), "s"), "ticks", _fmt(c.get("engine_ticks"))),
        ("queue", _fmt(g.get("queue_depth")), "active",
         f"{_fmt(g.get('slots_prefilling'))}p/{_fmt(g.get('slots_decoding'))}d"),
        ("submitted", _fmt(c.get("requests_submitted")), "retired",
         _fmt((c.get("requests_retired_eos") or 0)
              + (c.get("requests_retired_max_tokens") or 0))),
        ("tokens", _fmt(c.get("tokens_generated")), "tok/s(ewma)",
         _fmt(r.get("tokens_per_sec_ewma"))),
        ("ttft p50/p95", f"{_fmt(hp('ttft_s', 'p50'), 's')}/"
                         f"{_fmt(hp('ttft_s', 'p95'), 's')}",
         "tpot p50", _fmt(hp("tpot_s", "p50"), "s")),
        ("decode tick p50", _fmt(hp("decode_tick_s", "p50"), "s"),
         "verify tick p50", _fmt(hp("verify_tick_s", "p50"), "s")),
        ("pool occ", _fmt(g.get("pool_occupancy")), "free low-wm",
         _fmt(g.get("pool_pages_free_watermark"))),
        # per-slot decode tokens per batched call (the speculative-decoding
        # gain), from the per-request histogram — the raw counter ratio
        # decode_tokens/calls would conflate batch width with spec gain
        ("tok/decode-call", _fmt(hp("tokens_per_decode_call", "p50")),
         "acceptance", _fmt(
            (c.get("drafts_accepted") or 0) / dp
            if (dp := c.get("drafts_proposed") or 0) else None)),
        ("kv clip k/v", f"{_fmt(g.get('kv_clip_fraction_k'))}/"
                        f"{_fmt(g.get('kv_clip_fraction_v'))}",
         "scale bins", _fmt((snapshot.get("binned", {})
                             .get("kv_scale_hist_k") or {}).get("nonzero_bins"))),
    ]
    width = max(len(a) for a, _, _, _ in rows)
    w2 = max(len(x) for _, _, x, _ in rows)
    body = "\n".join(f"  {a:<{width}} {b:>10}   {x:<{w2}} {y:>10}"
                     for a, b, x, y in rows)
    return f"[telemetry] {head}\n{body}"
