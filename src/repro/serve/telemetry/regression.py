"""Bench-regression gate: compare a fresh ``BENCH_serve.json`` against the
committed baseline with per-metric tolerance bands.

``BENCH_serve.json`` has been persisted and schema-validated by CI since
PR 6 — but never *compared*, so a silent perf regression ships clean.  This
module closes that loop:

* both documents are flattened to dotted leaf paths
  (``throughput.mxfp4_paged_tok_per_s``, ``kv.cache_ratio``, …),
* each path is matched (first hit wins, ``fnmatch`` patterns) against
  :data:`RULES`, which give a *direction* (which way is worse), a relative
  tolerance band, and a *severity*:

  - ``hard`` — deterministic facts of the build: schema/arch/family/config
    identity, cache-byte counts and compression ratios, FP4 bytes-ratio
    wins, spec acceptance on the self-proposer, prefix hit rate.  Any drift
    outside the (tight) band is a real behavior change → nonzero exit.
  - ``soft`` — wall-clock metrics (throughput, TTFT/TPOT, tick times) that
    are meaningful on dedicated hardware but noisy on shared CPU CI.
    Violations print a visible warning and fail only under ``--strict``.
  - ``info`` — reported in the delta table, never gated (pool occupancy
    shifts with legitimate scheduling changes; quant health is
    workload-dependent; profile FLOPs/bytes drift with XLA versions).

* nullable sections are handled explicitly: both-null is a match, a hard
  field going null (a parity measurement disappearing) is a hard failure,
  and newly-present fields are informational.

CLI (the CI gate)::

    python -m repro.serve.telemetry.regression fresh.json \
        [--baseline BENCH_serve.json] [--strict] [--json report.json]

Exit status: 0 clean (soft warnings allowed), 1 regression (hard, or any
with ``--strict``), 2 unreadable/incomparable inputs.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import numbers
from fnmatch import fnmatch

HARD, SOFT, INFO = "hard", "soft", "info"

# (dotted-path pattern, direction, relative tolerance, severity).
# direction: "exact" (any change fails), "lower_worse" (fail when the fresh
# value drops below baseline by more than tol), "higher_worse" (fail when it
# rises above by more than tol), "any" (fail when |rel delta| exceeds tol).
# First matching rule wins; unmatched numeric leaves default to INFO.
RULES: tuple[tuple[str, str, float, str], ...] = (
    # identity / parity — deterministic, hard
    ("schema", "exact", 0.0, HARD),
    ("arch", "exact", 0.0, HARD),
    ("family", "exact", 0.0, HARD),
    ("config.*", "exact", 0.0, HARD),
    ("kv.cache_bytes_dense", "exact", 0.0, HARD),
    ("kv.cache_bytes_mxfp4", "exact", 0.0, HARD),
    ("kv.bits_per_elem_mxfp4", "any", 1e-6, HARD),
    ("kv.cache_ratio", "lower_worse", 0.01, HARD),
    ("kv.decode_bytes_ratio_gather_over_paged", "lower_worse", 0.01, HARD),
    ("kv.prefill_bytes_ratio_gather_over_paged", "lower_worse", 0.01, HARD),
    ("spec.k", "exact", 0.0, HARD),
    ("spec.proposer", "exact", 0.0, HARD),
    ("spec.acceptance_rate", "lower_worse", 0.01, HARD),
    ("spec.tokens_per_decode_call", "lower_worse", 0.05, HARD),
    ("prefix.hit_rate", "lower_worse", 0.01, HARD),
    ("prefix.shared_tokens", "lower_worse", 0.01, HARD),
    ("sharding.tp_run.parity_vs_single", "lower_worse", 0.0, HARD),
    ("sharding.dp_run.parity_vs_single", "lower_worse", 0.0, HARD),
    # wall-clock — soft (CPU CI noise); bands sized for shared runners
    ("throughput.*", "lower_worse", 0.15, SOFT),
    ("latency.*", "higher_worse", 0.50, SOFT),
    ("tick.*", "higher_worse", 0.75, SOFT),
    ("prefix.*ttft*", "higher_worse", 0.50, SOFT),
    ("prefix.*tok_per_s", "lower_worse", 0.25, SOFT),
    ("sharding.*tok_per_s", "lower_worse", 0.25, SOFT),
    ("sharding.*speedup*", "lower_worse", 0.25, SOFT),
    # profile cost accounting — HLO facts, but they drift across XLA
    # versions; a *rise* in per-call cost is the interesting direction
    ("profile.*flops_per_call", "higher_worse", 0.10, SOFT),
    ("profile.*hbm_bytes_per_call", "higher_worse", 0.10, SOFT),
    # state-pool family A/B (schema v5): parity vs the dense-slot oracle and
    # the pooled state-bytes win are deterministic facts — hard; per-family
    # throughput is wall-clock — soft
    ("families.*.token_parity", "lower_worse", 0.0, HARD),
    ("families.*.state_bytes_ratio", "lower_worse", 0.02, HARD),
    ("families.*tok_per_s", "lower_worse", 0.25, SOFT),
    # everything else (pool occupancy, quant health, utilizations, walls,
    # counters-of-calls) — informational only
    ("*", "any", 0.0, INFO),
)


@dataclasses.dataclass
class Delta:
    """One compared leaf: baseline vs fresh plus the verdict."""

    path: str
    base: object
    fresh: object
    direction: str
    tol: float
    severity: str       # hard / soft / info
    status: str         # ok / warn / fail / info / new / gone
    rel: float | None   # signed relative delta where defined

    @property
    def failed(self) -> bool:
        return self.status == "fail"

    @property
    def warned(self) -> bool:
        return self.status == "warn"


def flatten(doc: dict, prefix: str = "") -> dict[str, object]:
    """Dotted-path → leaf value (numbers, strings, None).  Lists are left
    opaque (the bench schema has none at gate-relevant depth)."""
    out: dict[str, object] = {}
    for key, v in doc.items():
        path = f"{prefix}{key}"
        if isinstance(v, dict):
            out.update(flatten(v, f"{path}."))
        else:
            out[path] = v
    return out


def _rule_for(path: str) -> tuple[str, float, str]:
    for pat, direction, tol, severity in RULES:
        if fnmatch(path, pat):
            return direction, tol, severity
    return "any", 0.0, INFO


def _is_num(v) -> bool:
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def compare(baseline: dict, fresh: dict) -> list[Delta]:
    """Flatten both docs and judge every leaf in the union of their paths."""
    base_flat, fresh_flat = flatten(baseline), flatten(fresh)
    deltas: list[Delta] = []
    for path in sorted(set(base_flat) | set(fresh_flat)):
        direction, tol, severity = _rule_for(path)
        b = base_flat.get(path)
        f = fresh_flat.get(path)
        rel = None
        if path not in base_flat or (b is None and f is not None):
            status = "new"  # newly measured — informational
        elif path not in fresh_flat or (f is None and b is not None):
            # a measurement disappearing is itself a regression for gated
            # fields (a parity/ratio going null means the path is gone)
            status = "fail" if severity == HARD else (
                "warn" if severity == SOFT else "gone")
        elif b is None and f is None:
            status = "ok"
        elif direction == "exact" or not (_is_num(b) and _is_num(f)):
            status = "ok" if b == f else (
                "fail" if severity == HARD else
                "warn" if severity == SOFT else "info")
        else:
            rel = (f - b) / abs(b) if b else (0.0 if f == b else float("inf"))
            if direction == "lower_worse":
                bad = rel < -tol
            elif direction == "higher_worse":
                bad = rel > tol
            else:  # "any"
                bad = severity != INFO and abs(rel) > tol
            status = ("fail" if severity == HARD else
                      "warn" if severity == SOFT else "info") if bad else "ok"
        deltas.append(Delta(path, b, f, direction, tol, severity, status, rel))
    return deltas


def _fmt(v) -> str:
    if v is None:
        return "null"
    if _is_num(v) and not isinstance(v, int):
        return f"{v:.6g}"
    return str(v)


def render_table(deltas: list[Delta], *, show_ok: bool = False) -> str:
    """Human-readable delta table: failures first, then warnings, then (with
    ``show_ok``) everything else."""
    order = {"fail": 0, "warn": 1, "gone": 2, "new": 3, "info": 4, "ok": 5}
    rows = [d for d in deltas
            if show_ok or d.status not in ("ok", "info", "new", "gone")]
    shown = sorted(rows, key=lambda d: (order[d.status], d.path))
    if not shown:
        return "regression gate: all gated metrics within tolerance\n"
    widths = [max(len("metric"), *(len(d.path) for d in shown)),
              max(len("baseline"), *(len(_fmt(d.base)) for d in shown)),
              max(len("fresh"), *(len(_fmt(d.fresh)) for d in shown))]
    head = (f"{'metric':<{widths[0]}}  {'baseline':>{widths[1]}}  "
            f"{'fresh':>{widths[2]}}  {'delta':>9}  band        verdict")
    lines = [head, "-" * len(head)]
    for d in shown:
        rel = f"{d.rel:+.1%}" if d.rel is not None else "—"
        band = (f"{d.direction}±{d.tol:g}" if d.direction == "any"
                else f"{d.direction}:{d.tol:g}")
        mark = {"fail": "FAIL", "warn": "WARN", "gone": "gone",
                "new": "new", "info": "info", "ok": "ok"}[d.status]
        lines.append(f"{d.path:<{widths[0]}}  {_fmt(d.base):>{widths[1]}}  "
                     f"{_fmt(d.fresh):>{widths[2]}}  {rel:>9}  {band:<10}  "
                     f"{mark}")
    return "\n".join(lines) + "\n"


def gate(baseline: dict, fresh: dict, *, strict: bool = False,
         ) -> tuple[bool, list[Delta], str]:
    """Compare and verdict.  Returns ``(ok, deltas, report_text)`` — ``ok``
    is False on any hard failure, or on soft warnings when ``strict``."""
    deltas = compare(baseline, fresh)
    n_fail = sum(d.failed for d in deltas)
    n_warn = sum(d.warned for d in deltas)
    ok = n_fail == 0 and (n_warn == 0 or not strict)
    report = render_table(deltas)
    verdict = ("PASS" if ok else "FAIL")
    report += (f"\nregression gate: {verdict} — {n_fail} hard failure(s), "
               f"{n_warn} soft warning(s)"
               f"{' (strict: warnings fail)' if strict and n_warn else ''}\n")
    if n_warn and ok:
        report += ("soft warnings are wall-clock metrics on shared CI "
                   "hardware — investigate before trusting, gate with "
                   "--strict on dedicated runners\n")
    return ok, deltas, report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Compare a fresh BENCH_serve.json against the committed "
                    "baseline with per-metric tolerance bands.")
    ap.add_argument("fresh", help="freshly produced BENCH_serve.json")
    ap.add_argument("--baseline", default="BENCH_serve.json",
                    help="committed baseline (default: ./BENCH_serve.json)")
    ap.add_argument("--strict", action="store_true",
                    help="soft (wall-clock) violations also fail the gate")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also write the full delta list as JSON")
    args = ap.parse_args(argv)
    try:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
        with open(args.fresh) as fh:
            fresh = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"regression gate: cannot read inputs: {e}")
        return 2
    if not isinstance(baseline, dict) or not isinstance(fresh, dict):
        print("regression gate: inputs must be JSON objects")
        return 2
    ok, deltas, report = gate(baseline, fresh, strict=args.strict)
    print(report, end="")
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump([dataclasses.asdict(d) for d in deltas], fh, indent=1)
            fh.write("\n")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
