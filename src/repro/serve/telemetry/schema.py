"""Schema versioning + validation for persisted telemetry artifacts.

Two documents leave the process:

* the **metrics stream** — JSON-lines of registry snapshots
  (``registry.METRICS_SCHEMA``), one object per emit,
* the **benchmark baseline** — ``BENCH_serve.json`` at the repo root
  (``BENCH_SCHEMA``), written by ``benchmarks/serve_throughput.py`` so the
  perf trajectory is tracked across PRs.

Validators are hand-rolled (no jsonschema dependency) and return a list of
human-readable error strings — empty means valid.  CI runs
``validate_bench_file`` against the smoke artifact; the schema-stability
test pins the metric catalog against golden name sets.
"""

from __future__ import annotations

import json
import numbers

from repro.serve.telemetry.registry import METRICS_SCHEMA

# v2: adds the "prefix" section (shared-prefix workload: hit rate, warm/cold
# TTFT, prefill tok/s) — null-filled when the benchmark skips that section
# v3: adds the nullable "sharding" section (multi-device serving: TP parity +
# TTFT/TPOT deltas, DP per-replica and aggregate tok/s, per-shard pool
# bytes) — null when the run is single-device or lacks forced host devices
# v4: adds the nullable "profile" section (per-phase HLO cost accounting
# from telemetry.profiling: FLOPs / HBM-proxy bytes per jitted call, mean
# roofline utilization and effective bandwidth over the primary run) — null
# when no step could be cost-accounted
# v5: adds the nullable "families" section (state-pool A/B over the
# non-attention families: per-family token parity vs the dense-slot oracle,
# pooled vs dense throughput, and per-decode-step state-byte traffic) —
# null when the benchmark runs without --family
BENCH_SCHEMA = "repro.bench_serve/v5"

_NUM = numbers.Real


class _Nullable:
    """Wrap an object spec: the whole section may be ``null`` (e.g. the
    ``sharding`` block on a single-device run), but when present it must
    conform to the wrapped spec."""

    def __init__(self, spec: dict):
        self.spec = spec


class _MapOf:
    """Object with *variable* keys (e.g. one block per benchmarked family),
    every value conforming to the wrapped spec.  The whole section may be
    ``null``; an empty object is valid (nothing was benchmarked)."""

    def __init__(self, spec: dict):
        self.spec = spec


def _check(errors: list, doc: dict, path: str, spec: dict) -> None:
    for key, want in spec.items():
        if key not in doc:
            errors.append(f"missing {path}{key}")
            continue
        v = doc[key]
        if isinstance(want, _MapOf):
            if v is None:
                continue
            if not isinstance(v, dict):
                errors.append(f"{path}{key}: expected object|null, "
                              f"got {type(v).__name__}")
                continue
            for sub, block in v.items():
                if not isinstance(block, dict):
                    errors.append(f"{path}{key}.{sub}: expected object, "
                                  f"got {type(block).__name__}")
                else:
                    _check(errors, block, f"{path}{key}.{sub}.", want.spec)
        elif isinstance(want, _Nullable):
            if v is None:
                continue
            if not isinstance(v, dict):
                errors.append(f"{path}{key}: expected object|null, "
                              f"got {type(v).__name__}")
            else:
                _check(errors, v, f"{path}{key}.", want.spec)
        elif isinstance(want, dict):
            if not isinstance(v, dict):
                errors.append(f"{path}{key}: expected object, got {type(v).__name__}")
            else:
                _check(errors, v, f"{path}{key}.", want)
        elif want is _NUM:
            if not isinstance(v, _NUM) or isinstance(v, bool):
                errors.append(f"{path}{key}: expected number, got {v!r}")
        elif want is str:
            if not isinstance(v, str):
                errors.append(f"{path}{key}: expected string, got {v!r}")
        elif want == "num_or_null":
            if v is not None and (not isinstance(v, _NUM) or isinstance(v, bool)):
                errors.append(f"{path}{key}: expected number|null, got {v!r}")


# Required shape of BENCH_serve.json.  Keys marked "num_or_null" may be null
# on dense-slot families (no paged pool / no spec section to measure).
_BENCH_SPEC = {
    "schema": str,
    "arch": str,
    "family": str,
    "config": {"n_requests": _NUM, "max_new": _NUM, "n_slots": _NUM},
    "throughput": {
        "mxfp4_paged_tok_per_s": _NUM,
        "dense_paged_tok_per_s": _NUM,
        "mxfp4_gather_tok_per_s": _NUM,
    },
    "latency": {
        "ttft_p50_s": _NUM, "ttft_p95_s": _NUM,
        "tpot_p50_s": "num_or_null", "tpot_p95_s": "num_or_null",
        "latency_p50_s": _NUM, "latency_p95_s": _NUM,
        "queue_wait_p50_s": _NUM,
    },
    "tick": {
        "decode_p50_s": "num_or_null", "decode_p95_s": "num_or_null",
        "prefill_p50_s": "num_or_null",
    },
    "kv": {
        "cache_bytes_dense": _NUM, "cache_bytes_mxfp4": _NUM,
        "cache_ratio": _NUM, "bits_per_elem_mxfp4": _NUM,
        "decode_bytes_ratio_gather_over_paged": "num_or_null",
        "prefill_bytes_ratio_gather_over_paged": "num_or_null",
    },
    "pool": {
        "occupancy_peak": _NUM,
        "free_page_watermark": _NUM,
    },
    "spec": {
        "k": _NUM,
        "proposer": str,
        "acceptance_rate": "num_or_null",
        "tokens_per_decode_call": "num_or_null",
    },
    "quant_health": {
        "clip_fraction_k": "num_or_null",
        "clip_fraction_v": "num_or_null",
        "zero_fraction_k": "num_or_null",
        "scale_hist_nonzero_bins": "num_or_null",
        "scale_code_min": "num_or_null",
        "scale_code_max": "num_or_null",
    },
    "prefix": {
        "hit_rate": "num_or_null",
        "shared_tokens": "num_or_null",
        "cow_pages": "num_or_null",
        "warm_ttft_mean_s": "num_or_null",
        "cold_ttft_mean_s": "num_or_null",
        "warm_ttft_p95_s": "num_or_null",
        "cold_ttft_p95_s": "num_or_null",
        "warm_prefill_tok_per_s": "num_or_null",
        "cold_prefill_tok_per_s": "num_or_null",
    },
    # whole section is null when the run is single-device (tp==dp==1), the
    # family is not paged, or the process has too few devices to shard
    "sharding": _Nullable({
        "tp": _NUM,
        "dp": _NUM,
        "devices": _NUM,
        "single": {
            "decode_tok_per_s": _NUM,
            "ttft_p50_s": _NUM,
            "tpot_p50_s": "num_or_null",
            "wall_sec": _NUM,
        },
        "tp_run": _Nullable({
            "decode_tok_per_s": _NUM,
            "ttft_p50_s": _NUM,
            "tpot_p50_s": "num_or_null",
            "wall_sec": _NUM,
            "pool_bytes_per_shard": _NUM,
            "parity_vs_single": _NUM,  # 1.0 exact / 0.0 mismatch
            "ttft_p50_delta_s": "num_or_null",
            "tpot_p50_delta_s": "num_or_null",
        }),
        "dp_run": _Nullable({
            "aggregate_decode_tok_per_s": _NUM,
            "speedup_vs_one_replica": _NUM,
            "parity_vs_single": _NUM,
            "pool_bytes_per_shard": _NUM,
            "wall_sec": _NUM,
        }),
    }),
    # per-phase device cost accounting of the primary (mxfp4+paged) run;
    # each phase block is null when that phase never ran (e.g. "verify"
    # without speculation) and the whole section null when nothing lowered
    "profile": _Nullable({
        "peak_flops": _NUM,
        "peak_bw": _NUM,
        "prefill": _Nullable(_PROFILE_PHASE_SPEC := {
            "flops_per_call": _NUM,
            "hbm_bytes_per_call": _NUM,
            "calls": _NUM,
            "wall_s": _NUM,
            "roofline_util_mean": "num_or_null",
            "effective_bw_mean": "num_or_null",
        }),
        "decode": _Nullable(_PROFILE_PHASE_SPEC),
        "verify": _Nullable(_PROFILE_PHASE_SPEC),
    }),
    # state-pool A/B over the non-attention families (--family): one block
    # per benchmarked arch (key = arch slug), null when the section was not
    # run.  token_parity is 1.0 when the pooled engine (kv_dtype="dense")
    # is token-exact vs the DenseSlotCache oracle on the same workload;
    # state bytes are per-decode-step HBM traffic of the mxfp4 pool vs the
    # oracle's dense per-slot caches
    "families": _MapOf({
        "family": str,
        "token_parity": _NUM,
        "pool_tok_per_s": _NUM,
        "oracle_tok_per_s": _NUM,
        "state_bytes_per_step_pool": _NUM,
        "state_bytes_per_step_dense": _NUM,
        "state_bytes_ratio": _NUM,
        "cache_bytes_pool": _NUM,
        "cache_bytes_dense": _NUM,
    }),
}


def validate_bench(doc: dict) -> list[str]:
    errors: list[str] = []
    if not isinstance(doc, dict):
        return [f"expected a JSON object, got {type(doc).__name__}"]
    _check(errors, doc, "", _BENCH_SPEC)
    if not errors and doc["schema"] != BENCH_SCHEMA:
        errors.append(f"schema {doc['schema']!r} != {BENCH_SCHEMA!r}")
    return errors


def validate_bench_file(path: str) -> dict:
    """Load + validate; raises ``ValueError`` listing every violation.
    Returns the parsed doc on success (CI entry point)."""
    with open(path) as fh:
        doc = json.load(fh)
    errors = validate_bench(doc)
    if errors:
        raise ValueError(f"{path} failed {BENCH_SCHEMA} validation:\n  "
                         + "\n  ".join(errors))
    return doc


_SNAPSHOT_SPEC = {
    "schema": str,
    "t": _NUM,
    "meta": {},
    "counters": {},
    "gauges": {},
    "histograms": {},
    "binned": {},
    "rates": {},
}


def validate_snapshot(obj: dict) -> list[str]:
    errors: list[str] = []
    if not isinstance(obj, dict):
        return [f"expected a JSON object, got {type(obj).__name__}"]
    _check(errors, obj, "", _SNAPSHOT_SPEC)
    if not errors and obj["schema"] != METRICS_SCHEMA:
        errors.append(f"schema {obj['schema']!r} != {METRICS_SCHEMA!r}")
    if not errors:
        for name, v in obj["counters"].items():
            if not isinstance(v, int) or v < 0:
                errors.append(f"counter {name}: expected int >= 0, got {v!r}")
        for name, s in obj["histograms"].items():
            if "count" not in s:
                errors.append(f"histogram {name}: missing count")
    return errors


def validate_metrics_file(path: str) -> int:
    """Validate every line of a JSONL metrics stream; raises on the first
    bad line, returns the number of snapshots otherwise."""
    n = 0
    with open(path) as fh:
        for i, line in enumerate(fh, 1):
            if not line.strip():
                continue
            errors = validate_snapshot(json.loads(line))
            if errors:
                raise ValueError(f"{path}:{i} failed {METRICS_SCHEMA} "
                                 f"validation:\n  " + "\n  ".join(errors))
            n += 1
    if n == 0:
        raise ValueError(f"{path}: empty metrics stream")
    return n
