"""Dependency-free metrics registry for the serving engine.

Four metric kinds, all plain host-side objects (no device work, no jit
interaction — instrumentation must never change what the engine compiles):

* :class:`Counter` — monotonically increasing event count,
* :class:`Gauge` — last-written value (plus a ``set_max`` helper for
  peak-tracking gauges like pool-occupancy high-water marks),
* :class:`Histogram` — streaming count/sum/min/max plus a bounded sample
  reservoir from which p50/p95/p99 are derived with numpy-compatible linear
  interpolation (below ``max_samples`` observations the percentiles are
  *exact*; past it the reservoir keeps the most recent window, which is the
  right bias for serving latencies),
* :class:`BinnedHistogram` — fixed integer bins whose counts are produced
  elsewhere (typically a device-side reduction, e.g. the E8M0 scale-code
  histogram from ``telemetry.quant_health``) and set/merged wholesale,
* :class:`EwmaRate` — exponentially-weighted events/sec (half-life in
  seconds), for "tokens/sec right now" style gauges.

:class:`MetricsRegistry` is create-or-get by name with kind checking, and
renders two export formats: a JSON-able :meth:`snapshot` dict (consumed by
the sinks in ``telemetry.sinks`` — the JSON-lines stream and the benchmark
baseline both derive from it) and Prometheus text exposition
(:meth:`prometheus_text`).
"""

from __future__ import annotations

import bisect
import math
from collections import deque
from typing import Iterable

METRICS_SCHEMA = "repro.serve_metrics/v1"

# Cumulative-bucket ladder for the Prometheus exposition: log-spaced seconds
# covering everything we observe (10 µs ticks up to minute-scale request
# latencies; `tokens_per_decode_call` values land in the 1..32 decades).
DEFAULT_BUCKETS: tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Counter:
    kind = "counter"
    __slots__ = ("help", "value")

    def __init__(self, help: str = ""):
        self.help = help
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        self.value += n


class Gauge:
    kind = "gauge"
    __slots__ = ("help", "value")

    def __init__(self, help: str = ""):
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def set_max(self, v: float) -> None:
        """Peak-tracking update: keep the largest value ever set."""
        self.value = max(self.value, float(v))

    def set_min(self, v: float) -> None:
        """Trough-tracking update (e.g. free-page low watermark)."""
        self.value = min(self.value, float(v))


class Histogram:
    kind = "histogram"
    __slots__ = ("help", "count", "total", "vmin", "vmax", "_buf",
                 "buckets", "bucket_counts")

    def __init__(self, help: str = "", max_samples: int = 4096,
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        self.help = help
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._buf: deque[float] = deque(maxlen=max_samples)
        # non-cumulative per-bucket counts; index len(buckets) is +Inf
        self.buckets = tuple(buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self._buf.append(v)
        self.bucket_counts[bisect.bisect_left(self.buckets, v)] += 1

    def percentile(self, q: float) -> float | None:
        """numpy-compatible linear interpolation over the retained samples
        (``np.quantile(xs, q)`` exactly while ``count <= max_samples``)."""
        if not self._buf:
            return None
        xs = sorted(self._buf)
        pos = q * (len(xs) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (pos - lo) * (xs[hi] - xs[lo])

    @property
    def mean(self) -> float | None:
        return self.total / self.count if self.count else None

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin,
            "max": self.vmax,
            "mean": self.mean,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


class BinnedHistogram:
    """Fixed integer bins set wholesale from an externally-computed count
    vector — the host-side face of a device-side histogram reduction."""

    kind = "binned"
    __slots__ = ("help", "n_bins", "counts", "samples")

    def __init__(self, n_bins: int, help: str = ""):
        self.help = help
        self.n_bins = n_bins
        self.counts = [0] * n_bins
        self.samples = 0  # number of set/merge calls that fed this histogram

    def set_counts(self, counts: Iterable[int]) -> None:
        """Replace with the latest sample (gauge-like: 'the pool right now')."""
        counts = [int(c) for c in counts]
        if len(counts) != self.n_bins:
            raise ValueError(f"expected {self.n_bins} bins, got {len(counts)}")
        self.counts = counts
        self.samples += 1

    def merge_counts(self, counts: Iterable[int]) -> None:
        """Accumulate (counter-like: 'everything ever observed')."""
        counts = [int(c) for c in counts]
        if len(counts) != self.n_bins:
            raise ValueError(f"expected {self.n_bins} bins, got {len(counts)}")
        self.counts = [a + b for a, b in zip(self.counts, counts)]
        self.samples += 1

    @property
    def nonzero_bins(self) -> int:
        return sum(1 for c in self.counts if c)

    def summary(self) -> dict:
        nz = [i for i, c in enumerate(self.counts) if c]
        return {
            "samples": self.samples,
            "total": sum(self.counts),
            "nonzero_bins": len(nz),
            "bin_min": nz[0] if nz else None,
            "bin_max": nz[-1] if nz else None,
            "counts": list(self.counts),
        }


class EwmaRate:
    """Exponentially-weighted events/sec.  ``mark(n, t)`` records ``n``
    events at time ``t``; the instantaneous rate over each inter-mark gap is
    blended with half-life ``halflife_s``.  Marks at a non-advancing clock
    accumulate into the next gap instead of dividing by zero."""

    kind = "ewma"
    __slots__ = ("help", "halflife_s", "_rate", "_last_t", "_pending")

    def __init__(self, halflife_s: float = 5.0, help: str = ""):
        self.help = help
        self.halflife_s = halflife_s
        self._rate: float | None = None
        self._last_t: float | None = None
        self._pending = 0.0

    def mark(self, n: float, t: float) -> None:
        if self._last_t is None:
            self._last_t = t
            self._pending = n
            return
        dt = t - self._last_t
        if dt <= 0:
            self._pending += n
            return
        inst = (self._pending + n) / dt
        alpha = 1.0 - 0.5 ** (dt / self.halflife_s)
        self._rate = inst if self._rate is None else (
            self._rate + alpha * (inst - self._rate))
        self._last_t = t
        self._pending = 0.0

    @property
    def rate(self) -> float | None:
        return self._rate


class MetricsRegistry:
    """Create-or-get metric store.  Asking for an existing name with a
    different kind is a bug and raises; everything else is cheap dict ops."""

    def __init__(self, hist_max_samples: int = 4096):
        self._metrics: dict[str, object] = {}
        self._hist_max_samples = hist_max_samples
        self.meta: dict = {}  # static run context (arch, backend, …)

    def _get(self, name: str, cls, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = cls(**kwargs)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(name, Histogram, help=help,
                         max_samples=self._hist_max_samples)

    def binned(self, name: str, n_bins: int, help: str = "") -> BinnedHistogram:
        return self._get(name, BinnedHistogram, n_bins=n_bins, help=help)

    def rate(self, name: str, halflife_s: float = 5.0, help: str = "") -> EwmaRate:
        return self._get(name, EwmaRate, halflife_s=halflife_s, help=help)

    def names(self, kind: str | None = None) -> list[str]:
        return sorted(n for n, m in self._metrics.items()
                      if kind is None or m.kind == kind)

    def reset(self) -> None:
        """Zero every metric in place (kinds and names survive — the schema
        is stable across a reset).  Used to drop warmup traffic from
        benchmark runs."""
        fresh = {}
        for name, m in self._metrics.items():
            if isinstance(m, Counter):
                fresh[name] = Counter(m.help)
            elif isinstance(m, Gauge):
                fresh[name] = Gauge(m.help)
            elif isinstance(m, Histogram):
                fresh[name] = Histogram(m.help, m._buf.maxlen, m.buckets)
            elif isinstance(m, BinnedHistogram):
                fresh[name] = BinnedHistogram(m.n_bins, m.help)
            elif isinstance(m, EwmaRate):
                fresh[name] = EwmaRate(m.halflife_s, m.help)
        self._metrics = fresh

    # -- exports ------------------------------------------------------------

    def snapshot(self, t: float = 0.0) -> dict:
        snap: dict = {"schema": METRICS_SCHEMA, "t": t,
                      "meta": dict(self.meta), "counters": {}, "gauges": {},
                      "histograms": {}, "binned": {}, "rates": {}}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                snap["counters"][name] = m.value
            elif isinstance(m, Gauge):
                snap["gauges"][name] = m.value
            elif isinstance(m, Histogram):
                snap["histograms"][name] = m.summary()
            elif isinstance(m, BinnedHistogram):
                snap["binned"][name] = m.summary()
            elif isinstance(m, EwmaRate):
                snap["rates"][name] = m.rate
        return snap

    def prometheus_text(self) -> str:
        """Prometheus-style text exposition (counters/gauges as-is,
        histograms as _count/_sum plus quantile-labelled gauges, binned
        histograms as le-labelled cumulative buckets)."""
        lines: list[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {m.value:g}")
            elif isinstance(m, EwmaRate):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {(m.rate or 0.0):g}")
            elif isinstance(m, Histogram):
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for bound, c in zip(m.buckets, m.bucket_counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{bound:g}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{name}_sum {m.total:g}")
                lines.append(f"{name}_count {m.count}")
            elif isinstance(m, BinnedHistogram):
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for i, c in enumerate(m.counts):
                    if c:
                        cum += c
                        lines.append(f'{name}_bucket{{le="{i}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {sum(m.counts)}')
                lines.append(f"{name}_count {sum(m.counts)}")
        return "\n".join(lines) + "\n"


def merge_histograms(hists: list[Histogram]) -> Histogram:
    """Pool histograms sample-by-sample: counts/sums add, extrema combine,
    reservoirs concatenate (into a reservoir big enough to keep everything
    the sources retained), bucket counts add elementwise.  Percentiles of
    the merge are therefore computed over the POOLED samples — the
    statistically meaningful DP aggregate — not averaged per-source."""
    if not hists:
        raise ValueError("nothing to merge")
    first = hists[0]
    for h in hists[1:]:
        if h.buckets != first.buckets:
            raise ValueError("cannot merge histograms with different buckets")
    out = Histogram(first.help,
                    max_samples=max(1, sum(h._buf.maxlen for h in hists)),
                    buckets=first.buckets)
    for h in hists:
        out.count += h.count
        out.total += h.total
        out.vmin = min(out.vmin, h.vmin)
        out.vmax = max(out.vmax, h.vmax)
        out._buf.extend(h._buf)
        for i, c in enumerate(h.bucket_counts):
            out.bucket_counts[i] += c
    return out


def merge_registries(regs: list[MetricsRegistry]) -> MetricsRegistry:
    """Merge per-replica registries into one aggregate view (data-parallel
    serving: replicas handle disjoint traffic concurrently).

    * counters — summed,
    * gauges — ``*_peak``/``*_watermark`` keep their extreme (max / min
      respectively), everything else averages across replicas,
    * histograms — pooled via :func:`merge_histograms` (reservoirs and
      cumulative buckets concatenated/added, so aggregate percentiles are
      over all replicas' samples),
    * binned histograms — counts added elementwise,
    * EWMA rates — summed (replicas emit tokens concurrently).

    Metric names are unioned; a metric missing from some replicas merges
    over the replicas that have it.
    """
    if not regs:
        raise ValueError("nothing to merge")
    out = MetricsRegistry(hist_max_samples=regs[0]._hist_max_samples)
    out.meta = dict(regs[0].meta)
    out.meta["replicas"] = len(regs)
    names: dict[str, object] = {}
    for reg in regs:
        for name, m in reg._metrics.items():
            names.setdefault(name, m)
    for name, proto in sorted(names.items()):
        ms = [reg._metrics[name] for reg in regs if name in reg._metrics]
        if isinstance(proto, Counter):
            out.counter(name, proto.help).inc(sum(m.value for m in ms))
        elif isinstance(proto, Gauge):
            g = out.gauge(name, proto.help)
            if name.endswith("_peak"):
                g.set(max(m.value for m in ms))
            elif name.endswith("_watermark"):
                g.set(min(m.value for m in ms))
            else:
                g.set(sum(m.value for m in ms) / len(ms))
        elif isinstance(proto, Histogram):
            out._metrics[name] = merge_histograms(ms)
        elif isinstance(proto, BinnedHistogram):
            b = out.binned(name, proto.n_bins, proto.help)
            for m in ms:
                b.merge_counts(m.counts)
        elif isinstance(proto, EwmaRate):
            r = out.rate(name, proto.halflife_s, proto.help)
            rates = [m.rate for m in ms if m.rate is not None]
            r._rate = sum(rates) if rates else None
    return out
