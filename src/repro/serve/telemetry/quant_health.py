"""Quantization-health gauges sampled from the packed MXFP4 KV pool.

Low-precision serving needs *numerical* observability: clip rates and scale
distributions are the leading indicators of FP4 degradation (the same
statistics FP4 training work tracks for gradients — see PAPERS.md).  Every
KV write quantizes through ``kernels/kv_pack`` semantics, so the packed pool
*is* the record of what quantization did; this module reduces it device-side
into three cheap health signals per K/V stream:

* **clip fraction** — share of E2M1 codes at the saturating magnitude
  (``kv_pack.E2M1_SAT_IDX``, |x| = 6.0): rising clip means the per-32-group
  AbsMax scales are being overwhelmed by outliers,
* **zero fraction** — share of codes at magnitude 0: rising dead codes mean
  the scale is too coarse for the tail (underflow),
* **E8M0 scale histogram** — 256-bin histogram of the biased scale
  exponents actually stored: drift or widening of this distribution is the
  earliest sign the KV value range is moving.

The reduction is ONE extra jitted function over the whole pool with a
``[n_pages]`` page mask (mapped pages only — scratch page 0 and unmapped
pages never count), compiled once per engine regardless of how many pages
are mapped; the engine fetches it at ``TelemetryConfig.quant_stride`` ticks.
The hot-path step functions are untouched — the compile-count guard in
``tests/test_telemetry.py`` pins that.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.kv_pack import E2M1_SAT_IDX, split_nibbles

N_SCALE_BINS = 256  # E8M0 biased exponent codes


def page_mask_from_tables(tables: np.ndarray, n_pages: int) -> np.ndarray:
    """Host-side [n_pages] bool mask of pages currently mapped by any slot.
    Page id 0 is the scratch sentinel — never mapped, never counted."""
    mask = np.zeros((n_pages,), bool)
    ids = np.asarray(tables).reshape(-1)
    mask[ids[ids > 0]] = True
    return mask


def _stream_health(codes: jnp.ndarray, scales: jnp.ndarray,
                   page_mask: jnp.ndarray) -> dict:
    """One packed stream ([L, P, ps, H, hd/2] codes + [L, P, ps, H, nb]
    scale codes) → masked clip/zero fractions and the scale histogram."""
    w = page_mask.astype(jnp.int32)[None, :, None, None, None]
    nib = split_nibbles(codes)  # [..., hd] u8
    mag = (nib & 7).astype(jnp.int32)
    # weights broadcast over the doubled last axis exactly like the codes
    w_el = jnp.broadcast_to(w, mag.shape)
    n_elems = jnp.sum(w_el)
    clip = jnp.sum((mag == E2M1_SAT_IDX).astype(jnp.int32) * w_el)
    zero = jnp.sum((mag == 0).astype(jnp.int32) * w_el)
    denom = jnp.maximum(n_elems, 1).astype(jnp.float32)
    w_sc = jnp.broadcast_to(w, scales.shape).reshape(-1)
    hist = jnp.zeros((N_SCALE_BINS,), jnp.int32).at[
        scales.reshape(-1).astype(jnp.int32)].add(w_sc)
    # bin 0 collects unmapped-page zeros scaled by w=0 scatter adds — they
    # contribute 0 counts, so no correction is needed
    return {"clip_frac": clip.astype(jnp.float32) / denom,
            "zero_frac": zero.astype(jnp.float32) / denom,
            "scale_hist": hist,
            "n_elems": n_elems}


@jax.jit
def pool_health(pool: dict, page_mask: jnp.ndarray) -> dict:
    """Packed MXFP4 pool + mapped-page mask → per-stream health dict.

    One compile per pool geometry (shapes are fixed for an engine's
    lifetime; the varying quantity — which pages are mapped — is a runtime
    operand), so sampling never perturbs the step compile counts.
    """
    if "k_codes" not in pool:
        raise ValueError("pool_health needs a packed (mxfp4) pool")
    return {
        "k": _stream_health(pool["k_codes"], pool["k_scales"], page_mask),
        "v": _stream_health(pool["v_codes"], pool["v_scales"], page_mask),
        "mapped_pages": jnp.sum(page_mask.astype(jnp.int32)),
    }


def sample_pool_health(cache) -> dict | None:
    """Host convenience: reduce a :class:`~repro.serve.paged_cache.PagedCache`
    and fetch the result — ``None`` when the pool is dense (nothing to
    measure) or no page is mapped (no live KV)."""
    if cache.kv_dtype != "mxfp4":
        return None
    mask = cache.page_mask()
    if not mask.any():
        return None
    out = pool_health(cache.pool, jnp.asarray(mask))
    return jax.tree.map(np.asarray, out)


@jax.jit
def ring_health(pool: dict, page_mask: jnp.ndarray) -> dict:
    """One packed state-ring plane ([P, E/2] codes + [P, E/32] scale codes)
    + live-page mask → clip/zero fractions.  The block-padding tail of each
    page quantizes exact zeros, so it rides in the zero fraction as a small
    constant floor (same pages every sample — trends are unaffected)."""
    if "codes" not in pool:
        raise ValueError("ring_health needs a packed (mxfp4) ring plane")
    w = page_mask.astype(jnp.int32)[:, None]
    nib = split_nibbles(pool["codes"])  # [P, E] u8
    mag = (nib & 7).astype(jnp.int32)
    w_el = jnp.broadcast_to(w, mag.shape)
    n_elems = jnp.sum(w_el)
    clip = jnp.sum((mag == E2M1_SAT_IDX).astype(jnp.int32) * w_el)
    zero = jnp.sum((mag == 0).astype(jnp.int32) * w_el)
    denom = jnp.maximum(n_elems, 1).astype(jnp.float32)
    return {"clip_frac": clip.astype(jnp.float32) / denom,
            "zero_frac": zero.astype(jnp.float32) / denom,
            "n_elems": n_elems}


def sample_state_health(pool) -> dict | None:
    """Reduce a :class:`~repro.serve.state_pool.StatePool`, per tenant kind:
    ``"kv"``/``"cross"`` reuse the paged-plane reduction (each is a real
    :class:`PagedCache`); ``"state"`` aggregates every ring plane's
    clip/zero fractions over the pages holding each live slot's CURRENT
    state, element-weighted across planes.  ``None`` when the pool is dense
    or nothing is live."""
    if pool.kv_dtype != "mxfp4":
        return None
    out = {}
    if pool.kv is not None and (h := sample_pool_health(pool.kv)) is not None:
        out["kv"] = h
    if pool.cross is not None and (h := sample_pool_health(pool.cross)) is not None:
        out["cross"] = h
    if pool.rings:
        mask = pool.ring_page_mask()
        if mask.any():
            clip = zero = n = 0
            for r in pool.rings:
                h = jax.tree.map(np.asarray, ring_health(r.pool, jnp.asarray(mask)))
                n_r = int(h["n_elems"])
                clip += float(h["clip_frac"]) * n_r
                zero += float(h["zero_frac"]) * n_r
                n += n_r
            if n:
                out["state"] = {"clip_frac": clip / n, "zero_frac": zero / n,
                                "n_elems": n}
    return out or None
