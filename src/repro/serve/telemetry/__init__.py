"""Engine telemetry: metrics registry + request tracing + pool health.

One :class:`EngineTelemetry` hub per engine wires together

* a :class:`~repro.serve.telemetry.registry.MetricsRegistry` (counters /
  gauges / histograms / EWMA rates) pre-registered with the full metric
  catalog so the export schema is stable from tick 0,
* a :class:`~repro.serve.telemetry.tracing.Tracer` deriving TTFT / TPOT /
  queue-wait / latency from request-lifecycle spans,
* pluggable sinks (JSON-lines stream, Prometheus text exposition, console
  snapshots — see ``telemetry.sinks``),
* quantization-health sampling of the packed MXFP4 pool at a configurable
  tick stride (``telemetry.quant_health``).

Everything here is host-side bookkeeping: instrumentation adds **zero** jit
compilations to the engine's step functions (the pool-health reduction is
its own once-compiled function), and with no sinks configured the cost is
dict updates — cheap enough to stay on by default.

The metric catalog (``CATALOG``) is the contract consumers code against —
``serve/README.md#observability`` documents name → kind → meaning; the
schema-stability test pins the names.
"""

from __future__ import annotations

import dataclasses
import time

from repro.serve.telemetry.quant_health import (sample_pool_health,
                                                sample_state_health)
from repro.serve.telemetry.registry import (
    METRICS_SCHEMA,
    BinnedHistogram,
    Counter,
    EwmaRate,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.serve.telemetry.sinks import (
    ConsoleSink,
    JsonlSink,
    NullSink,
    PrometheusTextSink,
    Sink,
    render_summary,
)
from repro.serve.telemetry.tracing import RequestTrace, Tracer

__all__ = [
    "TelemetryConfig", "EngineTelemetry", "MetricsRegistry", "Tracer",
    "RequestTrace", "Counter", "Gauge", "Histogram", "BinnedHistogram",
    "EwmaRate", "Sink", "NullSink", "JsonlSink", "PrometheusTextSink",
    "ConsoleSink", "render_summary", "CATALOG", "METRICS_SCHEMA",
]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Sink + sampling configuration (the registry/tracer are always on —
    they are host dicts; sinks and device sampling are the opt-ins)."""

    metrics_path: str | None = None      # JSON-lines snapshot stream
    trace_path: str | None = None        # JSON-lines per-request spans
    prometheus_path: str | None = None   # text exposition, rewritten per emit
    console_every: int = 0               # print a summary every N emits (0=off)
    emit_every_ticks: int = 25           # snapshot cadence (0 = finalize only)
    quant_stride: int = 0                # pool-health sample every N ticks (0=off)
    keep_traces: int = 1024              # completed traces retained in memory
    hist_max_samples: int = 4096         # percentile reservoir size
    profile: bool = False                # per-phase cost accounting + roofline gauges
    profile_trace_path: str | None = None  # Chrome trace-event JSON (implies profile)


# name → (kind, help).  Pre-registered so every snapshot carries the full
# catalog (schema stability) and so this module is the single source of
# truth the README table and the stability test both mirror.
CATALOG: dict[str, tuple[str, str]] = {
    # counters — engine lifecycle
    "engine_ticks": ("counter", "scheduler ticks executed"),
    "requests_submitted": ("counter", "requests entered the queue"),
    "requests_admitted": ("counter", "requests admitted into slots"),
    "requests_retired_eos": ("counter", "requests finished by EOS"),
    "requests_retired_max_tokens": ("counter", "requests finished by budget"),
    "admission_blocked_pages": ("counter",
                                "ticks the queue head had a slot but no pages"),
    # counters — device-call / token accounting
    "tokens_generated": ("counter", "tokens emitted (all phases)"),
    "decode_tokens": ("counter", "tokens emitted by decode/verify ticks"),
    "prompt_tokens_prefilled": ("counter", "prompt tokens consumed by prefill"),
    "prefill_calls": ("counter", "jitted prefill calls"),
    "decode_calls": ("counter", "jitted batched decode calls"),
    "cross_encode_calls": ("counter",
                           "cross-KV encode-at-admission calls (state pool)"),
    "verify_calls": ("counter", "jitted speculative verify calls"),
    "draft_decode_calls": ("counter", "proposer draft decode calls"),
    "draft_prefill_calls": ("counter", "proposer draft-cache sync prefill calls"),
    "drafts_proposed": ("counter", "drafted tokens at emittable positions"),
    "drafts_accepted": ("counter", "drafted tokens the target accepted"),
    "quant_health_samples": ("counter", "pool-health reductions fetched"),
    # counters — prefix sharing (zero unless EngineConfig.prefix_cache)
    "prefix_lookups": ("counter", "admissions that consulted the radix index"),
    "prefix_hit_requests": ("counter", "admissions that aliased >= 1 page"),
    "prefix_shared_tokens": ("counter",
                             "prompt tokens skipped via aliased pages"),
    "prefix_inserted_pages": ("counter", "pages published into the index"),
    "prefix_cow_pages": ("counter", "shared pages detached by copy-on-write"),
    "prefix_evicted_pages": ("counter",
                             "cached pages LRU-evicted under pool pressure"),
    # gauges — scheduler / pool pressure
    "queue_depth": ("gauge", "requests waiting for a slot"),
    "slots_active": ("gauge", "slots holding a live request"),
    "slots_prefilling": ("gauge", "slots in PREFILL"),
    "slots_decoding": ("gauge", "slots in DECODE"),
    "pool_pages_total": ("gauge", "allocatable pages (excl. scratch)"),
    "pool_pages_free": ("gauge", "free pages right now"),
    "pool_pages_free_watermark": ("gauge", "lowest free-page count seen"),
    "pool_occupancy": ("gauge", "mapped / allocatable pages"),
    "pool_occupancy_peak": ("gauge", "highest occupancy seen"),
    "kv_cache_bytes": ("gauge", "persistent KV bytes held by the cache"),
    # gauges — state-pool per-tenant-kind pressure (0 unless backend is
    # "statepool"; kinds: attn-KV plane / cross-KV plane / state rings)
    "pool_pages_total_attn_kv": ("gauge", "state pool: allocatable attn-KV pages"),
    "pool_pages_free_attn_kv": ("gauge", "state pool: free attn-KV pages"),
    "pool_occupancy_attn_kv": ("gauge", "state pool: attn-KV plane occupancy"),
    "pool_pages_total_cross_kv": ("gauge", "state pool: allocatable cross-KV pages"),
    "pool_pages_free_cross_kv": ("gauge", "state pool: free cross-KV pages"),
    "pool_occupancy_cross_kv": ("gauge", "state pool: cross-KV plane occupancy"),
    "pool_pages_total_state_ring": ("gauge", "state pool: ring pages (all planes)"),
    "pool_pages_free_state_ring": ("gauge", "state pool: inactive ring pages"),
    "pool_occupancy_state_ring": ("gauge", "state pool: active-slot ring fraction"),
    "spec_acceptance_rate": ("gauge", "cumulative accepted / proposed drafts"),
    "prefix_cached_pages": ("gauge", "pages pinned by the radix prefix index"),
    "prefix_hit_rate": ("gauge", "cumulative hit admissions / lookups"),
    # gauges — jit compile counts (compile storms show up here)
    "jit_compiled_decode_all": ("gauge", "compiled variants of decode_all"),
    "jit_compiled_prefill_all": ("gauge", "compiled variants of prefill_all"),
    "jit_compiled_prefill_chunk": ("gauge", "compiled variants of prefill_chunk"),
    "jit_compiled_verify_all": ("gauge", "compiled variants of verify_all"),
    # gauges — profiler cost accounting (0 unless TelemetryConfig.profile;
    # per-call costs are static HLO facts, util/bw refresh every profiled tick)
    "profile_flops_per_call_prefill": ("gauge", "HLO flops per prefill call"),
    "profile_flops_per_call_decode": ("gauge", "HLO flops per decode call"),
    "profile_flops_per_call_verify": ("gauge", "HLO flops per verify call"),
    "profile_hbm_bytes_per_call_prefill": ("gauge",
                                           "HLO HBM-traffic proxy per prefill call"),
    "profile_hbm_bytes_per_call_decode": ("gauge",
                                          "HLO HBM-traffic proxy per decode call"),
    "profile_hbm_bytes_per_call_verify": ("gauge",
                                          "HLO HBM-traffic proxy per verify call"),
    "roofline_util_prefill": ("gauge",
                              "achieved/peak FLOP rate, last prefill section"),
    "roofline_util_decode": ("gauge",
                             "achieved/peak FLOP rate, last decode section"),
    "roofline_util_verify": ("gauge",
                             "achieved/peak FLOP rate, last verify section"),
    "effective_bw_prefill": ("gauge", "HBM-proxy bytes/s, last prefill section"),
    "effective_bw_decode": ("gauge", "HBM-proxy bytes/s, last decode section"),
    "effective_bw_verify": ("gauge", "HBM-proxy bytes/s, last verify section"),
    # gauges — quantization health (mxfp4 pools, sampled at quant_stride)
    "kv_clip_fraction_k": ("gauge", "E2M1 codes at |6.0| in mapped K pages"),
    "kv_clip_fraction_v": ("gauge", "E2M1 codes at |6.0| in mapped V pages"),
    "kv_zero_fraction_k": ("gauge", "E2M1 codes at 0 in mapped K pages"),
    "kv_zero_fraction_v": ("gauge", "E2M1 codes at 0 in mapped V pages"),
    "cross_clip_fraction_k": ("gauge", "E2M1 codes at |6.0| in mapped cross-K pages"),
    "cross_clip_fraction_v": ("gauge", "E2M1 codes at |6.0| in mapped cross-V pages"),
    "cross_zero_fraction_k": ("gauge", "E2M1 codes at 0 in mapped cross-K pages"),
    "cross_zero_fraction_v": ("gauge", "E2M1 codes at 0 in mapped cross-V pages"),
    "state_clip_fraction": ("gauge", "E2M1 codes at |6.0| in live state-ring pages"),
    "state_zero_fraction": ("gauge", "E2M1 codes at 0 in live state-ring pages"),
    # histograms — latencies and per-request shape
    "tick_s": ("histogram", "wall time of one engine tick"),
    "prefill_tick_s": ("histogram", "wall time of a tick's prefill section"),
    "decode_tick_s": ("histogram", "wall time of a tick's decode section"),
    "verify_tick_s": ("histogram", "wall time of a tick's draft+verify section"),
    "ttft_s": ("histogram", "first token latency (submit -> first token)"),
    "tpot_s": ("histogram", "time per output token over the decode phase"),
    "queue_wait_s": ("histogram", "submit -> admit"),
    "request_latency_s": ("histogram", "submit -> retire"),
    "tokens_per_decode_call": ("histogram",
                               "per retired request: decode tokens / calls"),
    # binned — E8M0 scale-code distribution of the mapped pool
    "kv_scale_hist_k": ("binned", "E8M0 scale codes in mapped K pages"),
    "kv_scale_hist_v": ("binned", "E8M0 scale codes in mapped V pages"),
    # rates
    "tokens_per_sec_ewma": ("ewma", "EWMA token emission rate (wall clock)"),
}


def _register_catalog(reg: MetricsRegistry) -> None:
    for name, (kind, help_) in CATALOG.items():
        if kind == "counter":
            reg.counter(name, help_)
        elif kind == "gauge":
            reg.gauge(name, help_)
        elif kind == "histogram":
            reg.histogram(name, help_)
        elif kind == "binned":
            reg.binned(name, 256, help_)
        elif kind == "ewma":
            reg.rate(name, help=help_)


class EngineTelemetry:
    """Per-engine telemetry hub.  The engine calls :meth:`end_tick` once per
    ``step()``; launchers call :meth:`finalize` when the run ends."""

    def __init__(self, cfg: TelemetryConfig | None = None):
        self.cfg = cfg or TelemetryConfig()
        self.registry = MetricsRegistry(hist_max_samples=self.cfg.hist_max_samples)
        _register_catalog(self.registry)
        self.tracer = Tracer(self.registry, path=self.cfg.trace_path,
                             keep=self.cfg.keep_traces)
        self.sinks: list[Sink] = []
        if self.cfg.metrics_path:
            self.sinks.append(JsonlSink(self.cfg.metrics_path))
        if self.cfg.prometheus_path:
            self.sinks.append(PrometheusTextSink(self.cfg.prometheus_path))
        if self.cfg.console_every:
            self.sinks.append(ConsoleSink(self.cfg.console_every))
        if not self.sinks:
            self.sinks.append(NullSink())
        self.profiler = None  # EngineProfiler, created at attach() when enabled
        self._last_now = 0.0
        self._last_tokens = 0
        self._finalized = False

    @property
    def profiling(self) -> bool:
        return bool(self.cfg.profile or self.cfg.profile_trace_path)

    # -- engine lifecycle ---------------------------------------------------

    def attach(self, engine) -> None:
        """Record static run context + seed the pool gauges.  Called by the
        engine at the end of construction and again after :meth:`reset`."""
        cfg = engine.config
        backend = getattr(engine, "backend", "paged" if engine.paged else "")
        self.registry.meta.update({
            "arch": engine.model.cfg.name,
            "family": engine.model.cfg.family,
            "kv_dtype": (cfg.kv_dtype if backend in ("paged", "statepool")
                         else "dense_slots"),
            "decode_backend": engine.decode_backend,
            "n_slots": cfg.n_slots,
            "spec_proposer": engine.spec.proposer if engine.spec else None,
            "spec_k": engine.spec.k if engine.spec else None,
        })
        g = self.registry.gauge
        g("kv_cache_bytes").set(engine.cache.cache_bytes())
        if engine.paged:
            total = engine.cache.n_pages - 1  # scratch page is not allocatable
            g("pool_pages_total").set(total)
            g("pool_pages_free").set(engine.cache.free_pages)
            g("pool_pages_free_watermark").set(engine.cache.free_pages)
        elif backend == "statepool":
            stats = engine.cache.plane_stats()
            total = sum(s["pages_total"] for s in stats.values())
            free = sum(s["pages_free"] for s in stats.values())
            g("pool_pages_total").set(total)
            g("pool_pages_free").set(free)
            g("pool_pages_free_watermark").set(free)
            for kind, s in stats.items():
                g(f"pool_pages_total_{kind}").set(s["pages_total"])
                g(f"pool_pages_free_{kind}").set(s["pages_free"])
        # seed compile-count gauges so the profiler's compile-event diffing
        # doesn't re-announce warmup compiles after a post-warmup reset
        for name, count in engine.compile_counts().items():
            g(f"jit_compiled_{name}").set(count)
        if self.profiling:
            from repro.serve.telemetry.profiling import EngineProfiler
            old = self.profiler
            self.profiler = EngineProfiler(
                engine, self.registry, trace_path=self.cfg.profile_trace_path,
                pid=old.pid if old is not None else 0)
            if old is not None and old.engine is engine:
                # re-attach after reset(): drop the warmup trace but keep the
                # memoized step costs (pure functions of the engine's avals)
                self.profiler._costs = old._costs

    def phase(self, name: str, now: float, tick_t0: float,
              t0: float, t1: float) -> None:
        """One phase section of a tick finished.  ``tick_t0``/``t0``/``t1``
        are ``perf_counter`` readings (tick entry / section start / section
        end); ``now`` is the engine clock at tick entry — the profiler places
        the span at ``now + (t0 - tick_t0)`` so traces and request spans
        share one clock.  With profiling off this is exactly the histogram
        observe the engine used to do inline."""
        self.registry.histogram(f"{name}_tick_s").observe(t1 - t0)
        if self.profiler is not None:
            self.profiler.on_phase(name, now + (t0 - tick_t0), t1 - t0)

    def end_tick(self, engine, now: float, wall_s: float) -> None:
        reg = self.registry
        reg.counter("engine_ticks").inc()
        reg.histogram("tick_s").observe(wall_s)
        sched = engine.sched
        g = reg.gauge
        g("queue_depth").set(len(sched.queue))
        g("slots_active").set(len(sched.active))
        g("slots_prefilling").set(len(sched.prefilling()))
        g("slots_decoding").set(len(sched.decoding()))
        if engine.paged:
            total = engine.cache.n_pages - 1
            free = engine.cache.free_pages
            g("pool_pages_free").set(free)
            g("pool_pages_free_watermark").set_min(free)
            occ = engine.cache.occupancy()
            g("pool_occupancy").set(occ)
            g("pool_occupancy_peak").set_max(occ)
            prefix = getattr(engine, "prefix", None)
            if prefix is not None:
                g("prefix_cached_pages").set(prefix.cached_pages())
                if (lookups := reg.counter("prefix_lookups").value):
                    g("prefix_hit_rate").set(
                        reg.counter("prefix_hit_requests").value / lookups)
        elif getattr(engine, "backend", "") == "statepool":
            stats = engine.cache.plane_stats()
            free = sum(s["pages_free"] for s in stats.values())
            g("pool_pages_free").set(free)
            g("pool_pages_free_watermark").set_min(free)
            occ = engine.cache.occupancy()
            g("pool_occupancy").set(occ)
            g("pool_occupancy_peak").set_max(occ)
            for kind, s in stats.items():
                g(f"pool_pages_free_{kind}").set(s["pages_free"])
                g(f"pool_occupancy_{kind}").set(s["occupancy"])
            if getattr(engine, "cross_share", False):
                g("prefix_cached_pages").set(
                    engine.cache.cross_index.cached_pages())
                if (lookups := reg.counter("prefix_lookups").value):
                    g("prefix_hit_rate").set(
                        reg.counter("prefix_hit_requests").value / lookups)
        for name, count in engine.compile_counts().items():
            gauge = g(f"jit_compiled_{name}")
            if self.profiler is not None and count > gauge.value:
                self.profiler.compile_event(name, now, count)
            gauge.set(count)
        if self.profiler is not None:
            self.profiler.on_tick(engine, now, wall_s)
        toks = reg.counter("tokens_generated").value
        reg.rate("tokens_per_sec_ewma").mark(toks - self._last_tokens,
                                             time.perf_counter())
        self._last_tokens = toks
        stride = self.cfg.quant_stride
        if stride and engine.steps % stride == 0:
            self.sample_quant_health(engine.cache)
        self._last_now = now
        every = self.cfg.emit_every_ticks
        if every and engine.steps % every == 0:
            self.emit(now)

    def sample_quant_health(self, cache) -> dict | None:
        """Fetch the device-side pool reduction and fold it into the
        registry (no-op on dense pools / empty tables).  A ``StatePool``
        routes per tenant kind: attn-KV and cross-KV planes through the
        paged reduction, state rings through the ring reduction."""
        from repro.serve.state_pool import StatePool

        if isinstance(cache, StatePool):
            out = sample_state_health(cache)
            if out is None:
                return None
            g = self.registry.gauge
            if "kv" in out:
                for s in ("k", "v"):
                    g(f"kv_clip_fraction_{s}").set(float(out["kv"][s]["clip_frac"]))
                    g(f"kv_zero_fraction_{s}").set(float(out["kv"][s]["zero_frac"]))
                    self.registry.binned(f"kv_scale_hist_{s}", 256).set_counts(
                        out["kv"][s]["scale_hist"].tolist())
            if "cross" in out:
                for s in ("k", "v"):
                    g(f"cross_clip_fraction_{s}").set(
                        float(out["cross"][s]["clip_frac"]))
                    g(f"cross_zero_fraction_{s}").set(
                        float(out["cross"][s]["zero_frac"]))
            if "state" in out:
                g("state_clip_fraction").set(float(out["state"]["clip_frac"]))
                g("state_zero_fraction").set(float(out["state"]["zero_frac"]))
            self.registry.counter("quant_health_samples").inc()
            return out
        out = sample_pool_health(cache)
        if out is None:
            return None
        g = self.registry.gauge
        for s in ("k", "v"):
            g(f"kv_clip_fraction_{s}").set(float(out[s]["clip_frac"]))
            g(f"kv_zero_fraction_{s}").set(float(out[s]["zero_frac"]))
            self.registry.binned(f"kv_scale_hist_{s}", 256).set_counts(
                out[s]["scale_hist"].tolist())
        self.registry.counter("quant_health_samples").inc()
        return out

    # -- exports ------------------------------------------------------------

    def snapshot(self, t: float | None = None) -> dict:
        return self.registry.snapshot(self._last_now if t is None else t)

    def emit(self, t: float | None = None) -> dict:
        snap = self.snapshot(t)
        for sink in self.sinks:
            sink.emit(snap, self.registry)
        return snap

    def summary(self, t: float | None = None) -> str:
        return render_summary(self.snapshot(t))

    def finalize(self, t: float | None = None) -> dict:
        """Final emit + close sinks/trace file; idempotent."""
        if self._finalized:
            return self.snapshot(t)
        snap = self.emit(t)
        for sink in self.sinks:
            sink.close()
        if self.profiler is not None:
            self.profiler.finalize(self.tracer)
        self.tracer.close()
        self._finalized = True
        return snap

    def reset(self, engine=None) -> None:
        """Zero all metrics (schema survives) — drops warmup traffic from
        benchmark runs.  Pass the engine to re-seed the static gauges."""
        self.registry.reset()
        self._last_tokens = 0
        if engine is not None:
            self.attach(engine)
