"""Token data pipeline.

Two sources behind one interface:

* ``MemmapTokenDataset`` — production path: a flat binary of token ids
  (np.uint16/uint32 memmap, the standard "packed tokens" format; a C4
  tokenization drops in directly).
* ``SyntheticC4Dataset`` — a C4-statistics stand-in for the offline
  container: Zipf-distributed unigrams mixed with an order-2 Markov chain
  over a seeded transition table, so models have real learnable structure
  (validation losses order methods the same way real text does, which is
  what the Table-3 benchmark needs) without shipping the corpus.

``TokenBatcher`` handles sequence packing, per-host sharding (each host reads
only its slice), deterministic order from (seed, step) — so resuming from a
checkpoint replays the exact stream — and next-token label shifting.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class MemmapTokenDataset:
    """Flat token-id file. ``tokens[i]`` addressable, len() known."""

    def __init__(self, path: str, dtype=np.uint16, vocab_size: int | None = None):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab_size = vocab_size or int(self.tokens.max()) + 1

    def __len__(self) -> int:
        return len(self.tokens)

    def slice(self, start: int, n: int) -> np.ndarray:
        idx = np.arange(start, start + n) % len(self.tokens)
        return np.asarray(self.tokens[idx], dtype=np.int32)


class SyntheticC4Dataset:
    """Deterministic synthetic corpus: topic blocks + Zipfian vocabulary.

    The stream is position-addressable (token[i] = f(seed, i), no state), so
    sharding and resume are trivial.  Structure: positions are grouped into
    topic blocks of 64 tokens; within a block, 85% of tokens come from that
    topic's 64-token sub-vocabulary (Zipf-weighted), the rest from a global
    Zipf.  A model that infers the topic from context predicts within ~6 bits
    instead of ~log2(V) — real, learnable sequence structure (conditional
    entropy well below unigram entropy), which is what the Table-3 method
    comparison needs from its corpus.
    """

    BLOCK = 64
    TOPIC_VOCAB = 64
    N_TOPICS = 512
    IN_TOPIC = 0.85

    def __init__(self, vocab_size: int = 32000, seed: int = 0,
                 length: int = 1 << 34):
        self.vocab_size = vocab_size
        self.seed = seed
        self._length = length
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._unigram_cdf = np.cumsum(p / p.sum())
        tv = min(self.TOPIC_VOCAB, vocab_size)
        self._topics = rng.integers(0, vocab_size,
                                    size=(self.N_TOPICS, tv), dtype=np.int32)
        w = 1.0 / np.arange(1, tv + 1, dtype=np.float64)
        self._topic_cdf = np.cumsum(w / w.sum())

    def __len__(self) -> int:
        return self._length

    def _hash(self, i: np.ndarray, salt: int = 0) -> np.ndarray:
        h = (i.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
             + np.uint64((self.seed * 0xBF58476D1CE4E5B9 + salt * 0x2545F4914F6CDD1D)
                         % (1 << 64)))
        h ^= h >> np.uint64(31)
        h *= np.uint64(0x94D049BB133111EB)
        h ^= h >> np.uint64(29)
        return h

    def slice(self, start: int, n: int) -> np.ndarray:
        i = np.arange(start, start + n, dtype=np.int64)
        h = self._hash(i)
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        base = np.minimum(np.searchsorted(self._unigram_cdf, u),
                          self.vocab_size - 1).astype(np.int32)
        topic = (self._hash(i // self.BLOCK, salt=1) % np.uint64(self.N_TOPICS)).astype(np.int64)
        u2 = (self._hash(i, salt=2) >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        tv = self._topics.shape[1]
        pick = np.minimum(np.searchsorted(self._topic_cdf, u2), tv - 1)
        in_topic = self._topics[topic, pick]
        use_topic = ((h >> np.uint64(40)).astype(np.float64) / float(1 << 24)) < self.IN_TOPIC
        return np.where(use_topic, in_topic, base).astype(np.int32)


@dataclasses.dataclass
class TokenBatcher:
    """Deterministic next-token batches with host sharding.

    state = (step); batch(step) is a pure function, so checkpoint/resume and
    elastic re-sharding (different host counts) need no stream replay.
    """

    dataset: object
    global_batch: int
    seq_len: int
    host_index: int = 0
    host_count: int = 1
    seed: int = 0

    def batch(self, step: int) -> dict:
        per_host = self.global_batch // self.host_count
        rows = []
        stride = self.seq_len + 1
        for r in range(per_host):
            row = self.host_index * per_host + r
            start = (step * self.global_batch + row) * stride + self.seed
            rows.append(self.dataset.slice(start, stride))
        arr = np.stack(rows)  # [per_host, seq+1]
        return {
            "tokens": arr[:, :-1].astype(np.int32),
            "labels": arr[:, 1:].astype(np.int32),
        }


def make_dataset(spec: str, vocab_size: int, seed: int = 0):
    """spec: "synthetic" or a path to a memmap token file."""
    if spec == "synthetic":
        return SyntheticC4Dataset(vocab_size=vocab_size, seed=seed)
    return MemmapTokenDataset(spec, vocab_size=vocab_size)
