"""Data pipeline: deterministic, checkpointable token streams."""

from repro.data.pipeline import (  # noqa: F401
    MemmapTokenDataset,
    SyntheticC4Dataset,
    TokenBatcher,
    make_dataset,
)
