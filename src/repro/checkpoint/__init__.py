"""Fault-tolerant checkpointing: sharded, atomic, async, elastic."""

from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
