"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):

    <dir>/step_000123.tmp/           # written first
        meta.json                    # pytree structure + shapes + dtypes
        <leaf-idx>.npy               # one file per pytree leaf (host arrays)
    <dir>/step_000123/               # atomic rename when complete

Design points for 1000+-node runs:
  * atomic visibility: readers never see partial checkpoints (rename is the
    commit point; a crashed writer leaves only a .tmp to be garbage-collected);
  * async: serialization happens on a background thread off the step loop —
    the step only pays for the device→host copy;
  * keep-K retention with GC;
  * elastic restore: arrays are loaded to host then ``jax.device_put`` with
    the *target* sharding — the new mesh may differ from the writer's
    (scale-up/down restart), since leaves are stored unsharded.  Per-shard
    parallel writes (one file per shard) slot in behind the same API when
    hosts have disjoint filesystems; this single-host implementation writes
    assembled arrays.
  * data-pipeline state (step) rides in meta.json, so resume replays the
    exact token stream (pipeline is position-addressable).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------- save
    def save(self, step: int, state, extra_meta: dict | None = None,
             blocking: bool = False):
        """Snapshot to host memory now; write to disk on a background thread."""
        self.wait()  # one outstanding write at a time
        leaves, treedef = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {
            "step": int(step),
            # structure is re-derived from `state_like` at restore; only the
            # leaf count is needed for integrity checking
            "num_leaves": len(host_leaves),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "time": time.time(),
            **(extra_meta or {}),
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for i, arr in enumerate(host_leaves):
                # ml_dtypes (bfloat16, fp8, ...) round-trip through npy as
                # void; store their raw bits as uintN and re-view on restore
                if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
                    arr = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
                np.save(os.path.join(tmp, f"{i}.npy"), arr)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # commit point
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)
        # remove orphaned .tmp dirs from crashed writers
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # -------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``state_like``.

        ``shardings``: optional pytree of NamedSharding for elastic placement
        onto a (possibly different) mesh.  Returns (state, meta).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        leaves_like, treedef = jax.tree_util.tree_flatten(state_like)
        assert meta["num_leaves"] == len(leaves_like), \
            f"checkpoint has {meta['num_leaves']} leaves, state needs {len(leaves_like)}"
        for i, (like, shp) in enumerate(zip(leaves_like, meta["shapes"])):
            assert tuple(like.shape) == tuple(shp), \
                f"leaf {i}: checkpoint shape {shp} != expected {tuple(like.shape)}"
        import ml_dtypes  # noqa: F401  (registers bfloat16 & friends)

        host = []
        for i in range(len(leaves_like)):
            arr = np.load(os.path.join(d, f"{i}.npy"))
            want = np.dtype(meta["dtypes"][i])
            if arr.dtype != want:
                arr = arr.view(want)
            host.append(arr)
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)
            new = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                   for a, s in zip(host, sh_leaves)]
        else:
            new = [jax.device_put(a) for a in host]
        return jax.tree_util.tree_unflatten(treedef, new), meta
